"""Paper Fig. 7 — mRMR scalability across the number of SELECTED features.

Paper setting: 1M rows × 50k columns (wide/short -> ALTERNATIVE encoding),
select L ∈ {1, 2, 4, 6, 10}, 10 nodes.  Paper claim: SUBLINEAR relative ET
in L (fixed per-iteration overheads amortise).

The beyond-paper incremental variant turns the per-iteration redundancy
recompute (O(l) passes) into O(1); both slopes are recorded.
"""

from __future__ import annotations

from benchmarks.common import SCALE, csv_row, relative, run_worker, save

POINTS = {
    "smoke": dict(rows=1_000, cols=20_000, select=[1, 2, 4, 6, 10],
                  devices=8, repeats=3),
    "full": dict(rows=10_000, cols=50_000, select=[1, 2, 4, 6, 10],
                 devices=8, repeats=3),
}


def main() -> dict:
    p = POINTS[SCALE]
    out = {"figure": "fig7_selected", "scale": SCALE, "points": []}
    for variant, inc in (("paper-faithful", 0), ("incremental", 1)):
        for sel in p["select"]:
            rec = run_worker(
                devices=p["devices"], rows=p["rows"], cols=p["cols"],
                select=sel, encoding="alternative", score="mi",
                incremental=inc, repeats=p["repeats"],
            )
            rec["variant"] = variant
            out["points"].append(rec)
            csv_row(
                f"fig7/{variant}/L={sel}",
                rec["mean_s"] * 1e6,
                f"hits={rec['relevant_hits']}/{min(sel, 9)}",
            )
    for variant in ("paper-faithful", "incremental"):
        pts = [q for q in out["points"] if q["variant"] == variant]
        rel_t = relative([q["mean_s"] for q in pts])
        rel_l = relative([float(q["select"]) for q in pts])
        out[f"relative_et_{variant}"] = rel_t
        out["relative_L"] = rel_l
        print(f"fig7 {variant}: rel L {rel_l} -> rel ET "
              f"{[round(t, 2) for t in rel_t]} (paper: sublinear)")
    save("fig7_selected", out)
    return out


if __name__ == "__main__":
    main()
