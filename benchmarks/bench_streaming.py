"""Streaming vs in-memory fit: throughput + peak-memory estimate (dry-run).

Generates the paper's CorrAL-style dataset straight to a memmapped
``.npy`` (never materialising it on the host), fits once in-memory and
once per ``--block-obs`` value through the streaming engine, verifies the
selections agree, and records wall time, scoring-pass throughput and the
peak *input* bytes resident on device — ``M·N`` for in-memory vs
``block_obs·N`` + statistics for streaming, the block-size/memory
trade-off in one table.

    PYTHONPATH=src python benchmarks/bench_streaming.py --rows 200000 \
        --cols 256 --select 10 --block-obs 16384,65536 \
        --out BENCH_streaming.json

The committed ``BENCH_streaming.json`` at the repo root is the baseline
(default sizes above) that later PRs compare their perf trajectory to.
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

from repro import MIScore, MRMRSelector
from repro.data.sources import CorralSource, NpySource


def _fit_record(mode: str, args, fit_fn, peak_input_bytes: int) -> dict:
    t0 = time.time()
    sel = fit_fn()
    dt = time.time() - t0
    # Both engines run L scoring passes (1 relevance + L-1/L redundancy);
    # rows/s is nominal pass throughput over the whole selection.
    passes = args.select
    return dict(
        mode=mode,
        rows=args.rows,
        cols=args.cols,
        select=args.select,
        seconds=round(dt, 3),
        rows_per_s=round(args.rows * passes / dt),
        peak_input_bytes=int(peak_input_bytes),
        selected=sel.selected_.tolist(),
    )


def main(argv=None) -> list:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rows", type=int, default=200_000)
    ap.add_argument("--cols", type=int, default=256)
    ap.add_argument("--select", type=int, default=10)
    ap.add_argument("--block-obs", default="16384,65536",
                    help="comma-separated streaming block sizes")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write records to this JSON")
    args = ap.parse_args(argv)

    score = MIScore(num_values=2, num_classes=2)
    blocks = [int(b) for b in args.block_obs.split(",")]
    state_bytes = args.cols * 2 * 2 * 4  # (N, d_v, d_c) f32 statistics

    with tempfile.TemporaryDirectory() as tmp:
        src = CorralSource(args.rows, args.cols, seed=args.seed)
        x_path, y_path = src.to_npy(
            os.path.join(tmp, "X.npy"), os.path.join(tmp, "y.npy")
        )
        npy = NpySource(x_path, y_path)

        X, y = npy.materialize()
        records = [
            _fit_record(
                "in_memory", args,
                lambda: MRMRSelector(num_select=args.select,
                                     score=score).fit(X, y),
                X.nbytes,
            )
        ]
        base = records[0]["selected"]
        for bo in blocks:
            rec = _fit_record(
                f"streaming@{bo}", args,
                lambda bo=bo: MRMRSelector(
                    num_select=args.select, score=score, block_obs=bo
                ).fit(NpySource(x_path, y_path)),
                bo * args.cols * X.dtype.itemsize + state_bytes,
            )
            rec["block_obs"] = bo
            if rec["selected"] != base:
                raise SystemExit(
                    f"streaming@{bo} diverged: {rec['selected']} != {base}"
                )
            records.append(rec)

    for r in records:
        print(
            f"{r['mode']:<18s} {r['seconds']:8.2f}s "
            f"{r['rows_per_s']:>12,d} rows/s "
            f"peak_input={r['peak_input_bytes'] / 1e6:8.1f} MB"
        )
    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
    return records


if __name__ == "__main__":
    main()
