"""Streaming vs in-memory fit: throughput + peak-memory estimate (dry-run).

Generates the paper's CorrAL-style dataset straight to a memmapped
``.npy`` (never materialising it on the host), fits once in-memory and
once per ``(--block-obs, --prefetch)`` cell through the streaming engine,
verifies the selections agree, and records wall time, scoring-pass
throughput and the peak *input* bytes resident on device — ``M·N`` for
in-memory vs ``block_obs·N`` + statistics for streaming.  ``--prefetch
0,2`` turns the same table into a synchronous-vs-double-buffered placer
comparison.  A second **wide** dataset (``--wide-rows``/``--wide-cols``,
``m/n <= 0.25`` — the regime where feature-sharded statistics matter)
runs the same grid against the in-memory alternative engine.  A third
**continuous** float dataset (``--cont-rows``/``--cont-cols``/``--bins``)
compares exact MI on sketch-binned codes (``bins=``, in-memory vs
streaming, selections must agree) against the Pearson approximation —
the only pre-binning continuous path — and times the one-off quantile
sketch pass that cuts the bin edges.

The **I/O-tax cells** (``--batch-candidates`` / ``--spill-dir`` /
``--readahead``) measure the three pass-count/pass-cost knobs on the
smallest tall block (the regime where per-pass cost dominates): batched
redundancy (``+qN``), the encoded-block spill cache (``+spill``),
cross-pass read-ahead (``+raN``) and all three combined.  Each cell must
reproduce the plain streaming selections bitwise and records the
engine's ``io`` ledger (passes / blocks / bytes, parse-vs-replay split)
alongside the timing.

``--criterion mid,miq,jmi,cmim`` adds a greedy-objective axis: the FIRST
criterion runs the full (block x prefetch) grid on both datasets; each
further criterion runs one tall cell (largest block, last prefetch depth)
plus its own in-memory baseline.  For the marginal folds (miq) the cell
shows the fold is free (O(N) host math per pick; passes/IO identical to
mid's same-block cell); for the conditional folds (jmi/cmim) it prices
the class axis exactly — ``io.state_bytes`` doubles (d_c x the pair
statistics) while passes and bytes_read stay identical to mid, because
the 3-way count rides the same sweep via the fused-target trick.
Streaming cells must reproduce the in-memory selections OF THE SAME
CRITERION.

    PYTHONPATH=src python benchmarks/bench_streaming.py --rows 200000 \
        --cols 256 --select 10 --block-obs 16384,65536 --prefetch 0,2 \
        --criterion mid,miq,jmi,cmim --out BENCH_streaming.json

The committed ``BENCH_streaming.json`` at the repo root is the baseline
(default sizes above, criteria ``mid,miq,jmi,cmim``) that later PRs
compare their perf trajectory to.
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

from repro import MIScore, MRMRSelector, PearsonMIScore
from repro.data.binning import clear_binner_memo, fit_binned
from repro.data.sources import CorralSource, NpySource


def _fit_record(
    mode: str, rows: int, cols: int, select: int, fit_fn,
    peak_input_bytes: int, repeats: int = 1,
) -> dict:
    # min over repeats: the shared CI/container boxes these run on are
    # noisy, and the minimum is the least-contended (most comparable)
    # observation of each cell.
    dt = float("inf")
    for _ in range(repeats):
        t0 = time.time()
        sel = fit_fn()
        dt = min(dt, time.time() - t0)
    # Both engines run L scoring passes (1 relevance + L-1/L redundancy);
    # rows/s is nominal pass throughput over the whole selection.
    rec = dict(
        mode=mode,
        rows=rows,
        cols=cols,
        select=select,
        seconds=round(dt, 3),
        rows_per_s=round(rows * select / dt),
        peak_input_bytes=int(peak_input_bytes),
        repeats=repeats,
        selected=sel.selected_.tolist(),
    )
    # Streamed fits carry the pass/bytes ledger: savings from batching /
    # spilling / read-ahead are asserted from it, not eyeballed.
    if sel.result_ is not None and sel.result_.io is not None:
        rec["io"] = sel.result_.io
    return rec


def _bench_dataset(
    tag: str, rows: int, cols: int, select: int, blocks, prefetches,
    seed: int, tmp: str, repeats: int, criterion: str = "mid",
) -> list:
    """In-memory baseline + the (block_obs × prefetch) streaming grid for
    one dataset; every streaming cell must reproduce the baseline OF THE
    SAME CRITERION."""
    score = MIScore(num_values=2, num_classes=2)
    state_bytes = cols * 2 * 2 * 4  # (N, d_v, d_c) statistics
    x_path = os.path.join(tmp, f"{tag}X.npy")
    y_path = os.path.join(tmp, f"{tag}y.npy")
    if not (os.path.exists(x_path) and os.path.exists(y_path)):
        # tag + seed pin the dataset, so a later criterion's run over the
        # same tag reuses the files instead of regenerating ~rows x cols.
        CorralSource(rows, cols, seed=seed).to_npy(x_path, y_path)
    X, y = NpySource(x_path, y_path).materialize()

    parts = ([] if tag == "tall" else [tag]) + (
        [] if criterion == "mid" else [criterion]
    )
    prefix = "".join(f"{p}_" for p in parts)
    records = [
        _fit_record(
            f"{prefix}in_memory", rows, cols, select,
            lambda: MRMRSelector(num_select=select, score=score,
                                 criterion=criterion).fit(X, y),
            X.nbytes, repeats,
        )
    ]
    base = records[0]["selected"]
    for bo in blocks:
        # Warm the compiled accumulate for this block shape (a select=2 fit
        # traces both the class and feature passes), so the prefetch cells
        # compare placement strategies, not compilation order.
        MRMRSelector(num_select=2, score=score, block_obs=bo).fit(
            NpySource(x_path, y_path)
        )
        for pf in prefetches:
            rec = _fit_record(
                f"{prefix}streaming@{bo}+pf{pf}", rows, cols, select,
                lambda bo=bo, pf=pf: MRMRSelector(
                    num_select=select, score=score, criterion=criterion,
                    block_obs=bo, prefetch=pf,
                ).fit(NpySource(x_path, y_path)),
                bo * cols * X.dtype.itemsize + state_bytes, repeats,
            )
            rec["block_obs"] = bo
            rec["prefetch"] = pf
            if rec["selected"] != base:
                raise SystemExit(
                    f"{rec['mode']} diverged: {rec['selected']} != {base}"
                )
            records.append(rec)
    for r in records:
        r["criterion"] = criterion
    return records


def _bench_io_tax(
    tag: str, rows: int, cols: int, select: int, bo: int, base: list,
    qs, spill_root: str, readahead: int, tmp: str, repeats: int,
) -> list:
    """The L-pass I/O-tax cells on one dataset: batched redundancy,
    encoded-block spill, cross-pass read-ahead, and all three combined.
    Every cell must reproduce the plain streaming selections bitwise."""
    score = MIScore(num_values=2, num_classes=2)
    state_bytes = cols * 2 * 2 * 4
    x_path = os.path.join(tmp, f"{tag}X.npy")
    y_path = os.path.join(tmp, f"{tag}y.npy")
    prefix = "" if tag == "tall" else f"{tag}_"
    dtype_bytes = np.load(x_path, mmap_mode="r").dtype.itemsize

    def cell(mode: str, state_mult: int = 1, **knobs) -> dict:
        rec = _fit_record(
            f"{prefix}{mode}", rows, cols, select,
            lambda: MRMRSelector(
                num_select=select, score=score, block_obs=bo, **knobs
            ).fit(NpySource(x_path, y_path)),
            bo * cols * dtype_bytes + state_bytes * state_mult, repeats,
        )
        rec["block_obs"] = bo
        rec.update(knobs)
        if rec["selected"] != base:
            raise SystemExit(
                f"{rec['mode']} diverged: {rec['selected']} != {base}"
            )
        return rec

    records = []
    for q in qs:
        # Warm the batched (vmapped) accumulate for this (block, q) shape
        # so the cell times passes, not the one-off XLA compile.
        MRMRSelector(num_select=2, score=score, block_obs=bo,
                     batch_candidates=q).fit(NpySource(x_path, y_path))
        records.append(cell(f"streaming@{bo}+q{q}", state_mult=q,
                            batch_candidates=q))
    # Spill cells share one directory so repeats 2..R (and the combined
    # cell) time the replay path; min-over-repeats records the warm state,
    # the io ledger of the last run shows parse vs replay traffic.
    spill = os.path.join(spill_root, tag)
    records.append(cell(f"streaming@{bo}+spill", spill_dir=spill))
    records.append(cell(f"streaming@{bo}+ra{readahead}",
                        readahead=readahead))
    q = max(qs)
    records.append(cell(
        f"streaming@{bo}+q{q}+spill+ra{readahead}", state_mult=q,
        batch_candidates=q, spill_dir=spill, readahead=readahead,
    ))
    for r in records:
        r["criterion"] = "mid"
    return records


def _bench_continuous(
    rows: int, cols: int, select: int, bins: int, blocks, prefetch: int,
    seed: int, tmp: str, repeats: int,
) -> list:
    """Continuous float dataset: exact-MI-on-binned-codes (``bins=``) vs the
    Pearson approximation (the only pre-binning continuous path), plus the
    cost of the one-off sketch pass that cuts the bin edges."""
    x_path = os.path.join(tmp, "contX.npy")
    y_path = os.path.join(tmp, "conty.npy")
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 2, size=rows).astype(np.int32)
    X = rng.normal(size=(rows, cols)).astype(np.float32)
    k = min(8, cols)
    X[:, :k] += y[:, None] * np.linspace(1.5, 0.3, k)[None, :].astype(
        np.float32
    )
    np.save(x_path, X)
    np.save(y_path, y)

    # The sketch pass is the only cost bins= adds on top of the discrete
    # streaming path: one extra read of the source.  Cleared memo each
    # repeat so every timing pays the full pass.
    dt = float("inf")
    for _ in range(repeats):
        clear_binner_memo()
        t0 = time.time()
        fit_binned(NpySource(x_path, y_path), bins, block_obs=max(blocks))
        dt = min(dt, time.time() - t0)
    records = [dict(
        mode="cont_sketch_pass", rows=rows, cols=cols, select=select,
        seconds=round(dt, 3), rows_per_s=round(rows / dt),
        peak_input_bytes=max(blocks) * cols * 4, repeats=repeats,
        selected=[], criterion="mid", bins=bins,
    )]

    records.append(_fit_record(
        "cont_binned_in_memory", rows, cols, select,
        lambda: MRMRSelector(num_select=select, bins=bins).fit(X, y),
        X.nbytes, repeats,
    ))
    base = records[-1]["selected"]
    for bo in blocks:
        rec = _fit_record(
            f"cont_binned_streaming@{bo}+pf{prefetch}", rows, cols, select,
            lambda bo=bo: MRMRSelector(
                num_select=select, bins=bins, block_obs=bo,
                prefetch=prefetch,
            ).fit(NpySource(x_path, y_path)),
            bo * cols * 4, repeats,
        )
        rec["block_obs"] = bo
        rec["prefetch"] = prefetch
        if rec["selected"] != base:
            raise SystemExit(
                f"{rec['mode']} diverged: {rec['selected']} != {base}"
            )
        records.append(rec)
    # bins-off comparator: the Pearson approximation is the only engine
    # path that accepts raw floats.  Different score, so selections may
    # legitimately differ — no divergence check, just the throughput cell.
    bo = max(blocks)
    rec = _fit_record(
        f"cont_pearson_streaming@{bo}+pf{prefetch}", rows, cols, select,
        lambda: MRMRSelector(
            num_select=select, score=PearsonMIScore(), block_obs=bo,
            prefetch=prefetch,
        ).fit(NpySource(x_path, y_path)),
        bo * cols * 4, repeats,
    )
    rec["block_obs"] = bo
    rec["prefetch"] = prefetch
    records.append(rec)
    for r in records:
        r.setdefault("criterion", "mid")
        r["bins"] = bins if "pearson" not in r["mode"] else 0
    return records


def main(argv=None) -> list:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rows", type=int, default=200_000)
    ap.add_argument("--cols", type=int, default=256)
    ap.add_argument("--select", type=int, default=10)
    ap.add_argument("--block-obs", default="16384,65536",
                    help="comma-separated streaming block sizes (tall case)")
    ap.add_argument("--prefetch", default="0,2",
                    help="comma-separated prefetch depths (0 = synchronous)")
    ap.add_argument("--wide-rows", type=int, default=4096,
                    help="wide-case rows (0 skips the wide case)")
    ap.add_argument("--wide-cols", type=int, default=16384)
    ap.add_argument("--wide-block-obs", default="1024,4096",
                    help="comma-separated streaming block sizes (wide case)")
    ap.add_argument("--cont-rows", type=int, default=100_000,
                    help="continuous-case rows (0 skips the continuous case)")
    ap.add_argument("--cont-cols", type=int, default=64)
    ap.add_argument("--cont-block-obs", default="16384,65536",
                    help="comma-separated streaming block sizes (continuous)")
    ap.add_argument("--bins", type=int, default=16,
                    help="equal-frequency bins for the continuous case")
    ap.add_argument("--batch-candidates", default="4,8",
                    help="comma-separated q values for the batched-"
                         "redundancy cells (empty string skips them)")
    ap.add_argument("--spill-dir", default="",
                    help="encoded-block spill directory for the spill "
                         "cells (default: a per-run temp dir)")
    ap.add_argument("--readahead", type=int, default=2,
                    help="cross-pass read-ahead depth for the read-ahead "
                         "and combined cells")
    ap.add_argument("--criterion", default="mid,miq,jmi,cmim",
                    help="comma-separated greedy objectives; the first runs "
                         "the full grid, the rest one tall cell each "
                         "(largest block, last prefetch) + in-memory "
                         "baseline")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed repeats per cell (min is recorded)")
    ap.add_argument("--out", default=None, help="write records to this JSON")
    args = ap.parse_args(argv)
    if args.repeats < 1:
        ap.error(f"--repeats must be >= 1, got {args.repeats}")

    prefetches = [int(p) for p in args.prefetch.split(",")]
    criteria = args.criterion.split(",")
    tall_blocks = [int(b) for b in args.block_obs.split(",")]
    with tempfile.TemporaryDirectory() as tmp:
        records = _bench_dataset(
            "tall", args.rows, args.cols, args.select,
            tall_blocks, prefetches, args.seed, tmp, args.repeats,
            criterion=criteria[0],
        )
        for crit in criteria[1:]:
            # One cell per extra criterion: the fold is O(N) host math per
            # pick, so its throughput must sit within noise of the first
            # criterion's same-block cell.
            records += _bench_dataset(
                "tall", args.rows, args.cols, args.select,
                [max(tall_blocks)], prefetches[-1:], args.seed, tmp,
                args.repeats, criterion=crit,
            )
        qs = [int(q) for q in args.batch_candidates.split(",") if q]
        if qs:
            # I/O-tax cells ride the smallest tall block — the regime
            # where per-pass cost dominates and the PR 7 baseline showed
            # the 3x falloff the knobs attack.
            tall_base = next(
                r for r in records if r["mode"].startswith("streaming@")
            )["selected"]
            records += _bench_io_tax(
                "tall", args.rows, args.cols, args.select,
                min(tall_blocks), tall_base, qs,
                args.spill_dir or os.path.join(tmp, "spill"),
                args.readahead, tmp, args.repeats,
            )
        if args.wide_rows > 0:
            if args.wide_rows > args.wide_cols * 0.25:
                raise SystemExit(
                    f"--wide-rows {args.wide_rows} / --wide-cols "
                    f"{args.wide_cols} is not wide (m/n must be <= 0.25)"
                )
            wide_blocks = [int(b) for b in args.wide_block_obs.split(",")]
            wide_records = _bench_dataset(
                "wide", args.wide_rows, args.wide_cols, args.select,
                wide_blocks, prefetches,
                args.seed + 1, tmp, args.repeats, criterion=criteria[0],
            )
            records += wide_records
            if qs:
                records += _bench_io_tax(
                    "wide", args.wide_rows, args.wide_cols, args.select,
                    min(wide_blocks), wide_records[0]["selected"], qs,
                    args.spill_dir or os.path.join(tmp, "spill"),
                    args.readahead, tmp, args.repeats,
                )
        if args.cont_rows > 0:
            records += _bench_continuous(
                args.cont_rows, args.cont_cols, args.select, args.bins,
                [int(b) for b in args.cont_block_obs.split(",")],
                prefetches[-1], args.seed + 2, tmp, args.repeats,
            )

    for r in records:
        print(
            f"{r['mode']:<30s} {r['seconds']:8.2f}s "
            f"{r['rows_per_s']:>12,d} rows/s "
            f"peak_input={r['peak_input_bytes'] / 1e6:8.1f} MB"
        )
    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
    return records


if __name__ == "__main__":
    main()
