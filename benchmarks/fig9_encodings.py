"""Paper §V (text) — conventional vs alternative encoding, same dataset.

Paper claim: "The absolute execution time of mRMR MapReduce jobs with
alternative encoding is generally 4-6x faster than the respective jobs with
conventional encoding."

The claim is infrastructure-specific (Spark shuffles vs broadcast); our TPU
adaptation replaces the shuffle with one fused all-reduce of MXU-built
contingency tables, so the conventional path loses most of its Spark-era
penalty.  Both encodings are timed on identical discrete data and the
measured ratio is recorded next to the paper's.
"""

from __future__ import annotations

from benchmarks.common import SCALE, csv_row, run_worker, save

POINTS = {
    "smoke": dict(rows=50_000, cols=1024, select=10, devices=8, repeats=3),
    "full": dict(rows=500_000, cols=1000, select=10, devices=8, repeats=3),
}


def main() -> dict:
    p = POINTS[SCALE]
    out = {"figure": "fig9_encodings", "scale": SCALE, "points": []}
    for enc in ("conventional", "alternative"):
        rec = run_worker(
            devices=p["devices"], rows=p["rows"], cols=p["cols"],
            select=p["select"], encoding=enc, score="mi", incremental=0,
            repeats=p["repeats"],
        )
        rec["variant"] = enc
        out["points"].append(rec)
        csv_row(f"fig9/{enc}", rec["mean_s"] * 1e6,
                f"hits={rec['relevant_hits']}/9")
    conv, alt = out["points"]
    ratio = conv["mean_s"] / alt["mean_s"] if alt["mean_s"] else 0.0
    out["conventional_over_alternative"] = round(ratio, 2)
    print(f"fig9: conventional/alternative ET ratio = {ratio:.2f} "
          f"(paper on Spark: 4-6x; see EXPERIMENTS.md for why ours differs)")
    save("fig9_encodings", out)
    return out


if __name__ == "__main__":
    main()
