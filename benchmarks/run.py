"""Benchmark harness entry point — one suite per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run``            (smoke scale, default)
``REPRO_BENCH_SCALE=full python -m benchmarks.run``    (paper-scale inputs)
``python -m benchmarks.run --only fig5_rows,fig8_nodes``

Prints ``name,us_per_call,derived`` CSV rows per point and writes JSON under
``results/bench/``; EXPERIMENTS.md tables are regenerated from those files
by ``benchmarks/report.py``.
"""

from __future__ import annotations

import argparse
import time
import traceback

from benchmarks import (
    bench_kernels,
    fig5_rows,
    fig6_cols,
    fig7_selected,
    fig8_nodes,
    fig9_encodings,
)

SUITES = {
    "fig5_rows": fig5_rows.main,
    "fig6_cols": fig6_cols.main,
    "fig7_selected": fig7_selected.main,
    "fig8_nodes": fig8_nodes.main,
    "fig9_encodings": fig9_encodings.main,
    "kernels": bench_kernels.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names (default: all)")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)
    failed = []
    print("name,us_per_call,derived")
    for name in names:
        t0 = time.time()
        try:
            SUITES[name]()
            print(f"# suite {name} done in {time.time()-t0:.0f}s", flush=True)
        except Exception:
            failed.append(name)
            print(f"# suite {name} FAILED:\n{traceback.format_exc()}")
    if failed:
        raise SystemExit(f"failed suites: {failed}")


if __name__ == "__main__":
    main()
