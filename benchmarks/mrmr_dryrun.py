"""Cell C of the §Perf hillclimb: the paper's own mRMR job on the
production mesh (dry-run: lower + compile + roofline terms).

The paper's largest conventional-encoding workload — 10M rows × 1 000
binary columns, select L=10 — is sharded over all 256 chips of the single
pod (observation axes = ('data','model'), the MapReduce row-chunking) and
over 512 chips of the two-pod mesh.  Variants:

  paper      — paper-faithful recomputation (O(N·L²) pair scores)
  incremental— running redundancy sums (O(N·L)), identical selections
  f32onehot  — incremental, but f32 one-hot materialisation (pre-C2)

    PYTHONPATH=src python -m benchmarks.mrmr_dryrun [--rows 10000000] ...
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo_analysis import analyze_hlo
from repro.analysis.roofline import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.core.scores import MIScore
from repro.core.selector import SelectionPlan, build_engine_fn
from repro.launch.mesh import make_production_mesh

OUT = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def model_flops_mrmr(rows: int, cols: int, select: int, v: int, c: int,
                     incremental: bool) -> float:
    """Useful one-hot-matmul work: 2·M·N·V·C per scoring pass."""
    passes = (1 + select) if incremental else (1 + select * (select + 1) / 2)
    return 2.0 * rows * cols * v * c * passes


VARIANTS = {
    # name -> (incremental, onehot_dtype, static_inner)
    "paper": (False, "float32", True),
    "incremental": (True, "float32", False),
    "bf16onehot": (True, "bfloat16", False),
}


def run_variant(name: str, mesh_kind: str, rows: int, cols: int, select: int,
                incremental: bool, block: int) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    obs_axes = tuple(mesh.axis_names)  # rows sharded over every axis
    score = MIScore(num_values=2, num_classes=2)
    inc, oh_dt, static_inner = VARIANTS.get(
        name, (incremental, "bfloat16", False)
    )
    # The exact job MRMRSelector would run for this plan, via the engine
    # registry — benchmarks lower/compile the same HLO as production fits.
    plan = SelectionPlan(
        encoding="conventional", obs_axes=obs_axes,
        mesh_shape=tuple(mesh.shape[a] for a in obs_axes),
        block=block, incremental=inc, score=score,
        onehot_dtype=oh_dt, static_inner=static_inner,
    )
    fn = build_engine_fn(plan, mesh, select, cols)
    incremental = inc
    pad_rows = -(-rows // mesh.size) * mesh.size
    X = jax.ShapeDtypeStruct((pad_rows, cols), jnp.int8)
    y = jax.ShapeDtypeStruct((pad_rows,), jnp.int8)
    fn = jax.jit(
        fn,
        in_shardings=(
            NamedSharding(mesh, P(obs_axes, None)),
            NamedSharding(mesh, P(obs_axes)),
        ),
    )
    t0 = time.time()
    compiled = fn.lower(X, y).compile()
    dt = time.time() - t0
    hc = analyze_hlo(compiled.as_text(), bf16_model=False)
    mem = compiled.memory_analysis()
    n = mesh.size
    mf = model_flops_mrmr(rows, cols, select, 2, 2, incremental)
    terms = {
        "compute_s": hc["flops"] / PEAK_FLOPS,
        "memory_s": hc["bytes"] / HBM_BW,
        "collective_s": hc["collectives"]["operand_bytes"] / ICI_BW,
    }
    dom = max(terms, key=terms.get)
    rec = dict(
        variant=name, mesh=mesh_kind, rows=rows, cols=cols, select=select,
        incremental=incremental, block=block, n_devices=n,
        compile_s=round(dt, 1),
        flops_per_device=hc["flops"],
        bytes_per_device=hc["bytes"],
        collective_operand_bytes=hc["collectives"]["operand_bytes"],
        collective_by_type={
            k: v["operand_bytes"]
            for k, v in hc["collectives"]["by_type"].items()
        },
        roofline={**terms, "dominant": dom,
                  "model_flops": mf,
                  "hlo_flops_global": hc["flops"] * n,
                  "useful_flops_ratio": mf / (hc["flops"] * n) if hc["flops"] else 0,
                  },
        hbm_bytes=int(getattr(mem, "temp_size_in_bytes", 0) or 0)
        + int(getattr(mem, "argument_size_in_bytes", 0) or 0),
    )
    print(
        f"mrmr/{name:<12s} {mesh_kind:<6s} comp={terms['compute_s']:9.3e}s "
        f"mem={terms['memory_s']:9.3e}s coll={terms['collective_s']:9.3e}s "
        f"dom={dom[:-2]:<10s} useful={rec['roofline']['useful_flops_ratio']:5.2f} "
        f"compile={dt:.0f}s", flush=True,
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=10_000_000)
    ap.add_argument("--cols", type=int, default=1000)
    ap.add_argument("--select", type=int, default=10)
    ap.add_argument("--block", type=int, default=64)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--variants", default="paper,incremental,bf16onehot")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    recs = []
    for mesh_kind in meshes:
        for v in args.variants.split(","):
            recs.append(
                run_variant(
                    v, mesh_kind, args.rows, args.cols, args.select,
                    incremental=(v != "paper"), block=args.block,
                )
            )
    out = os.path.join(os.path.abspath(OUT), "mrmr_cells.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    existing = []
    if os.path.exists(out):
        with open(out) as f:
            existing = json.load(f)
    keyed = {(r["variant"], r["mesh"]): r for r in existing}
    for r in recs:
        keyed[(r["variant"], r["mesh"])] = r
    with open(out, "w") as f:
        json.dump(list(keyed.values()), f, indent=1)


if __name__ == "__main__":
    main()
