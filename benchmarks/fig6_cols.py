"""Paper Fig. 6 — mRMR scalability across the number of COLUMNS.

Paper setting: conventional encoding, 1M rows, columns 100→1000, select 10,
10 nodes.  Paper claim: SUPERLINEAR relative execution time in the number of
columns (each extra column adds both relevance and redundancy passes).
"""

from __future__ import annotations

from benchmarks.common import SCALE, csv_row, relative, run_worker, save

POINTS = {
    "smoke": dict(rows=100_000, cols=[128, 256, 512, 1024], select=10,
                  devices=8, repeats=3),
    "full": dict(rows=1_000_000, cols=[100, 400, 700, 1000], select=10,
                 devices=8, repeats=3),
}


def main() -> dict:
    p = POINTS[SCALE]
    out = {"figure": "fig6_cols", "scale": SCALE, "points": []}
    for variant, inc in (("paper-faithful", 0), ("incremental", 1)):
        for cols in p["cols"]:
            rec = run_worker(
                devices=p["devices"], rows=p["rows"], cols=cols,
                select=p["select"], encoding="conventional",
                incremental=inc, repeats=p["repeats"],
            )
            rec["variant"] = variant
            out["points"].append(rec)
            csv_row(
                f"fig6/{variant}/cols={cols}",
                rec["mean_s"] * 1e6,
                f"hits={rec['relevant_hits']}/9",
            )
    for variant in ("paper-faithful", "incremental"):
        pts = [q for q in out["points"] if q["variant"] == variant]
        rel_t = relative([q["mean_s"] for q in pts])
        rel_c = relative([float(q["cols"]) for q in pts])
        out[f"relative_et_{variant}"] = rel_t
        out["relative_cols"] = rel_c
        print(f"fig6 {variant}: rel cols {rel_c} -> rel ET "
              f"{[round(t, 2) for t in rel_t]} (paper: superlinear)")
    save("fig6_cols", out)
    return out


if __name__ == "__main__":
    main()
