"""Paper Fig. 5 — mRMR scalability across the number of ROWS.

Paper setting: conventional encoding, 1 000 columns, rows 1M→10M, select 10
features, 10 nodes.  Paper claim: execution time is LINEAR in the number of
rows ("as expected by MapReduce design").

CPU adaptation (single-core container): rows are scaled down (the claim is
about the *slope*, which is size-independent for a fixed per-pass cost
model); the cluster is 8 forced host devices in a subprocess.  Both the
paper-faithful recompute and the beyond-paper incremental variant run.
"""

from __future__ import annotations

from benchmarks.common import SCALE, csv_row, relative, run_worker, save

POINTS = {
    "smoke": dict(rows=[20_000, 40_000, 80_000, 160_000], cols=500,
                  select=10, devices=8, repeats=3),
    "full": dict(rows=[125_000, 500_000, 875_000, 1_250_000], cols=1000,
                 select=10, devices=8, repeats=3),
}


def main() -> dict:
    p = POINTS[SCALE]
    out = {"figure": "fig5_rows", "scale": SCALE, "points": []}
    for variant, inc in (("paper-faithful", 0), ("incremental", 1)):
        for rows in p["rows"]:
            rec = run_worker(
                devices=p["devices"], rows=rows, cols=p["cols"],
                select=p["select"], encoding="conventional",
                incremental=inc, repeats=p["repeats"],
            )
            rec["variant"] = variant
            out["points"].append(rec)
            csv_row(
                f"fig5/{variant}/rows={rows}",
                rec["mean_s"] * 1e6,
                f"hits={rec['relevant_hits']}/9",
            )
    # linearity check (paper claim): relative ET vs relative rows
    for variant in ("paper-faithful", "incremental"):
        pts = [q for q in out["points"] if q["variant"] == variant]
        rel_t = relative([q["mean_s"] for q in pts])
        rel_r = relative([float(q["rows"]) for q in pts])
        out[f"relative_et_{variant}"] = rel_t
        out[f"relative_rows"] = rel_r
        print(f"fig5 {variant}: rel rows {rel_r} -> rel ET "
              f"{[round(t, 2) for t in rel_t]} (paper: linear)")
    save("fig5_rows", out)
    return out


if __name__ == "__main__":
    main()
