"""Benchmark worker: one mRMR job in a fresh process.

Run as a subprocess so the forced host-device count (the simulated cluster
size — the paper's "number of nodes") is set before jax initialises::

    PYTHONPATH=src REPRO_DEVICES=8 python -m benchmarks.worker \
        --rows 100000 --cols 1000 --select 10 --encoding conventional

Prints exactly one JSON dict on the last stdout line.
"""

import os

_DEVICES = int(os.environ.get("REPRO_DEVICES", "1"))
if _DEVICES > 1:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_DEVICES} "
        + os.environ.get("XLA_FLAGS", "")
    )

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo_analysis import analyze_hlo
from repro.core.mrmr import make_alternative_fn, make_conventional_fn
from repro.core.scores import MIScore, PearsonMIScore
from repro.data.synthetic import corral_dataset_np
from repro.dist.meshes import make_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, required=True, help="observations M")
    ap.add_argument("--cols", type=int, required=True, help="features N")
    ap.add_argument("--select", type=int, default=10)
    ap.add_argument("--encoding", default="conventional",
                    choices=["conventional", "alternative"])
    ap.add_argument("--score", default="mi", choices=["mi", "pearson"])
    ap.add_argument("--incremental", type=int, default=0,
                    help="0 = paper-faithful recompute, 1 = running-sum")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--analyze", type=int, default=0,
                    help="1 = also lower+compile and parse collective bytes")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    X_np, y_np = corral_dataset_np(args.rows, args.cols, seed=args.seed)

    if args.encoding == "conventional":
        mesh = make_mesh((n_dev,), ("data",)) if n_dev > 1 else None
        # pad rows to the device count (out-of-range value 2 -> zero one-hot)
        pad = (-args.rows) % n_dev
        if pad:
            X_np = np.concatenate([X_np, np.full((pad, args.cols), 2, np.int8)])
            y_np = np.concatenate([y_np, np.full((pad,), 2, np.int8)])
        score = MIScore(num_values=2, num_classes=2)
        fn = make_conventional_fn(
            args.select, score, mesh=mesh, obs_axes=("data",),
            incremental=bool(args.incremental),
        )
        if mesh is not None:
            X = jax.device_put(X_np, NamedSharding(mesh, P("data", None)))
            y = jax.device_put(y_np, NamedSharding(mesh, P("data")))
        else:
            X, y = jnp.asarray(X_np), jnp.asarray(y_np)
    else:
        # alternative encoding stores features as rows: (N, M)
        Xr_np = np.ascontiguousarray(X_np.T)
        mesh = make_mesh((n_dev,), ("model",)) if n_dev > 1 else None
        pad = (-args.cols) % n_dev
        if pad:
            Xr_np = np.concatenate(
                [Xr_np, np.zeros((pad, args.rows), np.int8)]
            )
        if args.score == "mi":
            score = MIScore(num_values=2, num_classes=2)
            Xr_np = Xr_np.astype(np.int8)
        else:
            score = PearsonMIScore()
            Xr_np = Xr_np.astype(np.float32)
        fn = make_alternative_fn(
            args.select, score, args.cols, mesh=mesh, feat_axes=("model",),
            incremental=bool(args.incremental),
        )
        if mesh is not None:
            X = jax.device_put(Xr_np, NamedSharding(mesh, P("model", None)))
            y = jax.device_put(
                y_np.astype(Xr_np.dtype), NamedSharding(mesh, P())
            )
        else:
            X, y = jnp.asarray(Xr_np), jnp.asarray(y_np.astype(Xr_np.dtype))

    rec = dict(vars(args), devices=n_dev)

    if args.analyze:
        lowered = fn.lower(X, y)
        t0 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.perf_counter() - t0, 2)
        hc = analyze_hlo(compiled.as_text())
        rec["hlo"] = {
            "flops_per_device": hc["flops"],
            "bytes_per_device": hc["bytes"],
            "collective_operand_bytes": hc["collectives"]["operand_bytes"],
            "collective_wire_bytes": hc["collectives"]["wire_bytes"],
            "by_type": {
                k: v["operand_bytes"]
                for k, v in hc["collectives"]["by_type"].items()
            },
        }

    # warmup (compile + first run)
    t0 = time.perf_counter()
    sel, gains, _rel = fn(X, y)
    sel.block_until_ready()
    rec["warmup_s"] = round(time.perf_counter() - t0, 3)

    times = []
    for _ in range(args.repeats):
        t0 = time.perf_counter()
        sel, gains, _rel = fn(X, y)
        sel.block_until_ready()
        times.append(time.perf_counter() - t0)
    sel_np = np.asarray(sel).tolist()
    rec.update(
        times_s=[round(t, 4) for t in times],
        best_s=round(min(times), 4),
        mean_s=round(float(np.mean(times)), 4),
        selected=sel_np,
        gains=[round(float(g), 4) for g in np.asarray(gains)],
        # dataset ground truth: 8 relevant cols (0..7) + correlated col 8
        relevant_hits=len(set(sel_np) & set(range(9))),
    )
    print(json.dumps(rec))


if __name__ == "__main__":
    main()
