"""Regenerate the data-driven sections of EXPERIMENTS.md.

Reads ``results/dryrun/{single,multi}/*.json`` (LM cells),
``results/dryrun/mrmr_cells.json`` (paper cells) and ``results/bench/*.json``
(paper figures) and rewrites the blocks between
``<!-- AUTOGEN:<name> -->`` / ``<!-- /AUTOGEN:<name> -->`` markers.

    PYTHONPATH=src:. python -m benchmarks.report
"""

from __future__ import annotations

import glob
import json
import os
import re

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DOC = os.path.join(REPO, "EXPERIMENTS.md")


def _load_cells(mesh: str) -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(REPO, "results/dryrun", mesh, "*.json"))):
        if "__" in os.path.basename(f).replace(".json", "").split("__")[-1]:
            pass
        with open(f) as fh:
            r = json.load(fh)
        if "overrides" in r or r.get("mesh") != mesh:
            continue  # hillclimb variants are reported in §Perf, not here
        base = os.path.basename(f)[:-5]
        if base.count("__") != 1:
            continue  # tagged variant file
        out.append(r)
    return out


def _fmt(x: float) -> str:
    return f"{x:.3e}"


def dryrun_table(mesh: str) -> str:
    rows = [
        "| arch | shape | step | HBM/dev | flops/dev | bytes/dev | coll bytes/dev | compile |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in _load_cells(mesh):
        if r["status"] == "skipped":
            rows.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | — | SKIP (sub-quadratic-only cell) |"
            )
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | |")
            continue
        mem = r.get("memory", {}).get("total_hbm_bytes", 0)
        rows.append(
            "| {a} | {s} | {k} | {m:.2f} GiB | {f} | {b} | {c} | {t:.0f}s |".format(
                a=r["arch"], s=r["shape"], k=r["step_kind"],
                m=mem / 2**30,
                f=_fmt(r["cost"]["flops"]), b=_fmt(r["cost"]["bytes"]),
                c=_fmt(r["collectives"]["operand_bytes"]),
                t=r["compile_s"],
            )
        )
    return "\n".join(rows)


def roofline_table() -> str:
    rows = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | model TFLOP | useful | MFU-bound |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in _load_cells("single"):
        if r["status"] != "ok":
            continue
        ro = r["roofline"]
        rows.append(
            "| {a} | {s} | {c} | {m} | {co} | {d} | {mf:.1f} | {u:.2f} | {b:.3f} |".format(
                a=r["arch"], s=r["shape"],
                c=_fmt(ro["compute_s"]), m=_fmt(ro["memory_s"]),
                co=_fmt(ro["collective_s"]), d=ro["dominant"][:-2],
                mf=ro["model_flops"] / 1e12, u=ro["useful_flops_ratio"],
                b=ro["roofline_mfu_bound"],
            )
        )
    return "\n".join(rows)


def mrmr_table() -> str:
    path = os.path.join(REPO, "results/dryrun/mrmr_cells.json")
    if not os.path.exists(path):
        return "(run benchmarks/mrmr_dryrun.py)"
    with open(path) as f:
        recs = json.load(f)
    rows = [
        "| variant | mesh | compute_s | memory_s | collective_s | dominant | useful |",
        "|---|---|---|---|---|---|---|",
    ]
    order = {"paper": 0, "incremental": 1, "bf16onehot": 2}
    for r in sorted(recs, key=lambda r: (r["mesh"], order.get(r["variant"], 9))):
        ro = r["roofline"]
        rows.append(
            "| {v} | {m} | {c} | {me} | {co} | {d} | {u:.2f} |".format(
                v=r["variant"], m=r["mesh"], c=_fmt(ro["compute_s"]),
                me=_fmt(ro["memory_s"]), co=_fmt(ro["collective_s"]),
                d=ro["dominant"][:-2], u=ro["useful_flops_ratio"],
            )
        )
    return "\n".join(rows)


def bench_tables() -> str:
    out = []
    for name in ("fig5_rows", "fig6_cols", "fig7_selected", "fig8_nodes",
                 "fig9_encodings", "kernels"):
        path = os.path.join(REPO, "results/bench", f"{name}.json")
        if not os.path.exists(path):
            continue
        with open(path) as f:
            r = json.load(f)
        out.append(f"**{name}** (scale={r.get('scale')})")
        out.append("")
        pts = r.get("points", [])
        if name == "fig8_nodes":
            out.append("| nodes | mean_s | wall gain | structural gain (flops/dev) | coll bytes/dev |")
            out.append("|---|---|---|---|---|")
            for i, p in enumerate(pts):
                out.append(
                    f"| {p['devices']} | {p['mean_s']:.3f} | {r['wall_gain'][i]} | "
                    f"{r['structural_gain_flops'][i]} | {p['hlo']['collective_operand_bytes']:.2e} |"
                )
        elif name == "kernels":
            out.append("| kernel | mean_s | throughput |")
            out.append("|---|---|---|")
            for p in pts:
                thr = f"{p.get('flops_per_s', 0)/1e9:.1f} GFLOP/s" if p.get("flops_per_s") else ""
                out.append(f"| {p['name']} | {p['s']:.4f} | {thr} |")
        else:
            key = {"fig5_rows": "rows", "fig6_cols": "cols",
                   "fig7_selected": "select", "fig9_encodings": "variant"}[name]
            out.append(f"| {key} | variant | mean_s | relevant hits |")
            out.append("|---|---|---|---|")
            for p in pts:
                out.append(
                    f"| {p.get(key)} | {p.get('variant', p.get('encoding'))} | "
                    f"{p['mean_s']:.3f} | {p['relevant_hits']} |"
                )
        for k in ("relative_et_paper-faithful", "relative_et_incremental",
                  "conventional_over_alternative"):
            if k in r:
                v = r[k]
                v = [round(x, 2) for x in v] if isinstance(v, list) else v
                out.append(f"- {k}: {v}")
        out.append("")
    return "\n".join(out)


def inject(doc: str, name: str, content: str) -> str:
    pat = re.compile(
        rf"(<!-- AUTOGEN:{name} -->)(.*?)(<!-- /AUTOGEN:{name} -->)", re.S
    )
    if not pat.search(doc):
        raise SystemExit(f"marker AUTOGEN:{name} missing in EXPERIMENTS.md")
    return pat.sub(lambda m: f"{m.group(1)}\n{content}\n{m.group(3)}", doc)


def main() -> None:
    with open(DOC) as f:
        doc = f.read()
    doc = inject(doc, "dryrun_single", dryrun_table("single"))
    doc = inject(doc, "dryrun_multi", dryrun_table("multi"))
    doc = inject(doc, "roofline", roofline_table())
    doc = inject(doc, "mrmr_cells", mrmr_table())
    doc = inject(doc, "bench", bench_tables())
    with open(DOC, "w") as f:
        f.write(doc)
    print("EXPERIMENTS.md regenerated")


if __name__ == "__main__":
    main()
