"""Shared benchmark plumbing: subprocess workers, result IO, tables."""

from __future__ import annotations

import json
import os
import subprocess
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
RESULTS = os.path.join(REPO, "results", "bench")

SCALE = os.environ.get("REPRO_BENCH_SCALE", "smoke")  # smoke | full


def run_worker(devices: int = 1, timeout: int = 3600, **kwargs) -> dict:
    """Run one benchmarks.worker job in a fresh process; return its JSON."""
    cmd = [sys.executable, "-m", "benchmarks.worker"]
    for k, v in kwargs.items():
        cmd += [f"--{k}", str(v)]
    env = dict(
        os.environ,
        REPRO_DEVICES=str(devices),
        PYTHONPATH=os.path.join(REPO, "src") + ":" + REPO,
    )
    out = subprocess.run(
        cmd, capture_output=True, text=True, env=env, cwd=REPO,
        timeout=timeout,
    )
    if out.returncode != 0:
        raise RuntimeError(
            f"worker failed ({' '.join(cmd)}):\n{out.stderr[-4000:]}"
        )
    return json.loads(out.stdout.strip().splitlines()[-1])


def save(name: str, payload) -> str:
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def load(name: str):
    path = os.path.join(RESULTS, f"{name}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def csv_row(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def relative(values: list[float]) -> list[float]:
    base = values[0] if values and values[0] else 1.0
    return [v / base for v in values]
