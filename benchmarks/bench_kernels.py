"""Micro-benchmarks of the paper's scoring hot loop (kernel substrate).

Times the jnp/MXU formulations that back the Pallas kernels (the Pallas
bodies themselves only run in interpret mode on CPU — their timing is
meaningless here; correctness is covered by tests/test_kernels.py):

* batched contingency tables (one-hot matmul)  — conventional-encoding pass
* fused Pearson correlation                    — alternative-encoding pass
* MI from stacked tables                       — reducer payload
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SCALE, csv_row, save
from repro.core.contingency import batched_counts
from repro.core.scores import mi_from_counts, pearson_rows

SIZES = {
    "smoke": dict(M=100_000, F=512, T=16),
    "full": dict(M=1_000_000, F=1024, T=16),
}


def _time(fn, *args, repeats=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.mean(ts))


def main() -> dict:
    p = SIZES[SCALE]
    M, F, T = p["M"], p["F"], p["T"]
    key = jax.random.PRNGKey(0)
    X = jax.random.randint(key, (M, F), 0, 2, jnp.int8)
    y = jax.random.randint(key, (M,), 0, 2, jnp.int8)
    Xr = jax.random.normal(key, (F, M // 16), jnp.float32)
    Yr = jax.random.normal(key, (T, M // 16), jnp.float32)

    out = {"figure": "kernels", "scale": SCALE, "points": []}

    f1 = jax.jit(lambda a, b: batched_counts(a, b, 2, 2))
    t = _time(f1, X, y)
    eff = M * F * 4 * 2 / t  # one-hot matmul MACs*2
    out["points"].append({"name": "contingency", "s": t, "flops_per_s": eff})
    csv_row("kernel/contingency", t * 1e6, f"{eff/1e9:.1f}GFLOP/s")

    counts = f1(X, y)
    f2 = jax.jit(mi_from_counts)
    t = _time(f2, counts)
    out["points"].append({"name": "mi_from_counts", "s": t})
    csv_row("kernel/mi_from_counts", t * 1e6, f"F={F}")

    f3 = jax.jit(pearson_rows)
    t = _time(f3, Xr, Yr)
    eff = F * T * (M // 16) * 2 / t
    out["points"].append({"name": "pearson", "s": t, "flops_per_s": eff})
    csv_row("kernel/pearson", t * 1e6, f"{eff/1e9:.1f}GFLOP/s")

    save("kernels", out)
    return out


if __name__ == "__main__":
    main()
