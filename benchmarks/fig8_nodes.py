"""Paper Fig. 8 — mRMR scalability across the number of NODES.

Paper setting: conventional encoding, 1M rows × 100 columns, select 10,
nodes ∈ {1, 2, 5, 10}.  Paper claim: SUBLINEAR computational gain
(ET_1node / ET_n) — communication grows with the node count.

CPU adaptation: "nodes" are forced host devices in fresh subprocesses.  The
container has ONE physical core, so measured wall time cannot show real
speedup (all simulated devices timeshare the core) — wall time is reported
for honesty, but the *scaling evidence* is structural, from the compiled
HLO of the very job we time: per-device FLOPs must fall as 1/n while
all-reduce (the MapReduce shuffle's replacement) bytes grow with n — the
exact mechanism behind the paper's sublinear curve.
"""

from __future__ import annotations

from benchmarks.common import SCALE, csv_row, run_worker, save

POINTS = {
    "smoke": dict(rows=200_000, cols=128, select=10,
                  devices=[1, 2, 4, 8], repeats=3),
    "full": dict(rows=1_000_000, cols=100, select=10,
                 devices=[1, 2, 5, 10], repeats=3),
}


def main() -> dict:
    p = POINTS[SCALE]
    out = {"figure": "fig8_nodes", "scale": SCALE, "points": []}
    for n in p["devices"]:
        rec = run_worker(
            devices=n, rows=p["rows"], cols=p["cols"], select=p["select"],
            encoding="conventional", incremental=0, repeats=p["repeats"],
            analyze=1,
        )
        out["points"].append(rec)
        h = rec["hlo"]
        csv_row(
            f"fig8/nodes={n}",
            rec["mean_s"] * 1e6,
            f"flops/dev={h['flops_per_device']:.3e};"
            f"allreduce_bytes={h['collective_operand_bytes']:.3e}",
        )
    base = out["points"][0]
    gain = [base["mean_s"] / q["mean_s"] for q in out["points"]]
    fl = [q["hlo"]["flops_per_device"] for q in out["points"]]
    struct_gain = [fl[0] / f if f else 0.0 for f in fl]
    cb = [q["hlo"]["collective_operand_bytes"] for q in out["points"]]
    out["wall_gain"] = [round(g, 2) for g in gain]
    out["structural_gain_flops"] = [round(g, 2) for g in struct_gain]
    out["collective_bytes"] = cb
    print(f"fig8 nodes={p['devices']}")
    print(f"  wall gain (1 physical core!)    {out['wall_gain']}")
    print(f"  structural gain (flops/device)  {out['structural_gain_flops']}"
          f" (paper: sublinear in nodes)")
    print(f"  collective bytes/device         {[f'{b:.2e}' for b in cb]}"
          f" (grows with nodes -> sublinearity)")
    save("fig8_nodes", out)
    return out


if __name__ == "__main__":
    main()
