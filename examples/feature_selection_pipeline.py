"""mRMR as a first-class data-pipeline stage in front of model training.

    PYTHONPATH=src python examples/feature_selection_pipeline.py

The paper's motivating workflow: a wide dataset (more features than
observations) is reduced with distributed mRMR, then a downstream model is
trained on the selected columns.  We train the same logistic-regression
head (in JAX, AdamW) on (a) all features, (b) mRMR-selected, (c) randomly
selected — showing mRMR keeps accuracy at a fraction of the width.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import MRMRSelector, PearsonMIScore
from repro.data.synthetic import continuous_wide_dataset
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

N_OBS, N_FEAT, K = 2_000, 8_192, 16


def train_head(Xtr, ytr, Xte, yte, steps=300, lr=0.05):
    key = jax.random.PRNGKey(0)
    w = {
        "w": jax.random.normal(key, (Xtr.shape[1],)) * 0.01,
        "b": jnp.zeros(()),
    }
    cfg = AdamWConfig(learning_rate=lr, weight_decay=1e-4)
    opt = adamw_init(w, cfg)

    def loss_fn(w, X, y):
        z = X @ w["w"] + w["b"]
        return jnp.mean(jnp.logaddexp(0.0, z) - y * z)

    @jax.jit
    def step(w, opt, X, y):
        g = jax.grad(loss_fn)(w, X, y)
        w, opt, _ = adamw_update(g, opt, w, cfg)
        return w, opt

    for _ in range(steps):
        w, opt = step(w, opt, Xtr, ytr)
    acc = jnp.mean(((Xte @ w["w"] + w["b"]) > 0) == (yte > 0.5))
    return float(acc)


def main():
    X, y = continuous_wide_dataset(N_OBS, N_FEAT, seed=1)
    X, y = np.asarray(X), np.asarray(y, np.float32)
    ntr = int(0.8 * N_OBS)
    Xtr, Xte, ytr, yte = X[:ntr], X[ntr:], y[:ntr], y[ntr:]

    # feature selection sees only the training split (no leakage);
    # Pearson score -> the planner picks the feature-sharded encoding.
    fs = MRMRSelector(num_select=K, score=PearsonMIScore()).fit(Xtr, ytr)
    print(f"planned encoding: {fs.plan_.encoding}")
    sel = np.asarray(fs.selected_)
    rng = np.random.default_rng(0)
    rand = rng.choice(N_FEAT, size=K, replace=False)

    acc_all = train_head(jnp.asarray(Xtr), jnp.asarray(ytr),
                         jnp.asarray(Xte), jnp.asarray(yte))
    acc_sel = train_head(jnp.asarray(Xtr[:, sel]), jnp.asarray(ytr),
                         jnp.asarray(Xte[:, sel]), jnp.asarray(yte))
    acc_rnd = train_head(jnp.asarray(Xtr[:, rand]), jnp.asarray(ytr),
                         jnp.asarray(Xte[:, rand]), jnp.asarray(yte))

    print(f"selected (mRMR/Pearson): {sorted(sel.tolist())}")
    print(f"test acc — all {N_FEAT} features: {acc_all:.3f}")
    print(f"test acc — {K} mRMR features:     {acc_sel:.3f}")
    print(f"test acc — {K} random features:   {acc_rnd:.3f}")
    assert acc_sel > acc_rnd + 0.05, "mRMR should beat random selection"


if __name__ == "__main__":
    main()
