"""Custom feature-score functions (paper §IV.D, Listings 7-8).

The paper's alternative encoding exposes ``getResult(variableArray,
classArray, selectedVariablesArray) -> Double``.  Our JAX equivalent is a
``CustomScore`` whose ``get_result(v, cls, selected, n_selected)`` is traced
and vectorised over the feature shard — the same contract, but compiled.
Custom scores go through the same ``MRMRSelector`` front door as everything
else: the planner routes them to the feature-sharded (map-only) encoding
automatically, and the selector owns the layout transposition.

Two scores are shown:
  1. the paper's own example — Pearson-correlation MI approximation
     (Listing 8: f = -0.5*log(1-rho^2));
  2. a user-defined score the paper never shipped — an ANOVA-F-style
     signal-to-noise ratio, demonstrating that anything expressible in jnp
     drops in.
"""

import jax.numpy as jnp
import numpy as np

from repro import CustomScore, MRMRSelector, PearsonMIScore
from repro.core.scores import cor2mi
from repro.data.synthetic import continuous_wide_dataset


# --- 1. paper Listing 8, literally -----------------------------------------
def listing8_get_result(v, cls, selected, n_selected):
    """v (M,), cls (M,), selected (L, M); rows >= n_selected are zeros."""

    def pcc(a, b):
        a = a - a.mean()
        b = b - b.mean()
        return (a * b).sum() / jnp.sqrt((a * a).sum() * (b * b).sum() + 1e-12)

    sc = cor2mi(pcc(v, cls))
    live = jnp.arange(selected.shape[0]) < n_selected
    sfs = jnp.where(
        live, cor2mi(jnp.vectorize(pcc, signature="(m),(m)->()")(selected, v)), 0.0
    ).sum()
    coeff = jnp.where(n_selected > 0, 1.0 / jnp.maximum(n_selected, 1), 1.0)
    return sc - coeff * sfs


# --- 2. a user-defined score ------------------------------------------------
def anova_f_get_result(v, cls, selected, n_selected):
    """Relevance = between/within-class variance; redundancy = |rho|."""
    m1 = jnp.where(cls > 0.5, v, 0).sum() / jnp.maximum((cls > 0.5).sum(), 1)
    m0 = jnp.where(cls <= 0.5, v, 0).sum() / jnp.maximum((cls <= 0.5).sum(), 1)
    within = v.var() + 1e-6
    rel = (m1 - m0) ** 2 / within

    def absrho(a):
        a = a - a.mean()
        b = v - v.mean()
        return jnp.abs(
            (a * b).sum() / jnp.sqrt((a * a).sum() * (b * b).sum() + 1e-12)
        )

    live = jnp.arange(selected.shape[0]) < n_selected
    red = jnp.where(
        live, jnp.vectorize(absrho, signature="(m)->()")(selected), 0.0
    ).sum()
    return rel - red / jnp.maximum(n_selected, 1)


def main():
    X, y = continuous_wide_dataset(2_000, 4_096, seed=0)
    X = jnp.asarray(X)  # conventional orientation (obs × features)

    for name, score in [
        ("built-in PearsonMI", PearsonMIScore()),
        ("Listing 8 (custom)", CustomScore(get_result=listing8_get_result)),
        ("ANOVA-F (custom)", CustomScore(get_result=anova_f_get_result)),
    ]:
        fs = MRMRSelector(num_select=8, score=score).fit(X, y)
        sel = list(fs.selected_)
        print(f"{name:>20s}: selected {sel} "
              f"(encoding={fs.plan_.encoding})")
        print(f"{'':>20s}  signal cols (0-7) recovered: "
              f"{len(set(sel) & set(range(8)))}/8, "
              f"redundant shadow col 8 picked: {8 in sel}")


if __name__ == "__main__":
    main()
