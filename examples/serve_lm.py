"""Serve a small model with batched requests through ServeEngine.

    PYTHONPATH=src python examples/serve_lm.py

Requests with different prompt lengths are bucketed into waves; decode is a
jitted one-token step with the KV cache donated (steady-state decode
allocates nothing).  Works for every decoder-only family — swap --arch for
'mamba2-1.3b' to serve the SSM (state cache instead of KV).
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models.model import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    bundle = build_model(cfg, mesh=None)
    params = jax.jit(bundle.init)(jax.random.PRNGKey(0))
    engine = ServeEngine(bundle, params, temperature=args.temperature)

    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(0, cfg.vocab_size, size=n).tolist(),
                max_new_tokens=12)
        for n in (8, 8, 8, 16, 16, 24, 24, 24)
    ]
    t0 = time.time()
    outs = engine.serve(reqs)
    dt = time.time() - t0
    for i, (r, o) in enumerate(zip(reqs, outs)):
        print(f"req {i}: prompt_len={len(r.prompt):>2d} -> {o}")
    n_new = sum(len(o) for o in outs)
    print(f"{len(reqs)} requests / {n_new} new tokens in {dt:.2f}s "
          f"({n_new/dt:.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
