"""Quickstart: distributed mRMR feature selection in 30 lines.

    PYTHONPATH=src python examples/quickstart.py

Generates the paper's CorrAL-style dataset (Eq. 3: the class is a boolean
function of features 0..7, feature 8 is partially correlated, the rest is
noise), then runs mRMR through the ``MRMRSelector`` front door: once
auto-planned (the paper's §III aspect-ratio rule picks the encoding) and
once per explicit encoding, checking they recover the relevant features.
Also selects with the quotient-form criterion (``criterion="miq"``; from
the CLI: ``python -m repro.launch.select --criterion miq``) and the
class-conditioned pair (``"jmi"``/``"cmim"``) — the greedy objective is
pluggable, orthogonal to the encoding.
"""

import jax
import numpy as np

from repro import MRMRSelector
from repro.data.synthetic import corral_dataset

X, y = corral_dataset(20_000, 64, seed=0)
print(f"dataset: X{X.shape} y{y.shape}  devices: {jax.device_count()}")

fs = MRMRSelector(num_select=10).fit(X, y)
print(f"{'auto':>12s}: planned encoding = {fs.plan_.encoding!r}")

for encoding in ("conventional", "alternative"):
    fs = MRMRSelector(num_select=10, encoding=encoding).fit(X, y)
    sel = list(fs.selected_)
    hits = sorted(set(sel) & set(range(9)))
    print(f"{encoding:>12s}: selected {sel}")
    print(f"{'':>12s}  relevant recovered: {hits} ({len(hits)}/9)")

Xt = fs.transform(np.asarray(X))
print(f"transform: {np.asarray(X).shape} -> {Xt.shape}")

# Swap the greedy objective without touching anything else: MIQ divides
# relevance by mean redundancy instead of subtracting it.  The selector's
# read side reports what ran (result_) plus sklearn-style accessors.
fs = MRMRSelector(num_select=10, criterion="miq").fit(X, y)
print(f"{'miq':>12s}: selected {list(fs.selected_)} "
      f"(criterion={fs.result_.criterion!r}, engine={fs.result_.engine!r})")
print(f"{'':>12s}  support mask sum = {int(fs.get_support().sum())}, "
      f"top-relevance feature = {int(fs.scores_.argmax())}, "
      f"rank of feature 0 = {int(fs.ranking_[0])}")

# Class-conditioned criteria: JMI and CMIM fold the gap
# I(x;x_j|y) - I(x;x_j) (mean vs worst-case) — one fused 3-way count
# per pair feeds both terms, so they cost the same passes as mid.
for criterion in ("jmi", "cmim"):
    fs = MRMRSelector(num_select=10, criterion=criterion).fit(X, y)
    hits = sorted(set(fs.selected_.tolist()) & set(range(9)))
    print(f"{criterion:>12s}: selected {list(fs.selected_)} "
          f"(relevant recovered: {len(hits)}/9)")

# Out-of-core wide regime: a DataSource streams observation-blocks and a
# wide dataset (obs/feat <= 0.25) plans feature-sharded statistics — the
# per-pair statistics state splits across devices instead of replicating.
# ``prefetch`` double-buffers placement (host reads block i+1 while the
# device accumulates block i); selections match the in-memory engines.
from repro.data.sources import CorralSource

wide_src = CorralSource(512, 2048, seed=0)
fs = MRMRSelector(num_select=10, block_obs=128, prefetch=2).fit(wide_src)
plan = fs.plan_
print(f"{'streaming':>12s}: encoding={plan.encoding!r} "
      f"obs_axes={plan.obs_axes} feat_axes={plan.feat_axes} "
      f"block_obs={plan.block_obs} prefetch={plan.prefetch}")
print(f"{'':>12s}  selected {list(fs.selected_)}")

# Continuous features, exact discrete MI: bins= cuts equal-frequency bin
# edges from ONE streaming quantile-sketch pass, then every block encodes
# to int codes on the fly (device-side, fused with the contingency sums).
# The float dataset below would otherwise be refused by the MI path; with
# bins= it fits on both the in-memory and streaming engines, and the
# selections agree at every block size because the sketch (and hence the
# edges) is a pure function of the row stream.
rng = np.random.default_rng(0)
yf = rng.integers(0, 2, size=5_000)
Xf = rng.normal(size=(5_000, 32))
Xf[:, :4] += yf[:, None] * np.array([1.6, 1.2, 0.8, 0.5])  # informative

fs_mem = MRMRSelector(num_select=4, bins=16).fit(Xf, yf)
from repro.data.sources import ArraySource

fs_str = MRMRSelector(num_select=4, bins=16, block_obs=512).fit(
    ArraySource(Xf, yf)
)
print(f"{'binned':>12s}: in-memory {list(fs_mem.selected_)} == "
      f"streaming {list(fs_str.selected_)} (bins={fs_str.plan_.bins})")

# Cutting the L-pass I/O tax: a streamed fit costs 1 relevance pass plus
# num_select-1 redundancy passes over the source.  Three composable knobs
# attack that, with selections bitwise-identical to the plain engine:
#   batch_candidates=q  speculates the top-q candidates' redundancy
#                       vectors per pass -> ~ceil((L-1)/q) redundancy
#                       passes (select=32 at q=8: 31 passes -> 5);
#   spill_dir=          spills each parsed/encoded block on pass 1 and
#                       replays memmapped chunks on passes 2..L (CSV
#                       parse + bin encode paid once per dataset);
#   readahead=          streams the next pass's first blocks while the
#                       device drains the current pass's tail.
# The result reports the measured ledger (result_.io), so the pass math
# is observable, not guessed.
import tempfile

with tempfile.TemporaryDirectory() as spill:
    tall_src = CorralSource(50_000, 64, seed=0)
    plain = MRMRSelector(num_select=10, block_obs=8192).fit(tall_src)
    fast = MRMRSelector(
        num_select=10, block_obs=8192, batch_candidates=8,
        spill_dir=spill, readahead=2,
    ).fit(tall_src)
    assert list(plain.selected_) == list(fast.selected_)
    print(f"{'io tax':>12s}: plain passes={plain.result_.io['passes']} "
          f"vs batched+spill+readahead={fast.result_.io['passes']} "
          f"(cache: {fast.result_.io['cache']})")

# Selection-as-a-service: fits run as managed jobs behind a bounded work
# queue, with a content-addressed result cache (source fingerprint x
# score x criterion x num_select) and idempotency-key coalescing — the
# identical resubmission below is a cache hit with zero engine or I/O
# passes, and a stampede of identical concurrent submits runs once.
# (CLI: python -m repro.launch.serve_select --repeat 2 --distinct-select 3)
from repro.serve import SelectionService

with SelectionService(workers=2) as svc:
    job = svc.submit(CorralSource(20_000, 64, seed=0), num_select=10)
    result = svc.result(job)  # blocks until DONE; raises on FAILED
    again = svc.submit(CorralSource(20_000, 64, seed=0), num_select=10)
    info = svc.poll(again)
    print(f"{'service':>12s}: selected {[int(v) for v in result.selected]}")
    print(f"{'':>12s}  resubmission cache_hit={info.cache_hit} "
          f"cache={svc.stats()['cache']}")

# Multi-host map-reduce: the same streaming fit across N jax.distributed
# processes, each reading ONLY its shard of the data (§III applied to
# hosts: tall -> row ranges, wide -> column ranges, both-large -> a 2-D
# host grid).  The per-pass reduce is an explicit psum of exact integer
# statistics, so every host commits the identical selection — asserted
# below against the single-process fit.  In a worker you would call
# init_multihost() then MRMRSelector(..., hosts="auto"); here we drive
# the spawn-mode launcher, which stands up a loopback 2-process cluster.
# (Real cluster: one invocation per machine with --coordinator/--process-id.)
import json
import subprocess
import sys

proc = subprocess.run(
    [sys.executable, "-m", "repro.launch.select_multihost",
     "--num-processes", "2", "--rows", "6000", "--cols", "24",
     "--select", "4", "--block-obs", "1500"],
    capture_output=True, text=True, check=True,
)
mh = json.loads(proc.stdout.splitlines()[-1])
agg = mh["hosts"]["aggregate"]["bytes_read"]
shares = [round(h["bytes_read"] / agg, 2) for h in mh["hosts"]["per_host"]]
print(f"{'multihost':>12s}: grid={mh['hosts']['grid']} "
      f"selected {mh['selected']}")
print(f"{'':>12s}  per-host share of bytes read: {shares}")
