"""End-to-end driver: train a ~100M-parameter LM with the full substrate.

    PYTHONPATH=src python examples/train_lm.py            # quick (20 steps)
    PYTHONPATH=src python examples/train_lm.py --steps 300 --fail-at-step 150

The config is a qwen-family dense model sized to ~100M params.  Everything
is the production path: scan/remat stack, AdamW, deterministic resumable
pipeline, async checkpoints every 20 steps, watchdog, crash-restart driver.
``--fail-at-step`` demonstrates fault tolerance: the run crashes once, the
driver restores the latest checkpoint, and training completes.
"""

import argparse
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--fail-at-step", type=int, default=-1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    argv = [
        "--arch", "qwen1.5-0.5b", "--preset", "full",
        # surgery down to ~100M params (d=768, 12 layers, vocab 32k)
        "--num-layers", "12", "--d-model", "768", "--num-heads", "12",
        "--num-kv-heads", "12", "--d-ff", "2048", "--vocab-size", "32000",
        "--steps", str(args.steps), "--global-batch", "4",
        "--seq-len", "256", "--ckpt-every", "20",
        "--ckpt-dir", args.ckpt_dir, "--fail-at-step", str(args.fail_at_step),
        "--log-every", "5",
    ]
    metrics = train_mod.main(argv)
    print(f"final: {metrics}")
    return metrics


if __name__ == "__main__":
    sys.exit(0 if main() else 0)
