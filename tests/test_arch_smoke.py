"""Per-architecture smoke tests: reduced same-family configs, one forward /
train-loss / decode step on CPU, asserting output shapes and finiteness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, list_archs, smoke_config
from repro.configs.base import ShapeConfig
from repro.models.model import build_model

ARCHS = list_archs()
SMOKE_TRAIN = ShapeConfig("smoke_train", seq_len=64, global_batch=2, kind="train")
SMOKE_DECODE = ShapeConfig("smoke_decode", seq_len=64, global_batch=2, kind="decode")


def _dummy_batch(bundle, shape):
    cfg = bundle.cfg
    key = jax.random.PRNGKey(0)
    specs = bundle.input_specs(shape)

    def realise(sds):
        if jnp.issubdtype(sds.dtype, jnp.integer):
            hi = cfg.vocab_size if sds.shape else shape.seq_len - 1
            return jax.random.randint(key, sds.shape, 0, hi, sds.dtype)
        return jax.random.normal(key, sds.shape, sds.dtype) * 0.02

    batch = jax.tree.map(realise, specs)
    if "pos" in batch:
        batch["pos"] = jnp.asarray(shape.seq_len - 1, jnp.int32)
    if "positions" in batch and batch["positions"].ndim == 3:
        pos = jnp.arange(shape.seq_len, dtype=jnp.int32)
        batch["positions"] = jnp.broadcast_to(
            pos[None, :, None], (shape.global_batch, shape.seq_len, 3)
        )
    return batch


@pytest.fixture(scope="module")
def bundles():
    out = {}
    for arch in ARCHS:
        cfg = smoke_config(arch)
        bundle = build_model(cfg, mesh=None)
        params = bundle.init(jax.random.PRNGKey(1))
        out[arch] = (bundle, params)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_train_loss_finite(bundles, arch):
    bundle, params = bundles[arch]
    batch = _dummy_batch(bundle, SMOKE_TRAIN)
    loss, metrics = jax.jit(bundle.train_loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert float(metrics["loss"]) > 0  # CE against random targets


@pytest.mark.parametrize("arch", ARCHS)
def test_train_grads_finite(bundles, arch):
    bundle, params = bundles[arch]
    batch = _dummy_batch(bundle, SMOKE_TRAIN)
    grads = jax.jit(
        jax.grad(lambda p, b: bundle.train_loss(p, b)[0])
    )(params, batch)
    flat = jax.tree.leaves(grads)
    assert flat, arch
    assert all(np.all(np.isfinite(np.asarray(g))) for g in flat), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(bundles, arch):
    bundle, params = bundles[arch]
    batch = _dummy_batch(bundle, SMOKE_DECODE)
    logits, caches = jax.jit(bundle.serve_step)(params, batch)
    v = bundle.cfg.vocab_size
    assert logits.shape == (SMOKE_DECODE.global_batch, 1, v)
    assert np.all(np.isfinite(np.asarray(logits))), arch
    # cache pytree preserved
    assert jax.tree.structure(caches) == jax.tree.structure(batch["caches"])


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill(bundles, arch):
    bundle, params = bundles[arch]
    shape = ShapeConfig("smoke_prefill", 64, 2, "prefill")
    batch = _dummy_batch(bundle, shape)
    logits, caches = jax.jit(bundle.prefill)(params, batch)
    assert logits.shape == (2, bundle.cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits))), arch
    assert caches  # decode caches emitted


def test_prefill_then_decode_consistency():
    """Prefill caches + one decode step == full forward at the next position
    (validates the cache plumbing end-to-end for a dense arch)."""
    cfg = smoke_config("yi-6b")
    bundle = build_model(cfg, mesh=None)
    params = bundle.init(jax.random.PRNGKey(3))
    b, s = 2, 16
    key = jax.random.PRNGKey(4)
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size, jnp.int32)

    # Full forward over s tokens: logits at position s-1 predict token s.
    full_logits, _ = bundle.prefill(params, {"tokens": tokens})

    # Prefill on the first s-1 tokens, then decode token s-1 at pos s-1.
    _, caches = bundle.prefill(params, {"tokens": tokens[:, : s - 1]})
    # Grow cache buffers to length s (prefill emitted s-1 slots).
    caches = jax.tree.map(
        lambda c: jnp.pad(c, [(0, 0)] * 2 + [(0, 1)] + [(0, 0)] * (c.ndim - 3))
        if c.ndim == 5
        else c,
        caches,
    )
    step_logits, _ = bundle.serve_step(
        params,
        {
            "tokens": tokens[:, s - 1 :],
            "pos": jnp.asarray(s - 1, jnp.int32),
            "caches": caches,
        },
    )
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0]), np.asarray(full_logits),
        rtol=2e-4, atol=2e-4,
    )


def test_param_counts_match_public_scale():
    """Full configs must land near their nominal parameter counts."""
    from repro.configs import get_config
    from repro.models.model import build_model as bm

    expect = {
        "qwen1.5-110b": (111e9, 0.10),
        "yi-6b": (6.1e9, 0.10),
        "minitron-4b": (4.2e9, 0.15),
        "qwen1.5-0.5b": (0.62e9, 0.15),
        "dbrx-132b": (132e9, 0.10),
        "mamba2-1.3b": (1.3e9, 0.05),
        "jamba-1.5-large-398b": (398e9, 0.10),
        "llama4-scout-17b-a16e": (109e9, 0.10),  # total (not active) params
        "qwen2-vl-2b": (1.54e9, 0.10),  # text backbone (vision tower stubbed)
        "whisper-tiny": (0.039e9, 0.20),
    }
    for name, (target, tol) in expect.items():
        n = bm(get_config(name), mesh=None).num_params()
        assert abs(n - target) / target < tol, (name, n, target)
