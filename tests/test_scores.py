"""Unit tests for contingency math and score functions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import contingency, scores


def np_pair_counts(x, y, vx, vy):
    out = np.zeros((vx, vy))
    for xi, yi in zip(np.asarray(x), np.asarray(y)):
        if 0 <= xi < vx and 0 <= yi < vy:
            out[xi, yi] += 1
    return out


def np_mi(counts):
    counts = np.asarray(counts, dtype=np.float64)
    total = counts.sum()
    p = counts / total
    px = p.sum(axis=1, keepdims=True)
    py = p.sum(axis=0, keepdims=True)
    with np.errstate(divide="ignore", invalid="ignore"):
        terms = p * np.log(p / (px * py))
    return np.nansum(np.where(p > 0, terms, 0.0))


class TestContingency:
    def test_pair_counts_matches_numpy(self):
        rng = np.random.default_rng(0)
        x = rng.integers(0, 4, 257)
        y = rng.integers(0, 3, 257)
        got = contingency.pair_counts(jnp.asarray(x), jnp.asarray(y), 4, 3)
        np.testing.assert_allclose(got, np_pair_counts(x, y, 4, 3))

    def test_paper_table_iv(self):
        # Paper Table IV: pair (x1=2 -> one-hot col for value 2, c=0) of the
        # first entry in Table III, categories dv={-2,0,2} -> {0,1,2}.
        x = jnp.asarray([2])  # value "2" encoded as category index 2
        c = jnp.asarray([0])
        table = contingency.pair_counts(x, c, 3, 2)
        expected = np.zeros((3, 2))
        expected[2, 0] = 1
        np.testing.assert_allclose(table, expected)

    def test_paper_table_v_combiner(self):
        # Paper Table V: element-wise sum over the four entries of Table III
        # for (x1, c). x1 = (2, 0, 0, -2) -> encoded (2, 1, 1, 0); c=(0,0,0,1).
        x = jnp.asarray([2, 1, 1, 0])
        c = jnp.asarray([0, 0, 0, 1])
        table = contingency.pair_counts(x, c, 3, 2)
        expected = np.array([[0, 1], [2, 0], [1, 0]])
        np.testing.assert_allclose(table, expected.astype(float))

    @pytest.mark.parametrize("block", [1, 3, 64, 128])
    def test_batched_counts_blocks(self, block):
        rng = np.random.default_rng(1)
        X = rng.integers(0, 5, (100, 17))
        y = rng.integers(0, 2, 100)
        got = contingency.batched_counts(
            jnp.asarray(X), jnp.asarray(y), 5, 2, block=block
        )
        for f in range(17):
            np.testing.assert_allclose(got[f], np_pair_counts(X[:, f], y, 5, 2))

    def test_out_of_range_rows_ignored(self):
        # Padded rows carry out-of-range values -> zero contribution.
        X = jnp.asarray([[0], [1], [2**31 - 1]])
        y = jnp.asarray([0, 1, 2**31 - 1])
        got = contingency.batched_counts(X, y, 2, 2)
        np.testing.assert_allclose(got[0], np.array([[1, 0], [0, 1]]))


class TestMI:
    def test_known_value_independent(self):
        counts = jnp.full((4, 4), 25.0)
        assert abs(float(scores.mi_from_counts(counts))) < 1e-6

    def test_known_value_identical(self):
        # x == y uniform over k values: MI = log(k).
        counts = jnp.eye(5) * 20
        np.testing.assert_allclose(
            float(scores.mi_from_counts(counts)), np.log(5), rtol=1e-5
        )

    def test_matches_numpy_random(self):
        rng = np.random.default_rng(2)
        counts = rng.integers(0, 50, (7, 3, 4)).astype(float)
        got = scores.mi_from_counts(jnp.asarray(counts))
        want = [np_mi(counts[i]) for i in range(7)]
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_symmetry(self):
        rng = np.random.default_rng(3)
        counts = rng.integers(0, 50, (5, 5)).astype(float)
        a = float(scores.mi_from_counts(jnp.asarray(counts)))
        b = float(scores.mi_from_counts(jnp.asarray(counts.T)))
        assert abs(a - b) < 1e-6

    def test_entropy(self):
        counts = jnp.asarray([10.0, 10.0, 10.0, 10.0])
        np.testing.assert_allclose(
            float(scores.entropy_from_counts(counts)), np.log(4), rtol=1e-6
        )


class TestPearson:
    def test_pearson_matches_numpy(self):
        rng = np.random.default_rng(4)
        X = rng.normal(size=(6, 200)).astype(np.float32)
        y = rng.normal(size=200).astype(np.float32)
        got = scores.pearson_rows(jnp.asarray(X), jnp.asarray(y))
        want = [np.corrcoef(X[i], y)[0, 1] for i in range(6)]
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_cor2mi_listing8(self):
        # Listing 8: cor2mi(v) = -0.5*log(1 - v^2)
        v = jnp.asarray([0.0, 0.5, 0.9])
        got = scores.cor2mi(v)
        want = -0.5 * np.log(1.0 - np.asarray(v) ** 2)
        np.testing.assert_allclose(got, want, rtol=1e-5)

    def test_constant_row_zero_corr(self):
        X = jnp.ones((1, 50))
        y = jnp.asarray(np.random.default_rng(5).normal(size=50), jnp.float32)
        got = scores.pearson_rows(X, y)
        np.testing.assert_allclose(got, [0.0], atol=1e-6)


class TestScoreObjects:
    def test_mi_score_relevance(self):
        rng = np.random.default_rng(6)
        X = rng.integers(0, 3, (9, 300))  # feature-major
        y = rng.integers(0, 2, 300)
        s = scores.MIScore(num_values=3, num_classes=2)
        rel = s.relevance(jnp.asarray(X), jnp.asarray(y))
        want = [np_mi(np_pair_counts(X[i], y, 3, 2)) for i in range(9)]
        np.testing.assert_allclose(rel, want, rtol=1e-4, atol=1e-6)

    def test_mi_use_pallas_validated_at_construction(self):
        for ok in (True, False, "auto"):
            assert scores.MIScore(use_pallas=ok).use_pallas == ok
        with pytest.raises(ValueError, match="use_pallas"):
            scores.MIScore(use_pallas="bogus")
        with pytest.raises(ValueError, match="use_pallas"):
            scores.MIScore(use_pallas=None)

    def test_mi_use_pallas_false_uses_jnp_path(self):
        # Explicit False must route through the blocked jnp oracle and
        # still agree with the default dispatch path.
        rng = np.random.default_rng(8)
        X = rng.integers(0, 2, (6, 200))
        y = rng.integers(0, 2, 200)
        a = scores.MIScore(2, 2, use_pallas=False).relevance(
            jnp.asarray(X), jnp.asarray(y)
        )
        b = scores.MIScore(2, 2).relevance(jnp.asarray(X), jnp.asarray(y))
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)

    def test_custom_score_requires_callable(self):
        with pytest.raises(TypeError):
            scores.CustomScore()  # missing argument fails at construction
        with pytest.raises(TypeError, match="callable"):
            scores.CustomScore(get_result=None)
        with pytest.raises(TypeError, match="callable"):
            scores.CustomScore(get_result=42)

    def test_streaming_support_flags(self):
        assert scores.MIScore().supports_streaming
        assert scores.PearsonMIScore().supports_streaming
        custom = scores.CustomScore(get_result=lambda v, c, s, n: 0.0)
        assert not custom.supports_streaming
        with pytest.raises(NotImplementedError, match="streaming"):
            custom.init_state(4)

    def test_custom_score_equals_builtin_mrmr(self):
        rng = np.random.default_rng(7)
        X = rng.integers(0, 2, (8, 120))
        y = rng.integers(0, 2, 120)
        s = scores.MIScore(num_values=2, num_classes=2)
        custom = scores.mrmr_custom_score(s)
        sel = jnp.asarray(X[:3], jnp.int32)
        g_custom = custom.full_score(
            jnp.asarray(X), jnp.asarray(y), sel, jnp.int32(3)
        )
        rel = s.relevance(jnp.asarray(X), jnp.asarray(y))
        red = sum(s.redundancy(jnp.asarray(X), sel[j]) for j in range(3)) / 3.0
        np.testing.assert_allclose(g_custom, rel - red, rtol=1e-5, atol=1e-6)
