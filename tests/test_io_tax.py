"""The L-pass I/O tax knobs: batched redundancy, the encoded-block spill
cache, cross-pass read-ahead and the prefetch-auto heuristic.

The acceptance bar for all three knobs is the same: selections bitwise-
identical to the plain streaming engine under every combination, with the
I/O savings ASSERTED from the engine's pass/bytes ledger, never eyeballed.
"""

import json
import os

import numpy as np
import jax
import pytest

from repro import MIScore, MRMRSelector, PearsonMIScore
from repro.core.mrmr import MRMRResult
from repro.core.streaming import mrmr_streaming
from repro.data.binning import BinnedSource
from repro.data.block_cache import BlockCacheSource
from repro.data.sources import ArraySource, CSVSource, CorralSource
from repro.dist import factor_mesh, make_mesh
from repro.dist.streaming import CrossPassReader, resolve_prefetch


@pytest.fixture(scope="module")
def corral():
    return CorralSource(1500, 24, seed=3).materialize()


@pytest.fixture(scope="module")
def baseline(corral):
    X, y = corral
    res = mrmr_streaming(
        ArraySource(X, y), 6, MIScore(2, 2), block_obs=300, prefetch=0
    )
    return res


class CountingSource(ArraySource):
    """ArraySource that counts iter_blocks passes — the 'CSV parse' proxy
    for asserting the spill cache really stops re-reading the base."""

    def __init__(self, X, y):
        super().__init__(X, y)
        self.calls = []

    def iter_blocks(self, block_obs):
        self.calls.append(block_obs)
        return super().iter_blocks(block_obs)


def _same(res, want):
    np.testing.assert_array_equal(
        np.asarray(res.selected), np.asarray(want.selected)
    )
    np.testing.assert_array_equal(
        np.asarray(res.gains), np.asarray(want.gains)
    )


class TestBatchedRedundancy:
    # 300 divides 1500; 413 doesn't — batched picks must not depend on
    # how observations fall into blocks.
    @pytest.mark.parametrize("q", [2, 4, 8])
    @pytest.mark.parametrize("block_obs", [300, 413])
    def test_bitwise_identical_to_unbatched(self, corral, baseline, q,
                                            block_obs):
        X, y = corral
        res = mrmr_streaming(
            ArraySource(X, y), 6, MIScore(2, 2), block_obs=block_obs,
            prefetch=0, batch_candidates=q,
        )
        _same(res, baseline)

    def test_pass_count_drops(self, corral):
        # The acceptance bound: select=32 at q=8 in <= 6 iter_blocks
        # passes (1 relevance + ceil(31/8) redundancy + misses).
        X, y = CorralSource(4000, 64, seed=1).materialize()
        src = CountingSource(X, y)
        res = mrmr_streaming(
            src, 32, MIScore(2, 2), block_obs=1000, prefetch=0,
            batch_candidates=8,
        )
        assert len(src.calls) == res.io["passes"] <= 6
        want = mrmr_streaming(
            ArraySource(X, y), 32, MIScore(2, 2), block_obs=1000, prefetch=0
        )
        assert want.io["passes"] == 32
        _same(res, want)

    def test_q1_is_the_classic_loop(self, corral, baseline):
        X, y = corral
        res = mrmr_streaming(
            ArraySource(X, y), 6, MIScore(2, 2), block_obs=300,
            prefetch=0, batch_candidates=1,
        )
        _same(res, baseline)
        assert res.io["passes"] == 6  # 1 relevance + 5 redundancy

    def test_pearson_batched_bitwise(self):
        # f32 running moments through the vmapped accumulate: each slice
        # must run the identical arithmetic as the single-target step.
        rng = np.random.default_rng(7)
        X = rng.normal(size=(900, 40)).astype(np.float32)
        y = (X[:, :3].sum(1) > 0).astype(np.float32)
        src = ArraySource(X, y)
        want = mrmr_streaming(src, 6, PearsonMIScore(), block_obs=250,
                              prefetch=0)
        for q in (2, 4):
            got = mrmr_streaming(
                src, 6, PearsonMIScore(), block_obs=250, prefetch=0,
                batch_candidates=q,
            )
            _same(got, want)

    def test_tie_break(self):
        # Duplicate columns produce exactly tied objectives at every pick;
        # batched speculation must commit the same lowest-id winners.
        rng = np.random.default_rng(0)
        base = rng.integers(0, 2, size=(400, 4), dtype=np.int32)
        X = np.concatenate([base, base, base], axis=1)  # 12 cols, 3x dupes
        y = base[:, 0] ^ base[:, 1]
        src = ArraySource(X, y)
        want = mrmr_streaming(src, 6, MIScore(2, 2), block_obs=128,
                              prefetch=0)
        for q in (2, 4, 8):
            got = mrmr_streaming(src, 6, MIScore(2, 2), block_obs=128,
                                 prefetch=0, batch_candidates=q)
            _same(got, want)

    def test_q_guard(self, corral):
        X, y = corral
        with pytest.raises(ValueError, match="batch_candidates"):
            mrmr_streaming(ArraySource(X, y), 2, MIScore(2, 2),
                           batch_candidates=0)


class TestSpillCache:
    def test_replay_matches_direct(self, corral, baseline, tmp_path):
        X, y = corral
        src = CountingSource(X, y)
        cached = BlockCacheSource(src, str(tmp_path))
        res1 = mrmr_streaming(cached, 6, MIScore(2, 2), block_obs=300,
                              prefetch=0)
        _same(res1, baseline)
        # pass 1 staged from the base; passes 2..6 replayed from the spill
        # (calls at other block sizes are the memoised fingerprint scan)
        assert src.calls.count(300) == 1
        assert cached.counters["parse_passes"] == 1
        assert cached.counters["replay_passes"] == 5
        assert cached.counters["parsed_bytes"] > 0

    def test_second_fit_never_touches_base(self, corral, baseline, tmp_path):
        X, y = corral
        # same source class both times: the fingerprint (which keys the
        # spill entry) folds the type name in
        warm = BlockCacheSource(CountingSource(X, y), str(tmp_path))
        mrmr_streaming(warm, 6, MIScore(2, 2), block_obs=300, prefetch=0)
        src = CountingSource(X, y)
        cached = BlockCacheSource(src, str(tmp_path))
        res = mrmr_streaming(cached, 6, MIScore(2, 2), block_obs=300,
                             prefetch=0)
        _same(res, baseline)
        assert src.calls.count(300) == 0  # zero block reads: all replayed
        assert cached.counters["parse_passes"] == 0
        assert res.io["cache"]["parsed_bytes"] == 0

    def test_engine_spill_dir_knob(self, corral, baseline, tmp_path):
        X, y = corral
        res = mrmr_streaming(
            ArraySource(X, y), 6, MIScore(2, 2), block_obs=300, prefetch=0,
            spill_dir=str(tmp_path),
        )
        _same(res, baseline)
        assert res.io["cache"]["parse_passes"] == 1

    def test_block_size_keys_entries(self, corral, tmp_path):
        # Different block_obs = different chunk geometry = separate entry.
        X, y = corral
        c = BlockCacheSource(ArraySource(X, y), str(tmp_path))
        list(c.iter_blocks(300))
        list(c.iter_blocks(500))
        assert c.spilled_bytes(300) and c.spilled_bytes(500)
        assert c.counters["parse_passes"] == 2
        list(c.iter_blocks(300))
        assert c.counters["replay_passes"] == 1

    def test_binned_composition(self, tmp_path):
        # Wrapping a BinnedSource spills the ENCODED int codes at a narrow
        # dtype; the replayed fit must still match the fused direct path.
        rng = np.random.default_rng(3)
        X = rng.normal(size=(800, 32)).astype(np.float32)
        y = (X[:, 0] + X[:, 5] > 0).astype(np.int32)
        binned = BinnedSource(ArraySource(X, y), 16, fit_block_obs=200)
        score = MIScore(num_values=16, num_classes=2)
        want = mrmr_streaming(binned, 5, score, block_obs=200, prefetch=0)
        cached = BlockCacheSource(binned, str(tmp_path))
        got = mrmr_streaming(cached, 5, score, block_obs=200, prefetch=0,
                             batch_candidates=4)
        _same(got, want)
        assert cached.feature_dtype == np.int8  # 16 bins spill as int8
        # spilled codes are 4x smaller than the float32 base blocks
        assert cached.spilled_bytes(200) < X.nbytes
        got2 = mrmr_streaming(cached, 5, score, block_obs=200, prefetch=0)
        _same(got2, want)

    def test_truncated_chunk_detected_and_restaged(self, corral, baseline,
                                                   tmp_path):
        # Crash-after-manifest: a chunk torn AFTER the manifest landed must
        # be caught by the size check and the pass re-staged from the base
        # — a corrupt spill may cost a pass, never a wrong selection.
        X, y = corral
        c1 = BlockCacheSource(ArraySource(X, y), str(tmp_path))
        list(c1.iter_blocks(300))
        entry = c1._entry_dir(300)
        chunk = os.path.join(entry, "X00002.npy")
        with open(chunk, "r+b") as f:
            f.truncate(os.path.getsize(chunk) // 2)
        src = CountingSource(X, y)
        c2 = BlockCacheSource(src, str(tmp_path))
        res = mrmr_streaming(c2, 6, MIScore(2, 2), block_obs=300, prefetch=0)
        _same(res, baseline)
        assert c2.counters["parse_passes"] == 1  # re-staged, not reused
        assert src.calls.count(300) == 1
        assert c2.counters["replay_passes"] == 5  # repaired entry replays

    def test_crash_before_manifest_never_replays(self, corral, tmp_path):
        # Chunks without a manifest (crash mid-stage) are not an entry.
        X, y = corral
        entry = os.path.join(str(tmp_path), "deadbeef-b300")
        os.makedirs(entry)
        np.save(os.path.join(entry, "X00000.npy"), X[:300])
        src = CountingSource(X, y)
        c = BlockCacheSource(src, str(tmp_path))
        list(c.iter_blocks(300))
        assert c.counters["parse_passes"] == 1

    def test_lru_eviction_respects_budget(self, tmp_path):
        X1, y1 = CorralSource(600, 16, seed=1).materialize()
        X2, y2 = CorralSource(600, 16, seed=2).materialize()
        c1 = BlockCacheSource(ArraySource(X1, y1), str(tmp_path))
        list(c1.iter_blocks(200))
        sz = c1.spilled_bytes(200)
        # budget fits ONE entry: writing the second must evict the first
        c2 = BlockCacheSource(
            ArraySource(X2, y2), str(tmp_path), budget_bytes=sz + sz // 2
        )
        list(c2.iter_blocks(200))
        assert c2.spilled_bytes(200) is not None  # just-written kept
        assert c1.spilled_bytes(200) is None      # LRU victim

    def test_guards(self, corral, tmp_path):
        X, y = corral
        src = ArraySource(X, y)
        with pytest.raises(TypeError, match="DataSource"):
            BlockCacheSource(X, str(tmp_path))
        with pytest.raises(ValueError, match="already"):
            BlockCacheSource(
                BlockCacheSource(src, str(tmp_path)), str(tmp_path)
            )
        with pytest.raises(ValueError, match="budget"):
            BlockCacheSource(src, str(tmp_path), budget_bytes=0)

    def test_fingerprint_delegates(self, corral, tmp_path):
        # Same content, same address: the service's result cache must
        # coalesce spilled and direct fits of the same source.
        X, y = corral
        src = ArraySource(X, y)
        assert BlockCacheSource(src, str(tmp_path)).fingerprint() == \
            src.fingerprint()


class TestReadahead:
    def test_cross_pass_reader_replays_passes(self):
        X = np.arange(12, dtype=np.int32).reshape(6, 2)
        y = np.zeros(6, np.int32)
        src = CountingSource(X, y)
        reader = CrossPassReader(
            lambda: src.iter_blocks(2), depth=2, max_passes=3
        )
        try:
            for _ in range(3):
                blocks = list(reader.next_pass())
                assert len(blocks) == 3
                np.testing.assert_array_equal(
                    np.concatenate([b[0] for b in blocks]), X
                )
            with pytest.raises(RuntimeError, match="exhausted"):
                next(reader.next_pass())
        finally:
            reader.close()

    def test_reader_close_stops_thread(self):
        import threading

        produced = []

        def make_pass():
            for i in range(1000):
                produced.append(i)
                yield np.zeros((2, 1), np.int8), np.zeros(2, np.int8)

        reader = CrossPassReader(make_pass, depth=1, max_passes=100)
        it = reader.next_pass()
        next(it)
        reader.close()
        assert len(produced) < 1000
        assert not any(
            t.name == "cross-pass-reader" and t.is_alive()
            for t in threading.enumerate()
        )

    def test_reader_propagates_errors(self):
        def make_pass():
            yield np.zeros((2, 1), np.int8), np.zeros(2, np.int8)
            raise RuntimeError("disk died")

        reader = CrossPassReader(make_pass, depth=1, max_passes=2)
        try:
            with pytest.raises(RuntimeError, match="disk died"):
                list(reader.next_pass())
        finally:
            reader.close()

    def test_readahead_matches_baseline(self, corral, baseline):
        X, y = corral
        for depth in (1, 3):
            res = mrmr_streaming(
                ArraySource(X, y), 6, MIScore(2, 2), block_obs=300,
                readahead=depth,
            )
            _same(res, baseline)

    def test_maxrel_single_pass_with_readahead(self, corral):
        # maxrel needs ONE pass: the reader must not over-read the source.
        X, y = corral
        src = CountingSource(X, y)
        res = mrmr_streaming(
            src, 4, MIScore(2, 2), block_obs=300, readahead=2,
            criterion="maxrel",
        )
        assert res.io["passes"] == 1
        assert len(src.calls) == 1

    def test_guard(self, corral):
        X, y = corral
        with pytest.raises(ValueError, match="readahead"):
            mrmr_streaming(ArraySource(X, y), 2, MIScore(2, 2), readahead=-1)


class TestCombined:
    @pytest.mark.parametrize("block_obs", [300, 413])
    def test_all_knobs_bitwise(self, corral, baseline, tmp_path, block_obs):
        X, y = corral
        res = mrmr_streaming(
            ArraySource(X, y), 6, MIScore(2, 2), block_obs=block_obs,
            batch_candidates=4, spill_dir=str(tmp_path), readahead=2,
        )
        _same(res, baseline)
        assert res.io["passes"] < 6
        assert res.io["cache"]["parse_passes"] == 1

    def test_obs_sharded_mesh(self, corral, baseline, tmp_path):
        X, y = corral
        mesh = make_mesh((len(jax.devices()),), ("data",))
        res = mrmr_streaming(
            ArraySource(X, y), 6, MIScore(2, 2), block_obs=300, mesh=mesh,
            batch_candidates=4, spill_dir=str(tmp_path),
        )
        _same(res, baseline)

    def test_wide_feature_sharded_mesh(self, tmp_path):
        # Wide regime: the q-leading batched statistics state must shard
        # over the feature axis through state_shardings like the classic
        # state does.
        X, y = CorralSource(300, 256, seed=5).materialize()
        mesh = make_mesh((len(jax.devices()),), ("model",))
        want = MRMRSelector(
            num_select=5, score=MIScore(2, 2), mesh=mesh, block_obs=100
        ).fit(ArraySource(X, y))
        got = MRMRSelector(
            num_select=5, score=MIScore(2, 2), mesh=mesh, block_obs=100,
            batch_candidates=4, spill_dir=str(tmp_path),
        ).fit(ArraySource(X, y))
        np.testing.assert_array_equal(got.selected_, want.selected_)
        np.testing.assert_array_equal(got.gains_, want.gains_)

    def test_2d_grid_mesh(self, tmp_path):
        X, y = CorralSource(400, 64, seed=6).materialize()
        od, fd = factor_mesh(len(jax.devices()))
        mesh = make_mesh((od, fd), ("data", "model"))
        want = MRMRSelector(
            num_select=5, score=MIScore(2, 2), mesh=mesh, block_obs=100
        ).fit(ArraySource(X, y))
        got = MRMRSelector(
            num_select=5, score=MIScore(2, 2), mesh=mesh, block_obs=100,
            batch_candidates=8, spill_dir=str(tmp_path), readahead=2,
        ).fit(ArraySource(X, y))
        np.testing.assert_array_equal(got.selected_, want.selected_)
        np.testing.assert_array_equal(got.gains_, want.gains_)

    def test_selector_knobs_and_plan(self, corral, tmp_path):
        X, y = corral
        sel = MRMRSelector(
            num_select=4, score=MIScore(2, 2), block_obs=300,
            batch_candidates=4, spill_dir=str(tmp_path), readahead=1,
        ).fit(ArraySource(X, y))
        assert sel.plan_.batch_candidates == 4
        assert sel.plan_.spill_dir == str(tmp_path)
        assert sel.plan_.readahead == 1
        assert sel.result_.io is not None
        assert sel.result_.io["cache"]["parse_passes"] == 1

    def test_csv_pass2_bytes_zero(self, tmp_path):
        # The acceptance wording verbatim: with the spill cache on,
        # pass-2+ bytes parsed from CSV must be 0.
        rng = np.random.default_rng(0)
        X = rng.integers(0, 2, size=(200, 8))
        y = rng.integers(0, 2, size=200)
        path = tmp_path / "data.csv"
        rows = "\n".join(
            ",".join(map(str, list(xr) + [yi])) for xr, yi in zip(X, y)
        )
        path.write_text("\n".join(f"f{i}" for i in range(9)).replace("\n", ",")
                        + "\n" + rows + "\n")
        src = CSVSource(str(path), dtype=np.int32)
        res = mrmr_streaming(
            src, 4, MIScore(2, 2), block_obs=64, prefetch=0,
            spill_dir=str(tmp_path / "spill"),
        )
        cache = res.io["cache"]
        assert cache["parse_passes"] == 1
        assert cache["replay_passes"] == res.io["passes"] - 1
        want = mrmr_streaming(src, 4, MIScore(2, 2), block_obs=64, prefetch=0)
        _same(res, want)


class TestPrefetchAuto:
    def test_resolve(self):
        assert resolve_prefetch("auto", backend="cpu") == 0
        assert resolve_prefetch("auto", backend="tpu") == 2
        assert resolve_prefetch("auto", backend="gpu") == 2
        assert resolve_prefetch(3, backend="cpu") == 3
        assert resolve_prefetch(0, backend="tpu") == 0
        with pytest.raises(ValueError, match="prefetch"):
            resolve_prefetch(-1)
        with pytest.raises(ValueError, match="prefetch"):
            resolve_prefetch("fast")

    def test_selector_default_resolves_in_plan(self, corral):
        X, y = corral
        sel = MRMRSelector(num_select=2, score=MIScore(2, 2),
                           block_obs=500).fit(ArraySource(X, y))
        assert sel.plan_.prefetch == resolve_prefetch("auto")
        assert isinstance(sel.plan_.prefetch, int)


class TestIOAccounting:
    def test_counters_consistent(self, corral):
        X, y = corral
        res = mrmr_streaming(ArraySource(X, y), 4, MIScore(2, 2),
                             block_obs=300, prefetch=0)
        assert res.io["passes"] == 4
        assert res.io["blocks_read"] == 4 * 5  # 1500/300 blocks per pass
        assert res.io["bytes_read"] == 4 * (X.nbytes + y.nbytes)

    def test_result_json_roundtrip(self, corral):
        X, y = corral
        res = mrmr_streaming(ArraySource(X, y), 3, MIScore(2, 2),
                             block_obs=500, prefetch=0)
        back = MRMRResult.from_json(res.to_json())
        assert back.io == res.io
        assert json.loads(res.to_json())["io"]["passes"] == 3

    def test_in_memory_result_has_no_io(self, corral):
        X, y = corral
        sel = MRMRSelector(num_select=3, score=MIScore(2, 2)).fit(X, y)
        assert sel.result_.io is None
        back = MRMRResult.from_json(sel.result_.to_json())
        assert back.io is None


class TestServeKnobs:
    def test_cache_key_excludes_execution_knobs(self, corral):
        from repro.core.criteria import resolve_criterion
        from repro.serve.selection import SelectionRequest

        X, y = corral
        src = ArraySource(X, y)
        base = SelectionRequest(
            source=src, num_select=4, score=MIScore(2, 2),
            criterion=resolve_criterion("mid"),
        )
        variant = SelectionRequest(
            source=src, num_select=4, score=MIScore(2, 2),
            criterion=resolve_criterion("mid"), block_obs=128, prefetch=0,
            batch_candidates=8, spill_dir="/tmp/spill", readahead=2,
        )
        assert base.cache_key() == variant.cache_key()
        other = SelectionRequest(
            source=src, num_select=5, score=MIScore(2, 2),
            criterion=resolve_criterion("mid"),
        )
        assert base.cache_key() != other.cache_key()

    def test_submit_with_knobs_coalesces(self, corral, tmp_path):
        from repro.serve.selection import SelectionService

        X, y = corral
        with SelectionService(workers=1) as svc:
            j1 = svc.submit(ArraySource(X, y), num_select=3,
                            score=MIScore(2, 2))
            r1 = svc.result(j1, timeout=60)
            # same fit, different execution knobs: cache hit at submit
            j2 = svc.submit(
                ArraySource(X, y), num_select=3, score=MIScore(2, 2),
                batch_candidates=4, spill_dir=str(tmp_path), readahead=1,
            )
            assert svc.poll(j2).cache_hit
            r2 = svc.result(j2, timeout=60)
            np.testing.assert_array_equal(
                np.asarray(r1.selected), np.asarray(r2.selected)
            )
