"""Gradient compression: exactness bounds + error-feedback convergence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import compat
from repro.train.compression import GradCompression, compressed_psum


def test_compress_roundtrip_error_bound():
    key = jax.random.PRNGKey(0)
    grads = {
        "a": jax.random.normal(key, (64, 32)),
        "b": jax.random.normal(jax.random.fold_in(key, 1), (128,)) * 10,
    }
    state = GradCompression.init(grads)
    (q, s), state = state.compress(grads)
    for k in grads:
        deq = q[k].astype(jnp.float32) * s[k]
        err = np.abs(np.asarray(deq - grads[k]))
        # quantisation error bounded by half a step
        assert err.max() <= float(s[k]) * 0.5 + 1e-6
        # and exactly carried in the residual
        np.testing.assert_allclose(
            np.asarray(state.residual[k]), np.asarray(grads[k] - deq),
            rtol=0, atol=1e-6,
        )


def test_error_feedback_unbiased_over_time():
    """Repeatedly compressing the SAME gradient must sum (deq over steps)
    to ~steps * grad: the residual re-injects what quantisation dropped."""
    g = {"w": jnp.array([0.3, -0.004, 0.0021, 1.7], jnp.float32)}
    state = GradCompression.init(g)
    total = jnp.zeros_like(g["w"])
    steps = 50
    for _ in range(steps):
        (q, s), state = state.compress(g)
        total = total + q["w"].astype(jnp.float32) * s["w"]
    np.testing.assert_allclose(
        np.asarray(total / steps), np.asarray(g["w"]), rtol=0.02, atol=1e-4
    )


@pytest.mark.skipif(jax.device_count() < 2, reason="needs >=2 devices")
def test_compressed_psum_matches_mean():
    from repro.dist.meshes import make_mesh

    n = jax.device_count()
    mesh = make_mesh((n,), ("data",))
    key = jax.random.PRNGKey(0)
    grads = jax.random.normal(key, (n, 256))

    def body(g):
        st = GradCompression.init({"g": g[0]})
        out, _ = compressed_psum({"g": g.reshape(256)}, ("data",), st, n)
        return out["g"]

    fn = jax.jit(
        compat.shard_map(
            body, mesh=mesh, in_specs=P("data", None), out_specs=P()
        )
    )
    out = np.asarray(fn(grads))
    ref = np.asarray(grads.mean(axis=0))
    # int8 with shared scale: relative error ~1/127 of the max magnitude
    tol = float(np.abs(np.asarray(grads)).max()) / 127 * 1.01 + 1e-6
    assert np.abs(out - ref).max() <= tol
