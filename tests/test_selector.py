"""MRMRSelector front-door API: planning heuristic, engine agreement,
transform semantics, engine registry."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import (
    CustomScore,
    MIScore,
    MRMRSelector,
    PearsonMIScore,
    plan_selection,
)
from repro.core import mrmr_reference
from repro.core.mrmr import MRMRResult
from repro.core.selector import available_encodings, register_engine, get_engine
from repro.data.synthetic import corral_dataset
from repro.dist import make_mesh


class TestPlanSelection:
    def test_tall_narrow_conventional(self):
        plan = plan_selection((100_000, 100), 8)
        assert plan.encoding == "conventional"
        assert plan.mesh_shape == (8,)
        assert plan.obs_axes and not plan.feat_axes

    def test_wide_short_alternative(self):
        plan = plan_selection((200, 50_000), 8)
        assert plan.encoding == "alternative"
        assert plan.mesh_shape == (8,)
        assert plan.feat_axes and not plan.obs_axes

    def test_square_large_grid(self):
        plan = plan_selection((4096, 4096), 8)
        assert plan.encoding == "grid"
        assert plan.obs_axes and plan.feat_axes
        assert int(np.prod(plan.mesh_shape)) == 8

    def test_single_device_never_grid(self):
        plan = plan_selection((4096, 4096), 1)
        assert plan.encoding in ("conventional", "alternative")
        assert plan.mesh_shape == ()

    def test_non_mi_score_forces_alternative(self):
        plan = plan_selection((100_000, 100), 8, PearsonMIScore())
        assert plan.encoding == "alternative"
        custom = CustomScore(get_result=lambda v, c, s, n: jnp.float32(0))
        assert plan_selection((4096, 4096), 8, custom).encoding == "alternative"

    def test_mesh_constrains_planning(self):
        mesh = make_mesh((1,), ("data",))
        plan = plan_selection((200, 50_000), mesh)
        # wide data wants the alternative encoding, but the mesh has no
        # feature axis -> fall back to the observation-sharded job
        assert plan.encoding == "conventional"
        assert plan.obs_axes == ("data",)

    def test_non_mi_score_never_routed_to_mi_engine(self):
        # A non-MI score on a mesh without a feature axis must fall back
        # to the score-agnostic reference engine, not the MI-only
        # conventional one.
        mesh = make_mesh((1,), ("data",))
        plan = plan_selection((256, 16), mesh, PearsonMIScore())
        assert plan.encoding == "reference"


@pytest.fixture(scope="module")
def corral():
    X, y = corral_dataset(2000, 32, seed=1, flip_prob=0.02)
    return np.asarray(X, np.int32), np.asarray(y)


@pytest.fixture(scope="module")
def corral_ref(corral):
    X, y = corral
    score = MIScore(num_values=2, num_classes=2)
    res = mrmr_reference(jnp.asarray(X.T), jnp.asarray(y), 5, score)
    return np.asarray(res.selected), np.asarray(res.gains)


class TestEngineAgreement:
    @pytest.mark.parametrize("encoding", ["reference", "conventional",
                                          "alternative"])
    def test_matches_reference(self, corral, corral_ref, encoding):
        X, y = corral
        sel = MRMRSelector(num_select=5, encoding=encoding).fit(X, y)
        np.testing.assert_array_equal(sel.selected_, corral_ref[0])
        assert sel.plan_.encoding == encoding

    def test_grid_matches_reference(self, corral, corral_ref):
        X, y = corral
        mesh = make_mesh((1, 1), ("data", "model"))
        sel = MRMRSelector(num_select=5, encoding="grid", mesh=mesh).fit(X, y)
        np.testing.assert_array_equal(sel.selected_, corral_ref[0])
        np.testing.assert_allclose(sel.gains_, corral_ref[1],
                                   rtol=1e-4, atol=1e-5)

    def test_auto_plan_matches_reference(self, corral, corral_ref):
        X, y = corral
        sel = MRMRSelector(num_select=5).fit(X, y)
        assert sel.plan_ is not None
        np.testing.assert_array_equal(sel.selected_, corral_ref[0])

    def test_non_divisible_shapes_padded(self, corral, corral_ref):
        # 23 features / 2000 rows don't divide a (1,1) grid's padded walk —
        # exercise the pad/unpad ownership with ragged shapes.
        X, y = corral
        Xr, L = X[:, :23], 4
        score = MIScore(num_values=2, num_classes=2)
        want = np.asarray(
            mrmr_reference(jnp.asarray(Xr.T), jnp.asarray(y), L, score).selected
        )
        for encoding, mesh in [
            ("conventional", None),
            ("alternative", None),
            ("grid", make_mesh((1, 1), ("data", "model"))),
        ]:
            sel = MRMRSelector(num_select=L, encoding=encoding,
                               mesh=mesh).fit(Xr, y)
            np.testing.assert_array_equal(sel.selected_, want)


class TestContinuousTargets:
    def test_pearson_keeps_continuous_y(self):
        # Regression: fit() must not truncate a continuous target to int
        # for non-MI scores (Pearson relevance collapses if it does).
        rng = np.random.default_rng(0)
        X = rng.normal(size=(256, 16)).astype(np.float32)
        y = 0.9 * X[:, 3] + 0.1 * rng.normal(size=256)  # y in R, not classes
        sel = MRMRSelector(num_select=2, score=PearsonMIScore()).fit(X, y)
        assert sel.selected_[0] == 3
        assert sel.gains_[0] > 0.5  # int-truncated y would give ~0 MI


class TestTransform:
    def test_columns_in_selection_order(self, corral):
        X, y = corral
        sel = MRMRSelector(num_select=5).fit(X, y)
        Xt = sel.transform(X)
        assert Xt.shape == (X.shape[0], 5)
        for rank, feat in enumerate(sel.selected_):
            np.testing.assert_array_equal(Xt[:, rank], X[:, feat])

    def test_fit_transform(self, corral):
        X, y = corral
        a = MRMRSelector(num_select=3).fit_transform(X, y)
        b = MRMRSelector(num_select=3).fit(X, y).transform(X)
        np.testing.assert_array_equal(a, b)

    def test_transform_before_fit_raises(self):
        with pytest.raises(RuntimeError):
            MRMRSelector(num_select=2).transform(np.zeros((4, 4)))


class TestRegistry:
    def test_builtin_engines_registered(self):
        assert set(available_encodings()) >= {
            "reference", "conventional", "alternative", "grid",
        }

    def test_unknown_encoding_raises(self, corral):
        X, y = corral
        with pytest.raises(ValueError, match="unknown encoding"):
            MRMRSelector(num_select=2, encoding="mapreduce").fit(X, y)

    def test_custom_engine_dispatch(self, corral):
        X, y = corral

        @register_engine("_test_stub")
        def stub(X, y, *, num_select, plan, mesh):
            return MRMRResult(
                selected=jnp.arange(num_select, dtype=jnp.int32),
                gains=jnp.zeros((num_select,), jnp.float32),
            )

        try:
            sel = MRMRSelector(num_select=3, encoding="_test_stub").fit(X, y)
            np.testing.assert_array_equal(sel.selected_, [0, 1, 2])
            assert get_engine("_test_stub") is stub
        finally:
            from repro.core import selector as selector_mod

            selector_mod._ENGINES.pop("_test_stub", None)
