"""Streaming discretisation: sketch accuracy/mergeability, binner and
BinnedSource semantics, fingerprint identity, and the selector's ``bins=``
front door (in-memory == streaming, early errors for continuous MI)."""

import numpy as np
import pytest

from repro.core.scores import MIScore, PearsonMIScore
from repro.core.selector import MRMRSelector
from repro.data.binning import (
    BinnedSource,
    QuantileBinner,
    QuantileSketch,
    clear_binner_memo,
    fit_binned,
)
from repro.data.sources import ArraySource


def _columns(n, seed=0):
    """Uniform, skewed (cubed exponential) and heavy-tie distributions —
    the shapes that break naive samplers."""
    rng = np.random.default_rng(seed)
    return np.stack(
        [
            rng.uniform(size=n),
            rng.exponential(size=n) ** 3,
            np.repeat(np.arange(5), n // 5).astype(float),
            rng.normal(size=n),
        ],
        axis=1,
    )


def _rank_of(col_sorted, value):
    """Normalised rank interval [lo, hi] of ``value`` (ties widen it)."""
    n = len(col_sorted)
    lo = np.searchsorted(col_sorted, value, side="left") / n
    hi = np.searchsorted(col_sorted, value, side="right") / n
    return lo, hi


class TestQuantileSketch:
    QS = np.array([0.05, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95])

    def test_rank_error_within_tolerance(self):
        n = 40_000
        X = _columns(n)
        sk = QuantileSketch(X.shape[1], k=256, seed=0)
        for i in range(0, n, 1000):
            sk.update(X[i : i + 1000])
        approx = sk.quantiles(self.QS)
        for j in range(X.shape[1]):
            col = np.sort(X[:, j])
            for q, val in zip(self.QS, approx[j]):
                lo, hi = _rank_of(col, val)
                assert lo - 0.02 <= q <= hi + 0.02, (j, q, lo, hi)

    def test_block_size_independence(self):
        X = _columns(20_000, seed=1)
        sketches = []
        for bs in (37, 1000, 4096, 20_000):
            sk = QuantileSketch(X.shape[1], k=128, seed=0)
            for i in range(0, len(X), bs):
                sk.update(X[i : i + bs])
            sketches.append(sk.quantiles(self.QS))
        for other in sketches[1:]:
            np.testing.assert_array_equal(sketches[0], other)

    def test_merge_matches_tolerance(self):
        n = 30_000
        X = _columns(n, seed=2)
        parts = [
            QuantileSketch(X.shape[1], k=256, seed=0).update(X[i : i + 10_000])
            for i in range(0, n, 10_000)
        ]
        merged = parts[0]
        for p in parts[1:]:
            merged.merge(p)
        assert merged.count == n
        approx = merged.quantiles(self.QS)
        for j in range(X.shape[1]):
            col = np.sort(X[:, j])
            for q, val in zip(self.QS, approx[j]):
                lo, hi = _rank_of(col, val)
                assert lo - 0.03 <= q <= hi + 0.03, (j, q, lo, hi)

    def test_merge_geometry_mismatch_raises(self):
        a = QuantileSketch(3, k=64)
        with pytest.raises(ValueError, match="geometry"):
            a.merge(QuantileSketch(4, k=64))
        with pytest.raises(ValueError, match="geometry"):
            a.merge(QuantileSketch(3, k=128))

    def test_small_stream_is_exact(self):
        # Fewer rows than k: nothing ever compacts, quantiles are exact
        # order statistics of the f32 stream.
        rng = np.random.default_rng(3)
        X = rng.normal(size=(50, 2))
        sk = QuantileSketch(2, k=64).update(X)
        med = sk.quantiles([0.5])[:, 0]
        want = np.sort(X.astype(np.float32), axis=0)[24]
        np.testing.assert_array_equal(med, want)

    def test_rejects_nonfinite_and_bad_shapes(self):
        sk = QuantileSketch(2, k=8)
        with pytest.raises(ValueError, match="non-finite"):
            sk.update(np.array([[np.nan, 0.0]]))
        with pytest.raises(ValueError, match="num_features"):
            sk.update(np.zeros((4, 3)))
        with pytest.raises(ValueError, match="even"):
            QuantileSketch(2, k=7)
        with pytest.raises(ValueError):
            sk.quantiles([0.5])  # empty


class TestQuantileBinner:
    def test_fit_transform_equal_frequency(self):
        n = 12_000
        rng = np.random.default_rng(4)
        X = rng.normal(size=(n, 3))
        y = rng.integers(0, 4, size=n)
        b = QuantileBinner(bins=8).fit(ArraySource(X, y), block_obs=1000)
        assert b.fitted and b.edges_.shape == (3, 7)
        assert b.num_classes_ == 4 and b.n_obs_ == n
        codes = b.transform(X)
        assert codes.dtype == np.int32
        assert codes.min() >= 0 and codes.max() < 8
        counts = np.apply_along_axis(np.bincount, 0, codes, minlength=8)
        assert counts.min() > (n / 8) * 0.7  # roughly equal-frequency

    def test_encode_column_matches_transform(self):
        rng = np.random.default_rng(5)
        X = rng.normal(size=(500, 4))
        y = rng.integers(0, 2, size=500)
        b = QuantileBinner(bins=16).fit(ArraySource(X, y))
        full = b.transform(X)
        for j in range(4):
            np.testing.assert_array_equal(
                b.encode_column(j, X[:, j]), full[:, j]
            )

    def test_unfitted_transform_raises(self):
        with pytest.raises(RuntimeError, match="fit"):
            QuantileBinner(bins=4).transform(np.zeros((2, 2)))
        with pytest.raises(ValueError, match="bins"):
            QuantileBinner(bins=1)

    def test_continuous_target_raises(self):
        rng = np.random.default_rng(6)
        src = ArraySource(rng.normal(size=(100, 2)), rng.normal(size=100))
        with pytest.raises(ValueError, match="target"):
            QuantileBinner(bins=4).fit(src)

    def test_float_integral_target_accepted(self):
        rng = np.random.default_rng(7)
        y = rng.integers(0, 3, size=200).astype(np.float64)  # CSV-style
        b = QuantileBinner(bins=4).fit(ArraySource(rng.normal(size=(200, 2)), y))
        assert b.num_classes_ == 3


class TestBinnedSource:
    def _src(self, n=2000, f=6, seed=8):
        rng = np.random.default_rng(seed)
        return ArraySource(
            rng.normal(size=(n, f)), rng.integers(0, 3, size=n)
        )

    def test_blocks_match_binner_transform(self):
        src = self._src()
        bs = BinnedSource(src, 8)
        Xc, yc = bs.materialize(256)
        want = bs.binner.transform(src.materialize()[0])
        np.testing.assert_array_equal(Xc, want)
        np.testing.assert_array_equal(yc, src.materialize()[1])

    def test_stats_discrete_no_scan(self):
        bs = fit_binned(self._src(), 8)
        st = bs.stats()
        assert st.discrete and st.num_values == 8 and st.num_classes == 3

    def test_fingerprint_derives_from_base_and_config(self):
        src = self._src()
        fp16 = BinnedSource(src, 16).fingerprint()
        fp64 = BinnedSource(src, 64).fingerprint()
        assert fp16 != fp64
        assert fp16 != src.fingerprint()
        # same config, fresh wrapper -> same identity (pre-fit, no I/O)
        assert fp16 == BinnedSource(src, 16).fingerprint()
        # sketch config is part of the identity too
        assert fp16 != BinnedSource(src, 16, sketch_k=256).fingerprint()
        assert fp16 != BinnedSource(src, 16, seed=1).fingerprint()

    def test_binner_memoised_across_instances(self):
        clear_binner_memo()
        src = self._src(seed=9)
        a = BinnedSource(src, 8)
        first = a.binner
        b = BinnedSource(src, 8)
        assert b.binner is first  # memo hit, no second sketch pass
        clear_binner_memo()

    def test_guards(self):
        src = self._src()
        with pytest.raises(ValueError, match="already binned"):
            BinnedSource(BinnedSource(src, 4), 4)
        with pytest.raises(TypeError, match="DataSource"):
            BinnedSource(np.zeros((2, 2)), 4)
        with pytest.raises(ValueError, match="exactly one"):
            BinnedSource(src)
        with pytest.raises(ValueError, match="exactly one"):
            BinnedSource(src, 4, binner=QuantileBinner(4))


class TestSelectorBins:
    def _data(self, n=2500, f=10, seed=10):
        rng = np.random.default_rng(seed)
        y = rng.integers(0, 2, size=n)
        X = rng.normal(size=(n, f))
        for j in range(3):
            X[:, j] += y * (1.5 - 0.4 * j)
        return X, y

    def test_in_memory_binned_fit(self):
        X, y = self._data()
        fs = MRMRSelector(num_select=3, bins=16).fit(X, y)
        assert fs.plan_.bins == 16
        assert isinstance(fs.plan_.score, MIScore)
        assert fs.plan_.score.num_values == 16
        assert set(fs.selected_) == {0, 1, 2}

    def test_streaming_matches_in_memory_every_block_size(self):
        X, y = self._data(seed=11)
        base = MRMRSelector(num_select=3, bins=16).fit(X, y)
        src = ArraySource(X, y)
        for bo in (128, 999, 4096):
            fs = MRMRSelector(num_select=3, bins=16, block_obs=bo).fit(src)
            assert fs.plan_.encoding == "streaming" and fs.plan_.bins == 16
            np.testing.assert_array_equal(fs.selected_, base.selected_)

    def test_prewrapped_source_agrees(self):
        X, y = self._data(seed=12)
        src = ArraySource(X, y)
        a = MRMRSelector(num_select=3, bins=8).fit(src)
        b = MRMRSelector(num_select=3).fit(BinnedSource(src, 8))
        np.testing.assert_array_equal(a.selected_, b.selected_)
        assert b.plan_.bins == 8

    def test_float64_npy_source_end_to_end(self, tmp_path):
        X, y = self._data(seed=13)
        src = ArraySource(X.astype(np.float64), y)
        xp, yp = src.to_npy(
            str(tmp_path / "X.npy"), str(tmp_path / "y.npy")
        )
        from repro.data.sources import NpySource

        fs = MRMRSelector(num_select=3, bins=16, block_obs=512).fit(
            NpySource(xp, yp)
        )
        base = MRMRSelector(num_select=3, bins=16).fit(X, y)
        np.testing.assert_array_equal(fs.selected_, base.selected_)

    def test_continuous_mi_early_error_array(self):
        X, y = self._data()
        with pytest.raises(ValueError, match="bins="):
            MRMRSelector(num_select=2, score=MIScore(2, 2)).fit(X, y)

    def test_continuous_mi_early_error_source(self):
        X, y = self._data()
        with pytest.raises(ValueError, match="bins="):
            MRMRSelector(num_select=2, score=MIScore(2, 2)).fit(
                ArraySource(X, y)
            )

    def test_explicit_score_num_values_guard(self):
        X, y = self._data()
        with pytest.raises(ValueError, match="num_values"):
            MRMRSelector(num_select=2, score=MIScore(4, 2), bins=16).fit(X, y)

    def test_bins_ignored_for_discrete_and_pearson(self):
        rng = np.random.default_rng(14)
        Xd = rng.integers(0, 3, size=(400, 5))
        yd = rng.integers(0, 2, size=400)
        fd = MRMRSelector(num_select=2, bins=16).fit(Xd, yd)
        assert fd.plan_.bins is None
        Xc, yc = self._data()
        fp = MRMRSelector(
            num_select=2, bins=16, score=PearsonMIScore()
        ).fit(Xc, yc)
        assert fp.plan_.bins is None
        assert isinstance(fp.plan_.score, PearsonMIScore)
