"""Pallas kernel tests: shape/dtype sweeps vs the pure-jnp oracles.

All kernels run in ``interpret=True`` mode on CPU (the kernel body executes
in Python), which validates the BlockSpec tiling, accumulation-across-grid
logic and padding behaviour against ``repro.kernels.ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.binning import bin_codes_pallas
from repro.kernels.contingency import contingency_tables_pallas
from repro.kernels.mi_score import mi_scores_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.pearson import pearson_corr_pallas


class TestContingencyKernel:
    @pytest.mark.parametrize(
        "m,f,v,c",
        [
            (16, 4, 2, 2),
            (100, 7, 3, 2),     # non-divisible M and F
            (512, 8, 4, 3),
            (1030, 33, 5, 4),   # padding on both axes
            (64, 1, 2, 2),      # single feature
        ],
    )
    def test_matches_oracle(self, m, f, v, c):
        rng = np.random.default_rng(hash((m, f, v, c)) % 2**31)
        X = jnp.asarray(rng.integers(0, v, (m, f)), jnp.int32)
        y = jnp.asarray(rng.integers(0, c, m), jnp.int32)
        got = contingency_tables_pallas(X, y, v, c, interpret=True)
        want = ref.contingency_tables(X, y, v, c)
        np.testing.assert_allclose(got, want, atol=0)

    @pytest.mark.parametrize("tile_m,tile_f", [(8, 2), (32, 8), (512, 64)])
    def test_tile_sweep(self, tile_m, tile_f):
        rng = np.random.default_rng(0)
        X = jnp.asarray(rng.integers(0, 3, (130, 21)), jnp.int32)
        y = jnp.asarray(rng.integers(0, 2, 130), jnp.int32)
        got = contingency_tables_pallas(
            X, y, 3, 2, tile_m=tile_m, tile_f=tile_f, interpret=True
        )
        want = ref.contingency_tables(X, y, 3, 2)
        np.testing.assert_allclose(got, want, atol=0)

    def test_out_of_range_padding_rows(self):
        X = jnp.asarray([[0], [1], [2**31 - 1]], jnp.int32)
        y = jnp.asarray([0, 1, 2**31 - 1], jnp.int32)
        got = contingency_tables_pallas(X, y, 2, 2, interpret=True)
        np.testing.assert_allclose(got[0], np.array([[1, 0], [0, 1]]))

    @pytest.mark.parametrize("dtype", [jnp.int8, jnp.int16, jnp.int32])
    def test_dtypes(self, dtype):
        rng = np.random.default_rng(1)
        X = jnp.asarray(rng.integers(0, 2, (40, 5)), dtype)
        y = jnp.asarray(rng.integers(0, 2, 40), dtype)
        got = contingency_tables_pallas(X, y, 2, 2, interpret=True)
        want = ref.contingency_tables(X.astype(jnp.int32), y.astype(jnp.int32), 2, 2)
        np.testing.assert_allclose(got, want, atol=0)


class TestPearsonKernel:
    @pytest.mark.parametrize(
        "f,t,m",
        [
            (4, 1, 64),
            (7, 3, 100),     # non-divisible everywhere
            (128, 128, 512),
            (130, 5, 1030),  # padding on every axis
        ],
    )
    def test_matches_oracle(self, f, t, m):
        rng = np.random.default_rng(hash((f, t, m)) % 2**31)
        X = jnp.asarray(rng.normal(size=(f, m)), jnp.float32)
        Y = jnp.asarray(rng.normal(size=(t, m)), jnp.float32)
        got = pearson_corr_pallas(X, Y, interpret=True)
        want = ref.pearson_corr(X, Y)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)

    @pytest.mark.parametrize("tile", [(8, 8, 16), (64, 32, 128)])
    def test_tile_sweep(self, tile):
        tf, tt, tm = tile
        rng = np.random.default_rng(2)
        X = jnp.asarray(rng.normal(size=(33, 200)), jnp.float32)
        Y = jnp.asarray(rng.normal(size=(9, 200)), jnp.float32)
        got = pearson_corr_pallas(
            X, Y, tile_f=tf, tile_t=tt, tile_m=tm, interpret=True
        )
        want = ref.pearson_corr(X, Y)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)

    def test_bf16_input(self):
        rng = np.random.default_rng(3)
        X = jnp.asarray(rng.normal(size=(8, 128)), jnp.bfloat16)
        Y = jnp.asarray(rng.normal(size=(2, 128)), jnp.bfloat16)
        got = pearson_corr_pallas(X, Y, interpret=True)
        want = ref.pearson_corr(X.astype(jnp.float32), Y.astype(jnp.float32))
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)

    def test_self_correlation_diagonal(self):
        rng = np.random.default_rng(4)
        X = jnp.asarray(rng.normal(size=(6, 300)), jnp.float32)
        got = pearson_corr_pallas(X, X, interpret=True)
        np.testing.assert_allclose(np.diag(got), np.ones(6), rtol=1e-4)


class TestMIScoreKernel:
    @pytest.mark.parametrize(
        "f,v,c", [(1, 2, 2), (10, 3, 2), (300, 4, 4), (257, 5, 3)]
    )
    def test_matches_oracle(self, f, v, c):
        rng = np.random.default_rng(hash((f, v, c)) % 2**31)
        counts = jnp.asarray(rng.integers(0, 50, (f, v, c)), jnp.float32)
        got = mi_scores_pallas(counts, interpret=True)
        want = ref.mi_scores(counts)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_zero_rows(self):
        counts = jnp.zeros((4, 3, 3), jnp.float32)
        got = mi_scores_pallas(counts, interpret=True)
        np.testing.assert_allclose(got, np.zeros(4), atol=1e-6)


class TestBinCodesKernel:
    @pytest.mark.parametrize(
        "b,n,e",
        [
            (16, 4, 3),
            (100, 7, 15),     # non-divisible B and N
            (300, 130, 7),    # feature padding past one lane tile
            (64, 1, 31),      # single feature
            (1, 5, 1),        # single row, single edge
        ],
    )
    def test_matches_oracle_bitwise(self, b, n, e):
        rng = np.random.default_rng(hash((b, n, e)) % 2**31)
        X = jnp.asarray(rng.normal(size=(b, n)), jnp.float32)
        edges = jnp.asarray(np.sort(rng.normal(size=(n, e)), axis=1), jnp.float32)
        got = np.asarray(bin_codes_pallas(X, edges, interpret=True))
        want = np.asarray(ref.bin_codes(X, edges))
        assert got.dtype == np.int32
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("tile_b,tile_n", [(8, 2), (64, 8), (512, 256)])
    def test_tile_sweep(self, tile_b, tile_n):
        rng = np.random.default_rng(11)
        X = jnp.asarray(rng.normal(size=(130, 21)), jnp.float32)
        edges = jnp.asarray(np.sort(rng.normal(size=(21, 9)), axis=1), jnp.float32)
        got = bin_codes_pallas(
            X, edges, tile_b=tile_b, tile_n=tile_n, interpret=True
        )
        np.testing.assert_array_equal(got, ref.bin_codes(X, edges))

    def test_ties_go_to_upper_bin(self):
        # side="right" semantics: a value exactly on an edge counts that
        # edge, landing in the bin ABOVE it — both paths must agree.
        edges = jnp.asarray([[0.0, 1.0, 2.0]], jnp.float32).T.reshape(1, 3)
        X = jnp.asarray([[-1.0], [0.0], [0.5], [1.0], [2.0], [3.0]], jnp.float32)
        got = np.asarray(bin_codes_pallas(X, edges, interpret=True))[:, 0]
        np.testing.assert_array_equal(got, [0, 1, 1, 2, 3, 3])
        np.testing.assert_array_equal(
            got, np.asarray(ref.bin_codes(X, edges))[:, 0]
        )

    def test_duplicate_edges_skip_bins(self):
        # Heavy-tie features fit duplicate edges; codes jump past the
        # empty bins identically in kernel and oracle.
        edges = jnp.asarray([[1.0, 1.0, 1.0, 5.0]], jnp.float32)
        X = jnp.asarray([[0.0], [1.0], [4.0], [5.0]], jnp.float32)
        got = np.asarray(bin_codes_pallas(X, edges, interpret=True))[:, 0]
        np.testing.assert_array_equal(got, [0, 3, 3, 4])
        np.testing.assert_array_equal(
            got, np.asarray(ref.bin_codes(X, edges))[:, 0]
        )

    def test_ops_dispatch_agrees(self):
        rng = np.random.default_rng(12)
        X = jnp.asarray(rng.normal(size=(77, 13)), jnp.float32)
        edges = jnp.asarray(np.sort(rng.normal(size=(13, 7)), axis=1), jnp.float32)
        auto = np.asarray(ops.bin_codes(X, edges))
        forced = np.asarray(ops.bin_codes(X, edges, use_pallas=True))
        oracle = np.asarray(ref.bin_codes(X, edges))
        np.testing.assert_array_equal(auto, oracle)
        np.testing.assert_array_equal(forced, oracle)


class TestOpsDispatch:
    def test_ops_cpu_uses_oracle(self):
        rng = np.random.default_rng(5)
        X = jnp.asarray(rng.integers(0, 2, (50, 6)), jnp.int32)
        y = jnp.asarray(rng.integers(0, 2, 50), jnp.int32)
        auto = ops.contingency_tables(X, y, 2, 2)
        oracle = ref.contingency_tables(X, y, 2, 2)
        np.testing.assert_allclose(auto, oracle)

    def test_ops_forced_pallas_interpret(self):
        rng = np.random.default_rng(6)
        X = jnp.asarray(rng.integers(0, 3, (64, 8)), jnp.int32)
        y = jnp.asarray(rng.integers(0, 2, 64), jnp.int32)
        forced = ops.contingency_tables(X, y, 3, 2, use_pallas=True)
        oracle = ref.contingency_tables(X, y, 3, 2)
        np.testing.assert_allclose(forced, oracle)

    def test_mi_tables_end_to_end(self):
        rng = np.random.default_rng(7)
        X = jnp.asarray(rng.integers(0, 2, (200, 10)), jnp.int32)
        y = jnp.asarray(rng.integers(0, 2, 200), jnp.int32)
        got = ops.mi_tables(X, y, 2, 2, use_pallas=True)
        from repro.core import mi_from_counts

        want = mi_from_counts(ref.contingency_tables(X, y, 2, 2))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


class TestFlashAttentionKernel:
    @pytest.mark.parametrize(
        "b,s,h,kv,d,causal",
        [
            (2, 256, 8, 4, 64, True),
            (1, 128, 4, 4, 32, False),   # MHA (kv == h)
            (2, 512, 8, 2, 64, True),    # GQA group 4
            (1, 256, 8, 1, 128, True),   # MQA
        ],
    )
    def test_matches_oracle(self, b, s, h, kv, d, causal):
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (b, s, h, d), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, d))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, d))
        out = flash_attention_pallas(
            q, k, v, causal=causal, block_q=128, block_kv=128, interpret=True
        )
        want = ref.flash_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5
        )

    @pytest.mark.parametrize("bq,bkv", [(64, 128), (128, 64), (256, 256)])
    def test_block_sweep(self, bq, bkv):
        key = jax.random.PRNGKey(3)
        q = jax.random.normal(key, (1, 256, 4, 64))
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, 256, 2, 64))
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, 256, 2, 64))
        out = flash_attention_pallas(
            q, k, v, causal=True, block_q=bq, block_kv=bkv, interpret=True
        )
        want = ref.flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5
        )

    def test_bf16(self):
        key = jax.random.PRNGKey(4)
        q = jax.random.normal(key, (2, 128, 4, 64), jnp.bfloat16)
        k = jax.random.normal(jax.random.fold_in(key, 1), (2, 128, 4, 64),
                              jnp.bfloat16)
        v = jax.random.normal(jax.random.fold_in(key, 2), (2, 128, 4, 64),
                              jnp.bfloat16)
        out = flash_attention_pallas(
            q, k, v, causal=True, block_q=64, block_kv=64, interpret=True
        )
        want = ref.flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(want, np.float32),
            rtol=3e-2, atol=3e-2,
        )

    def test_matches_model_blockwise_path(self):
        from repro.models.attention import blockwise_attention

        key = jax.random.PRNGKey(5)
        q = jax.random.normal(key, (1, 512, 8, 64))
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, 512, 4, 64))
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, 512, 4, 64))
        out = flash_attention_pallas(
            q, k, v, causal=True, block_q=128, block_kv=128, interpret=True
        )
        want = blockwise_attention(q, k, v, causal=True, block_q=128,
                                   block_kv=128)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(want), rtol=3e-5, atol=3e-5
        )
