"""mRMR driver tests: reference behaviour, encoding agreement, invariants."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    MIScore,
    PearsonMIScore,
    mrmr_alternative,
    mrmr_conventional,
    mrmr_reference,
    mrmr_select,
    mrmr_custom_score,
)
from repro.data.synthetic import corral_dataset, continuous_wide_dataset


def brute_force_mrmr(X_cols: np.ndarray, y: np.ndarray, L: int, vx: int, vy: int):
    """Slow numpy mRMR for ground truth (conventional orientation input)."""
    from tests.test_scores import np_mi, np_pair_counts

    n = X_cols.shape[1]
    rel = np.array([np_mi(np_pair_counts(X_cols[:, k], y, vx, vy)) for k in range(n)])
    selected, cand = [], set(range(n))
    for l in range(L):
        best_k, best_g = None, -np.inf
        for k in sorted(cand):
            red = np.mean(
                [np_mi(np_pair_counts(X_cols[:, k], X_cols[:, j], vx, vx))
                 for j in selected]
            ) if selected else 0.0
            g = rel[k] - red
            if g > best_g + 1e-12:
                best_g, best_k = g, k
        selected.append(best_k)
        cand.remove(best_k)
    return selected


@pytest.fixture(scope="module")
def small_discrete():
    rng = np.random.default_rng(0)
    X = rng.integers(0, 2, size=(400, 12)).astype(np.int32)
    # Make col 0 highly class-informative, col 1 a near-copy of col 0
    # (redundant), col 2 moderately informative.
    y = (X[:, 0] ^ (rng.random(400) < 0.1)).astype(np.int32)
    X[:, 1] = X[:, 0] ^ (rng.random(400) < 0.05)
    X[:, 2] = y ^ (rng.random(400) < 0.3)
    return X, y


class TestReference:
    def test_matches_brute_force(self, small_discrete):
        X, y = small_discrete
        want = brute_force_mrmr(X, y, 4, 2, 2)
        score = MIScore(num_values=2, num_classes=2)
        got = mrmr_reference(jnp.asarray(X.T), jnp.asarray(y), 4, score)
        assert list(np.asarray(got.selected)) == want

    def test_incremental_equals_paper_faithful(self, small_discrete):
        X, y = small_discrete
        score = MIScore(num_values=2, num_classes=2)
        a = mrmr_reference(jnp.asarray(X.T), jnp.asarray(y), 6, score,
                           incremental=True)
        b = mrmr_reference(jnp.asarray(X.T), jnp.asarray(y), 6, score,
                           incremental=False)
        np.testing.assert_array_equal(a.selected, b.selected)
        np.testing.assert_allclose(a.gains, b.gains, rtol=1e-5, atol=1e-6)

    def test_redundant_feature_down_ranked(self, small_discrete):
        X, y = small_discrete
        score = MIScore(num_values=2, num_classes=2)
        res = mrmr_reference(jnp.asarray(X.T), jnp.asarray(y), 3, score)
        sel = list(np.asarray(res.selected))
        # col 0 first (max relevance); col 1 (its copy) must NOT be second.
        assert sel[0] == 0
        assert sel[1] != 1

    def test_unique_selection(self, small_discrete):
        X, y = small_discrete
        score = MIScore(num_values=2, num_classes=2)
        res = mrmr_reference(jnp.asarray(X.T), jnp.asarray(y), 10, score)
        sel = list(np.asarray(res.selected))
        assert len(set(sel)) == 10
        assert all(0 <= s < 12 for s in sel)


class TestEncodingAgreement:
    def test_conventional_equals_reference(self, small_discrete):
        X, y = small_discrete
        score = MIScore(num_values=2, num_classes=2)
        ref = mrmr_reference(jnp.asarray(X.T), jnp.asarray(y), 5, score)
        conv = mrmr_conventional(jnp.asarray(X), jnp.asarray(y), 5, score)
        np.testing.assert_array_equal(ref.selected, conv.selected)
        np.testing.assert_allclose(ref.gains, conv.gains, rtol=1e-4, atol=1e-5)

    def test_alternative_equals_reference(self, small_discrete):
        X, y = small_discrete
        score = MIScore(num_values=2, num_classes=2)
        ref = mrmr_reference(jnp.asarray(X.T), jnp.asarray(y), 5, score)
        alt = mrmr_alternative(jnp.asarray(X.T), jnp.asarray(y), 5, score)
        np.testing.assert_array_equal(ref.selected, alt.selected)

    def test_custom_score_path_agrees(self, small_discrete):
        X, y = small_discrete
        score = MIScore(num_values=2, num_classes=2)
        custom = mrmr_custom_score(score)
        ref = mrmr_reference(jnp.asarray(X.T), jnp.asarray(y), 4, score)
        cus = mrmr_alternative(jnp.asarray(X.T), jnp.asarray(y), 4, custom)
        np.testing.assert_array_equal(ref.selected, cus.selected)


class TestCorral:
    def test_recovers_relevant_features(self):
        X, y = corral_dataset(4000, 32, seed=1, flip_prob=0.02)
        res = mrmr_select(np.asarray(X), np.asarray(y), 8, layout="conventional")
        sel = set(np.asarray(res.selected).tolist())
        # The 8 Eq.-3 features plus the correlated col 8 dominate; require
        # most of the true 8 in the top-8 picks.
        assert len(sel & set(range(8))) >= 6

    def test_pearson_wide_dataset(self):
        X, y = continuous_wide_dataset(300, 64, seed=2)
        res = mrmr_select(
            np.asarray(X), np.asarray(y), 4,
            score=PearsonMIScore(), layout="alternative",
        )
        sel = list(np.asarray(res.selected))
        assert sel[0] == 0  # strongest signal column first
        assert 8 not in sel[:2]  # redundant shadow of col 0 not picked next


class TestSelectorAPI:
    def test_auto_layout(self):
        assert_sel = lambda X, y: mrmr_select(X, y, 2)
        rng = np.random.default_rng(3)
        X = rng.integers(0, 2, (64, 9)).astype(np.int32)
        y = rng.integers(0, 2, 64).astype(np.int32)
        res = assert_sel(X, y)
        assert res.selected.shape == (2,)

    def test_transform(self):
        from repro.core import FeatureSelector

        rng = np.random.default_rng(4)
        X = rng.integers(0, 2, (64, 9)).astype(np.int32)
        y = (X[:, 3] ^ (rng.random(64) < 0.1)).astype(np.int32)
        fs = FeatureSelector(num_select=3).fit(X, y)
        Xt = fs.transform(X)
        assert Xt.shape == (64, 3)
        assert fs.selected_[0] == 3
