"""CheckpointManager multi-process publish protocol.

Simulates N writers on one shared directory via the injectable
``process_index``/``process_count`` coordinates (no jax.distributed
needed): every process atomically lands only its own ``proc_<i>.npz``;
process 0 alone — once all shards exist — writes the manifest and swaps
the step into place.
"""

import os
import threading

import numpy as np
import pytest

from repro.runtime.checkpoint import CheckpointManager


def _mgr(d, i, n, **kw):
    return CheckpointManager(
        str(d), use_async=False, process_index=i, process_count=n, **kw
    )


def test_single_process_save_restore_roundtrip(tmp_path):
    mgr = _mgr(tmp_path, 0, 1)
    state = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
             "step": np.int32(7)}
    mgr.save(3, state)
    assert mgr.latest_step() == 3
    restored = mgr.restore(3, state)
    np.testing.assert_array_equal(np.asarray(restored["w"]), state["w"])
    assert int(restored["step"]) == 7


def test_nonzero_process_never_publishes(tmp_path):
    mgr1 = _mgr(tmp_path, 1, 2)
    mgr1.save(0, {"b": np.ones(3, np.float32)})
    # Shard landed in the tmp dir; no manifest, no final step, and the
    # step is invisible to restore-side listings.
    tmp = tmp_path / "step_00000000.tmp"
    assert (tmp / "proc_1.npz").exists()
    assert not (tmp / "manifest.json").exists()
    assert not (tmp_path / "step_00000000").exists()
    assert mgr1.all_steps() == []


def test_coordinator_publishes_once_all_shards_arrive(tmp_path):
    a = np.arange(4, dtype=np.float32)
    b = np.arange(5, dtype=np.float32) * 2
    _mgr(tmp_path, 1, 2).save(0, {"b": b})
    _mgr(tmp_path, 0, 2).save(0, {"a": a})
    final = tmp_path / "step_00000000"
    assert final.exists() and not (tmp_path / "step_00000000.tmp").exists()
    assert (final / "proc_0.npz").exists() and (final / "proc_1.npz").exists()
    # Restore merges the disjoint per-process shard files.
    restored = _mgr(tmp_path, 0, 2).restore(0, {"a": a * 0, "b": b * 0})
    np.testing.assert_array_equal(np.asarray(restored["a"]), a)
    np.testing.assert_array_equal(np.asarray(restored["b"]), b)


def test_coordinator_waits_for_straggler_thread(tmp_path):
    a = np.zeros(2, np.float32)
    b = np.ones(2, np.float32)

    def late_save():
        _mgr(tmp_path, 1, 2).save(0, {"b": b})

    t = threading.Timer(0.3, late_save)
    t.start()
    try:
        # Blocks polling until the straggler's shard lands, then publishes.
        _mgr(tmp_path, 0, 2, publish_timeout=30.0).save(0, {"a": a})
    finally:
        t.join()
    assert (tmp_path / "step_00000000" / "manifest.json").exists()
    assert _mgr(tmp_path, 0, 2).latest_step() == 0


def test_coordinator_times_out_on_missing_shard(tmp_path):
    with pytest.raises(TimeoutError, match="proc_1.npz"):
        _mgr(tmp_path, 0, 2, publish_timeout=0.3).save(
            0, {"a": np.zeros(2, np.float32)}
        )
    # Nothing was published — the torn step can never be restored.
    assert _mgr(tmp_path, 0, 2).all_steps() == []


def test_republish_same_step_replaces_cleanly(tmp_path):
    for val in (1.0, 2.0):
        arr = np.full(3, val, np.float32)
        _mgr(tmp_path, 1, 2).save(5, {"b": arr})
        _mgr(tmp_path, 0, 2).save(5, {"a": arr})
    restored = _mgr(tmp_path, 0, 2).restore(
        5, {"a": np.zeros(3, np.float32), "b": np.zeros(3, np.float32)}
    )
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.full(3, 2.0))
    np.testing.assert_array_equal(np.asarray(restored["b"]), np.full(3, 2.0))


def test_retention_gc_only_runs_on_coordinator(tmp_path):
    for step in range(5):
        _mgr(tmp_path, 1, 2).save(step, {"b": np.zeros(1, np.float32)})
        _mgr(tmp_path, 0, 2, keep=2).save(step, {"a": np.zeros(1, np.float32)})
    assert _mgr(tmp_path, 0, 2).all_steps() == [3, 4]
    # No orphaned tmp dirs linger after publication.
    assert not [n for n in os.listdir(tmp_path) if n.endswith(".tmp")]
