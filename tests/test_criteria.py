"""Criterion layer: fold semantics, registry, engine x criterion
equivalence (the api_redesign acceptance bar), and the selector read side.
"""

import dataclasses

import jax
import numpy as np
import jax.numpy as jnp
import pytest

from repro import (
    CMIMCriterion,
    Criterion,
    CustomScore,
    JMICriterion,
    MIDCriterion,
    MIQCriterion,
    MIScore,
    MRMRSelector,
    MaxRelCriterion,
    available_criteria,
    register_criterion,
)
from repro.core import mrmr_reference
from repro.core.criteria import (
    _CRITERIA,
    conditional_terms,
    marginal_terms,
    resolve_criterion,
)
from repro.core.mrmr import MRMRResult
from repro.core.selector import check_num_select, register_engine
from repro.data.sources import ArraySource
from repro.data.synthetic import corral_dataset
from repro.dist import make_mesh


@pytest.fixture(scope="module")
def corral():
    X, y = corral_dataset(2000, 32, seed=1, flip_prob=0.02)
    return np.asarray(X, np.int32), np.asarray(y)


ALL_ENCODINGS = ["reference", "conventional", "alternative", "grid"]


def fit(X, y, encoding, L=5, **kw):
    mesh = make_mesh((1, 1), ("data", "model")) if encoding == "grid" else None
    return MRMRSelector(num_select=L, encoding=encoding, mesh=mesh, **kw).fit(X, y)


class TestFoldSemantics:
    """The built-in folds compute exactly their documented formulas."""

    def test_mid_is_difference(self):
        crit = MIDCriterion()
        rel = jnp.asarray([1.0, 2.0, 3.0])
        st = crit.init_state(3)
        st = crit.update(st, jnp.asarray([0.5, 1.0, 0.0]), 0)
        st = crit.update(st, jnp.asarray([0.5, 1.0, 0.0]), 1)
        np.testing.assert_allclose(
            np.asarray(crit.objective(rel, st, 2)), [0.5, 1.0, 3.0]
        )
        # l=0: empty state, denominator clamps to 1 -> pure relevance
        np.testing.assert_allclose(
            np.asarray(crit.objective(rel, crit.init_state(3), 0)),
            np.asarray(rel),
        )

    def test_miq_is_quotient(self):
        crit = MIQCriterion()
        rel = jnp.asarray([1.0, 2.0])
        st = crit.update(crit.init_state(2), jnp.asarray([0.5, 4.0]), 0)
        np.testing.assert_allclose(
            np.asarray(crit.objective(rel, st, 1)), [2.0, 0.5]
        )

    def test_miq_first_pick_is_relevance_argmax(self, corral):
        X, y = corral
        miq = fit(X, y, "reference", criterion="miq")
        assert miq.selected_[0] == int(np.argmax(miq.scores_))

    def test_maxrel_needs_no_redundancy(self):
        crit = MaxRelCriterion()
        assert not crit.needs_redundancy
        rel = jnp.asarray([3.0, 1.0])
        st = crit.update(crit.init_state(2), jnp.asarray([9.0, 9.0]), 0)
        np.testing.assert_allclose(np.asarray(crit.objective(rel, st, 1)), rel)

    def test_maxrel_selects_top_relevance(self, corral):
        X, y = corral
        sel = fit(X, y, "reference", L=6, criterion="maxrel")
        # iterated masked argmax == stable descending relevance order
        want = np.argsort(-sel.scores_, kind="stable")[:6]
        np.testing.assert_array_equal(sel.selected_, want)


class TestRegistry:
    def test_builtins_registered(self):
        assert {"mid", "miq", "maxrel", "jmi", "cmim"} <= set(
            available_criteria()
        )

    def test_resolve(self):
        assert resolve_criterion("mid").name == "mid"
        inst = MIQCriterion()
        assert resolve_criterion(inst) is inst
        assert resolve_criterion(None).name == "mid"
        with pytest.raises(ValueError, match="unknown criterion"):
            resolve_criterion("nope")

    def test_unnamed_criterion_rejected(self):
        with pytest.raises(ValueError, match="no name"):
            register_criterion(Criterion())

    def test_name_alias_syncs_instance_name(self):
        # Registering under name= must keep provenance (.name) in sync
        # with the registry key, or result_.criterion could not be
        # round-tripped through resolve_criterion.
        try:
            register_criterion(MIQCriterion(), name="_test_alias")
            crit = resolve_criterion("_test_alias")
            assert crit.name == "_test_alias"
        finally:
            _CRITERIA.pop("_test_alias", None)

    def test_register_round_trip(self, corral):
        # The user-extensibility bar: a registered criterion is resolvable
        # by name and runs end-to-end through the front door.
        X, y = corral

        @register_criterion
        @dataclasses.dataclass(frozen=True)
        class DoublePenalty(MIDCriterion):
            name = "_test_mid2x"

            def objective(self, rel, state, l):
                denom = jnp.maximum(l, 1).astype(jnp.float32)
                return rel - 2.0 * state["red_sum"] / denom

        try:
            assert "_test_mid2x" in available_criteria()
            sel = MRMRSelector(num_select=4, criterion="_test_mid2x").fit(X, y)
            assert sel.result_.criterion == "_test_mid2x"
            assert len(set(sel.selected_.tolist())) == 4
            # doubling the penalty is not a no-op on this dataset's gains
            mid = MRMRSelector(num_select=4, criterion="mid").fit(X, y)
            assert not np.allclose(sel.gains_[1:], mid.gains_[1:])
        finally:
            _CRITERIA.pop("_test_mid2x", None)


class TestMidReproducesLegacy:
    """`mid` through the Criterion layer == the pre-criterion fold.

    The default path IS the criterion path now, so the strongest pin is
    (a) default == explicit mid == fresh MIDCriterion instance, bitwise,
    and (b) the objective trajectory equals an independently computed
    rel - red_sum/l fold from the raw score primitives.
    """

    @pytest.mark.parametrize("encoding", ALL_ENCODINGS)
    def test_default_is_mid_bitwise(self, corral, encoding):
        X, y = corral
        a = fit(X, y, encoding)
        b = fit(X, y, encoding, criterion="mid")
        c = fit(X, y, encoding, criterion=MIDCriterion())
        np.testing.assert_array_equal(a.selected_, b.selected_)
        np.testing.assert_array_equal(a.selected_, c.selected_)
        np.testing.assert_array_equal(a.gains_, b.gains_)   # bitwise
        np.testing.assert_array_equal(a.gains_, c.gains_)   # bitwise

    def test_trajectory_matches_manual_fold(self, corral):
        X, y = corral
        L = 5
        score = MIScore(2, 2)
        sel = fit(X, y, "reference", L=L)
        # independent numpy fold over the same score primitives
        Xr = jnp.asarray(X.T)
        rel = np.asarray(score.relevance(Xr, jnp.asarray(y)), np.float32)
        red_sum = np.zeros_like(rel)
        mask = np.zeros(rel.shape, bool)
        for l in range(L):
            g = rel - red_sum / np.float32(max(l, 1))
            g[mask] = -np.inf
            k = int(np.argmax(g))
            assert sel.selected_[l] == k
            # in-loop vs out-of-loop XLA fusion wiggles the last ulp or two
            np.testing.assert_allclose(sel.gains_[l], g[k], rtol=1e-5,
                                       atol=1e-6)
            mask[k] = True
            red_sum = red_sum + np.asarray(
                score.redundancy(Xr, Xr[k]), np.float32
            )

    @pytest.mark.parametrize("encoding", ["reference", "conventional"])
    def test_recompute_path_mid(self, corral, encoding):
        X, y = corral
        a = fit(X, y, encoding, L=6, incremental=True)
        b = fit(X, y, encoding, L=6, incremental=False)
        np.testing.assert_array_equal(a.selected_, b.selected_)
        np.testing.assert_allclose(a.gains_, b.gains_, rtol=1e-5, atol=1e-6)


class TestCriterionEngineAgreement:
    """Every criterion selects identically on every engine."""

    @pytest.mark.parametrize("criterion", ["miq", "maxrel", "jmi", "cmim"])
    def test_engines_agree(self, corral, criterion):
        X, y = corral
        ref = fit(X, y, "reference", criterion=criterion)
        for encoding in ALL_ENCODINGS[1:]:
            got = fit(X, y, encoding, criterion=criterion)
            np.testing.assert_array_equal(got.selected_, ref.selected_)
            # the quotient amplifies cross-engine MI ulp differences when
            # mean redundancy is tiny; selections are the acceptance bar
            np.testing.assert_allclose(got.gains_, ref.gains_,
                                       rtol=5e-3, atol=1e-5)

    @pytest.mark.parametrize("encoding", ["reference", "conventional",
                                          "alternative"])
    def test_miq_incremental_equals_recompute(self, corral, encoding):
        X, y = corral
        a = fit(X, y, encoding, L=6, criterion="miq", incremental=True)
        b = fit(X, y, encoding, L=6, criterion="miq", incremental=False)
        np.testing.assert_array_equal(a.selected_, b.selected_)

    def test_miq_differs_from_mid_somewhere(self, corral):
        # The knob must actually steer: on this seed dataset the quotient
        # form picks a different set than the difference form.
        X, y = corral
        mid = fit(X, y, "reference", criterion="mid")
        miq = fit(X, y, "reference", criterion="miq")
        assert mid.selected_.tolist() != miq.selected_.tolist()


class TestConditionalFoldSemantics:
    """JMI/CMIM folds compute exactly their documented formulas, and the
    terms helpers accept both the dict form and bare arrays."""

    def test_jmi_is_mean_gap(self):
        crit = JMICriterion()
        assert crit.needs_redundancy and crit.needs_conditional_redundancy
        rel = jnp.asarray([1.0, 2.0])
        st = crit.init_state(2)
        st = crit.update(st, dict(marginal=jnp.asarray([0.5, 1.0]),
                                  conditional=jnp.asarray([1.0, 0.5])), 0)
        st = crit.update(st, dict(marginal=jnp.asarray([0.0, 1.0]),
                                  conditional=jnp.asarray([0.5, 0.0])), 1)
        # gaps (cond - marg): [0.5, -0.5] then [0.5, -1.0]; mean over 2
        np.testing.assert_allclose(
            np.asarray(crit.objective(rel, st, 2)), [1.5, 1.25]
        )
        # l=0: empty state -> pure relevance
        np.testing.assert_allclose(
            np.asarray(crit.objective(rel, crit.init_state(2), 0)),
            np.asarray(rel),
        )

    def test_cmim_is_min_gap(self):
        crit = CMIMCriterion()
        assert crit.needs_conditional_redundancy
        rel = jnp.asarray([1.0, 2.0])
        st = crit.init_state(2)
        st = crit.update(st, dict(marginal=jnp.asarray([0.5, 1.0]),
                                  conditional=jnp.asarray([1.0, 0.5])), 0)
        st = crit.update(st, dict(marginal=jnp.asarray([0.0, 1.0]),
                                  conditional=jnp.asarray([0.5, 0.0])), 1)
        # min-fold keeps the WORST gap: [0.5, -1.0]
        np.testing.assert_allclose(
            np.asarray(crit.objective(rel, st, 2)), [1.5, 1.0]
        )

    def test_cmim_inf_identity_never_leaks(self):
        # The min-fold identity is +inf; at l=0 the objective must be pure
        # finite relevance (rel + inf would poison the argmax), and a
        # single fold must fully replace the identity.
        crit = CMIMCriterion()
        rel = jnp.asarray([3.0, 1.0])
        obj0 = np.asarray(crit.objective(rel, crit.init_state(2), 0))
        np.testing.assert_allclose(obj0, np.asarray(rel))
        assert np.isfinite(obj0).all()
        st = crit.update(crit.init_state(2),
                         dict(marginal=jnp.asarray([1.0, 1.0]),
                              conditional=jnp.asarray([1.5, 0.5])), 0)
        obj1 = np.asarray(crit.objective(rel, st, 1))
        np.testing.assert_allclose(obj1, [3.5, 0.5])
        assert np.isfinite(obj1).all()

    def test_terms_helpers(self):
        arr = jnp.asarray([1.0])
        assert marginal_terms(arr) is arr  # bare-array back-compat
        d = dict(marginal=arr, conditional=arr + 1.0)
        assert marginal_terms(d) is arr
        np.testing.assert_allclose(np.asarray(conditional_terms(d)), [2.0])
        for bad in (arr, dict(marginal=arr, conditional=None)):
            with pytest.raises(ValueError, match="conditional"):
                conditional_terms(bad)

    def test_marginal_criteria_declare_no_conditional(self):
        # The zero-cost contract hangs off this flag: if a marginal
        # criterion ever flips it, every engine starts counting 3-way
        # tables for it.
        for crit in (MIDCriterion(), MIQCriterion(), MaxRelCriterion()):
            assert not crit.needs_conditional_redundancy


class TestConditionalTrajectory:
    """Reference JMI/CMIM selections match an independent numpy fold over
    the raw score primitives (the manual-fold oracle pattern)."""

    @pytest.mark.parametrize("criterion", ["jmi", "cmim"])
    def test_trajectory_matches_manual_fold(self, corral, criterion):
        X, y = corral
        L = 5
        score = MIScore(2, 2)
        sel = fit(X, y, "reference", L=L, criterion=criterion)
        Xr = jnp.asarray(X.T)
        yj = jnp.asarray(y)
        rel = np.asarray(score.relevance(Xr, yj), np.float32)
        gap_acc = (np.zeros_like(rel) if criterion == "jmi"
                   else np.full_like(rel, np.inf))
        mask = np.zeros(rel.shape, bool)
        for l in range(L):
            if l == 0:
                g = rel.copy()
            elif criterion == "jmi":
                g = rel + gap_acc / np.float32(l)
            else:
                g = rel + gap_acc
            g[mask] = -np.inf
            k = int(np.argmax(g))
            assert sel.selected_[l] == k
            np.testing.assert_allclose(sel.gains_[l], g[k], rtol=1e-5,
                                       atol=1e-6)
            mask[k] = True
            terms = score.redundancy_terms(Xr, Xr[k], yj, conditional=True)
            gap = (np.asarray(terms["conditional"], np.float32)
                   - np.asarray(terms["marginal"], np.float32))
            gap_acc = (gap_acc + gap if criterion == "jmi"
                       else np.minimum(gap_acc, gap))

    @pytest.mark.parametrize("criterion", ["jmi", "cmim"])
    def test_incremental_equals_recompute(self, corral, criterion):
        X, y = corral
        a = fit(X, y, "reference", L=6, criterion=criterion,
                incremental=True)
        b = fit(X, y, "reference", L=6, criterion=criterion,
                incremental=False)
        np.testing.assert_array_equal(a.selected_, b.selected_)
        np.testing.assert_allclose(a.gains_, b.gains_, rtol=1e-5, atol=1e-6)

    def test_jmi_cmim_steer_differently(self, corral):
        # The conditional fold must actually change selections vs mid on
        # the seed dataset, and the mean/min folds must differ from each
        # other somewhere in the trajectory.
        X, y = corral
        mid = fit(X, y, "reference", L=6, criterion="mid")
        jmi = fit(X, y, "reference", L=6, criterion="jmi")
        cmim = fit(X, y, "reference", L=6, criterion="cmim")
        assert not np.array_equal(jmi.gains_, mid.gains_)
        assert not np.array_equal(jmi.gains_, cmim.gains_)

    def test_cmim_tie_break_lowest_id(self, corral):
        # Duplicate columns produce exactly tied objectives; the argmax
        # contract (toward the lowest id) must hold for the min-fold too,
        # on both the compiled and the host-driven fold.
        X, y = corral
        X = X.copy()
        X[:, 12] = X[:, 5]
        ref = fit(X, y, "reference", L=6, criterion="cmim")
        got = MRMRSelector(num_select=6, criterion="cmim",
                           block_obs=512).fit(ArraySource(X, y))
        np.testing.assert_array_equal(got.selected_, ref.selected_)
        picks = ref.selected_.tolist()
        if 12 in picks:
            assert 5 in picks and picks.index(5) < picks.index(12)


class TestConditionalStreaming:
    """Streaming JMI/CMIM == in-memory, at dividing / non-dividing /
    oversized block sizes, under candidate batching, and with bins=."""

    @pytest.mark.parametrize("criterion", ["jmi", "cmim"])
    @pytest.mark.parametrize("block_obs", [128, 999, 4096])
    def test_streaming_matches_reference(self, corral, criterion,
                                         block_obs):
        X, y = corral
        ref = fit(X, y, "reference", criterion=criterion)
        got = MRMRSelector(num_select=5, criterion=criterion,
                           block_obs=block_obs).fit(ArraySource(X, y))
        assert got.plan_.encoding == "streaming"
        np.testing.assert_array_equal(got.selected_, ref.selected_)
        np.testing.assert_allclose(got.gains_, ref.gains_, rtol=1e-4,
                                   atol=1e-5)

    @pytest.mark.parametrize("criterion", ["jmi", "cmim"])
    @pytest.mark.parametrize("q", [2, 4])
    def test_batched_candidates_bitwise(self, corral, criterion, q):
        X, y = corral
        plain = MRMRSelector(num_select=5, criterion=criterion,
                             block_obs=512).fit(ArraySource(X, y))
        batched = MRMRSelector(num_select=5, criterion=criterion,
                               block_obs=512,
                               batch_candidates=q).fit(ArraySource(X, y))
        np.testing.assert_array_equal(batched.selected_, plain.selected_)
        np.testing.assert_array_equal(batched.gains_, plain.gains_)
        assert batched.result_.io["passes"] <= plain.result_.io["passes"]

    def test_state_bytes_ledger(self, corral):
        # The zero-cost contract, asserted in bytes: a conditional
        # criterion's statistics state carries the class axis (d_c x the
        # pair state), a marginal criterion's does not.
        X, y = corral
        n, v, c = X.shape[1], 2, 2

        def io_of(criterion):
            sel = MRMRSelector(num_select=4, criterion=criterion,
                               block_obs=512).fit(ArraySource(X, y))
            return sel.result_.io

        mid, jmi, cmim = io_of("mid"), io_of("jmi"), io_of("cmim")
        # int32 counts: relevance (n, v, c), marginal pair (n, v, v),
        # conditional pair (n, v, v*c) -- peak is the redundancy state
        assert mid["state_bytes"] == n * v * max(v, c) * 4
        assert jmi["state_bytes"] == n * v * v * c * 4
        assert cmim["state_bytes"] == jmi["state_bytes"]
        # the class axis rides the SAME passes -- no extra I/O
        assert jmi["passes"] == mid["passes"]
        assert jmi["bytes_read"] == mid["bytes_read"]

    @pytest.mark.parametrize("criterion", ["jmi", "cmim"])
    def test_bins_composition(self, criterion):
        # Continuous data -> quantile bins -> conditional criterion: the
        # in-memory binned fit and the streamed fused-encode fit agree.
        rng = np.random.default_rng(7)
        X = rng.normal(size=(900, 16)).astype(np.float32)
        y = (X[:, 3] + 0.5 * X[:, 8] > 0).astype(np.int32)
        a = MRMRSelector(num_select=4, criterion=criterion, bins=8).fit(X, y)
        b = MRMRSelector(num_select=4, criterion=criterion, bins=8,
                         block_obs=256).fit(ArraySource(X, y))
        assert b.plan_.encoding == "streaming"
        np.testing.assert_array_equal(a.selected_, b.selected_)
        assert 3 in a.selected_.tolist()

    @pytest.mark.parametrize("criterion", ["jmi", "cmim"])
    def test_obs_sharded_mesh(self, corral, criterion):
        # Tall regime: blocks shard over the data axis (1 device locally,
        # 8 in CI); the psum'd 3-way state must match the reference.
        X, y = corral
        mesh = make_mesh((len(jax.devices()),), ("data",))
        got = MRMRSelector(num_select=4, criterion=criterion,
                           block_obs=512, mesh=mesh).fit(ArraySource(X, y))
        ref = fit(X, y, "reference", L=4, criterion=criterion)
        np.testing.assert_array_equal(got.selected_, ref.selected_)
        np.testing.assert_allclose(got.gains_, ref.gains_, rtol=1e-4,
                                   atol=1e-5)

    def test_feature_sharded_conditional_state(self):
        # Wide regime: the (n, v, v*c) conditional statistics state shards
        # over the feature axis like every other leaf.
        from repro.data.sources import CorralSource

        X, y = CorralSource(256, 1024, seed=5).materialize()
        want = MRMRSelector(num_select=4, criterion="jmi",
                            encoding="alternative").fit(X, y)
        mesh = make_mesh((len(jax.devices()),), ("model",))
        got = MRMRSelector(num_select=4, criterion="jmi", block_obs=100,
                           mesh=mesh).fit(ArraySource(X, y))
        assert got.plan_.feat_axes == ("model",)
        np.testing.assert_array_equal(got.selected_, want.selected_)
        np.testing.assert_allclose(got.gains_, want.gains_, rtol=1e-4,
                                   atol=1e-5)

    def test_grid_2d_mesh(self, corral):
        # 2-D obs x feat grid: conditional state pvaried over feat axes,
        # blocks split over both.
        X, y = corral
        mesh = make_mesh((1, len(jax.devices())), ("data", "model"))
        got = MRMRSelector(num_select=4, criterion="cmim", block_obs=512,
                           mesh=mesh).fit(ArraySource(X, y))
        ref = fit(X, y, "reference", L=4, criterion="cmim")
        np.testing.assert_array_equal(got.selected_, ref.selected_)


class TestConditionalGuards:
    def test_pearson_rejects_conditional_in_memory(self):
        rng = np.random.default_rng(0)
        X = rng.normal(size=(200, 8)).astype(np.float32)
        y = (X[:, 0] > 0).astype(np.int32)
        with pytest.raises(ValueError, match="class-conditioned"):
            MRMRSelector(num_select=2, criterion="jmi").fit(X, y)

    def test_pearson_rejects_conditional_streaming(self):
        rng = np.random.default_rng(0)
        X = rng.normal(size=(200, 8)).astype(np.float32)
        y = (X[:, 0] > 0).astype(np.int32)
        with pytest.raises(ValueError, match="bins="):
            MRMRSelector(num_select=2, criterion="cmim").fit(
                ArraySource(X, y)
            )

    def test_score_without_conditional_decomposition(self, corral):
        X, y = corral
        from repro.core.mrmr import check_conditional_support
        from repro.core.scores import PearsonMIScore

        check_conditional_support(MIScore(2, 2), resolve_criterion("jmi"))
        check_conditional_support(PearsonMIScore(),
                                  resolve_criterion("mid"))
        with pytest.raises(ValueError, match="conditional"):
            check_conditional_support(PearsonMIScore(),
                                      resolve_criterion("cmim"))


class TestGuards:
    def test_custom_score_rejects_non_mid(self, corral):
        X, y = corral
        score = CustomScore(get_result=lambda v, c, s, n: jnp.float32(0))
        with pytest.raises(ValueError, match="CustomScore"):
            MRMRSelector(num_select=2, score=score, criterion="miq").fit(X, y)

    def test_unknown_criterion_fails_at_fit(self, corral):
        X, y = corral
        with pytest.raises(ValueError, match="unknown criterion"):
            MRMRSelector(num_select=2, criterion="typo").fit(X, y)

    def test_check_num_select(self):
        check_num_select(1, 1)
        for bad in (0, -3, 5):
            with pytest.raises(ValueError, match="out of range"):
                check_num_select(bad, 4)


class TestResultReport:
    def test_rich_result_fields(self, corral):
        X, y = corral
        score = MIScore(2, 2)
        res = mrmr_reference(jnp.asarray(X.T), jnp.asarray(y), 4, score,
                             criterion="miq")
        assert res.criterion == "miq" and res.engine == "reference"
        assert res.relevance.shape == (X.shape[1],)
        np.testing.assert_allclose(
            np.asarray(res.relevance),
            np.asarray(score.relevance(jnp.asarray(X.T), jnp.asarray(y))),
            rtol=1e-6,
        )
        np.testing.assert_array_equal(
            np.asarray(res.objective_trajectory), np.asarray(res.gains)
        )

    def test_custom_score_nan_relevance(self, corral):
        from repro.core import mrmr_custom_score

        X, y = corral
        custom = mrmr_custom_score(MIScore(2, 2))
        sel = MRMRSelector(num_select=3, score=custom).fit(X, y)
        assert np.isnan(sel.scores_).all()
        assert sel.result_.engine == "alternative"  # custom -> alternative


class TestSelectorReadSide:
    @pytest.mark.parametrize("encoding", ALL_ENCODINGS)
    def test_in_memory_read_side(self, corral, encoding):
        X, y = corral
        L = 5
        sel = fit(X, y, encoding, L=L)
        n = X.shape[1]
        assert sel.n_features_in_ == n
        assert sel.scores_.shape == (n,) and sel.scores_.dtype == np.float32
        # relevance VALUES must survive sharded assembly (out_specs concat
        # order on feature-sharded engines under forced multi-device runs)
        want = np.asarray(
            MIScore(2, 2).relevance(jnp.asarray(X.T), jnp.asarray(y))
        )
        np.testing.assert_allclose(sel.scores_, want, rtol=1e-4, atol=1e-6)
        # ranking: selected get 1..L in pick order, the rest share L+1
        assert sel.ranking_.shape == (n,)
        for rank, feat in enumerate(sel.selected_, start=1):
            assert sel.ranking_[feat] == rank
        assert (sel.ranking_[sel.get_support() == False] == L + 1).all()  # noqa: E712
        # support: boolean mask <-> ascending indices
        mask = sel.get_support()
        assert mask.dtype == bool and mask.sum() == L
        np.testing.assert_array_equal(
            sel.get_support(indices=True), np.sort(sel.selected_)
        )

    def test_streaming_read_side(self, corral):
        from repro.data.sources import ArraySource

        X, y = corral
        sel = MRMRSelector(num_select=4, block_obs=300).fit(ArraySource(X, y))
        assert sel.plan_.encoding == "streaming"
        assert sel.scores_.shape == (X.shape[1],)
        assert sel.result_.engine == "streaming"
        assert sel.get_support().sum() == 4
        in_mem = MRMRSelector(num_select=4).fit(X, y)
        np.testing.assert_allclose(sel.scores_, in_mem.scores_,
                                   rtol=1e-5, atol=1e-6)

    def test_get_support_before_fit_raises(self):
        with pytest.raises(RuntimeError, match="fit"):
            MRMRSelector(num_select=2).get_support()

    def test_stub_engine_without_relevance(self, corral):
        # Engines predating the rich report return MRMRResult(sel, gains);
        # the selector must still populate ranking_/support and leave
        # scores_ None rather than crash.
        X, y = corral

        @register_engine("_test_stub_crit")
        def stub(X, y, *, num_select, plan, mesh):
            return MRMRResult(
                selected=jnp.arange(num_select, dtype=jnp.int32),
                gains=jnp.zeros((num_select,), jnp.float32),
            )

        try:
            sel = MRMRSelector(num_select=3, encoding="_test_stub_crit",
                               criterion="miq").fit(X, y)
            assert sel.scores_ is None
            assert sel.result_.engine == "_test_stub_crit"
            # criterion provenance backfills from the plan, not "mid"
            assert sel.result_.criterion == "miq"
            np.testing.assert_array_equal(sel.get_support(indices=True),
                                          [0, 1, 2])
        finally:
            from repro.core import selector as selector_mod

            selector_mod._ENGINES.pop("_test_stub_crit", None)


class TestSummedFoldCriteria:
    """MIFS/CIFE/ICAP: the un-normalised-sum family (Brown et al.'s
    unified frame at β=γ=1) — fold formulas, registry, and engine
    agreement including streaming."""

    def test_mifs_is_summed_redundancy(self):
        from repro import MIFSCriterion

        crit = MIFSCriterion()
        assert crit.needs_redundancy
        assert not crit.needs_conditional_redundancy
        rel = jnp.asarray([1.0, 2.0, 3.0])
        st = crit.init_state(3)
        st = crit.update(st, jnp.asarray([0.5, 1.0, 0.0]), 0)
        st = crit.update(st, jnp.asarray([0.5, 1.0, 0.0]), 1)
        # Sum, NOT mean: penalty 1.0 / 2.0 / 0.0 (mid would halve it).
        np.testing.assert_allclose(
            np.asarray(crit.objective(rel, st, 2)), [0.0, 0.0, 3.0]
        )
        np.testing.assert_allclose(
            np.asarray(crit.objective(rel, crit.init_state(3), 0)),
            np.asarray(rel),
        )

    def test_cife_is_summed_gap(self):
        from repro import CIFECriterion

        crit = CIFECriterion()
        assert crit.needs_conditional_redundancy
        rel = jnp.asarray([1.0, 2.0])
        st = crit.init_state(2)
        st = crit.update(st, dict(marginal=jnp.asarray([0.5, 1.0]),
                                  conditional=jnp.asarray([1.0, 0.5])), 0)
        st = crit.update(st, dict(marginal=jnp.asarray([0.0, 1.0]),
                                  conditional=jnp.asarray([0.5, 0.0])), 1)
        # gaps (cond - marg): [0.5, -0.5] + [0.5, -1.0], summed not meaned
        np.testing.assert_allclose(
            np.asarray(crit.objective(rel, st, 2)), [2.0, 0.5]
        )

    def test_icap_caps_at_zero(self):
        from repro import ICAPCriterion

        crit = ICAPCriterion()
        assert crit.needs_conditional_redundancy
        rel = jnp.asarray([1.0, 2.0])
        st = crit.init_state(2)
        # feature 0: class explains the dependence (cond > marg) -> no
        # penalty; feature 1: unexplained redundancy 0.5 -> penalised.
        st = crit.update(st, dict(marginal=jnp.asarray([0.5, 1.0]),
                                  conditional=jnp.asarray([1.0, 0.5])), 0)
        np.testing.assert_allclose(
            np.asarray(crit.objective(rel, st, 1)), [1.0, 1.5]
        )
        # Synergy never accumulates negative penalty across folds.
        st = crit.update(st, dict(marginal=jnp.asarray([0.0, 0.0]),
                                  conditional=jnp.asarray([2.0, 2.0])), 1)
        np.testing.assert_allclose(
            np.asarray(crit.objective(rel, st, 2)), [1.0, 1.5]
        )

    def test_registered(self):
        names = available_criteria()
        for name in ("mifs", "cife", "icap"):
            assert name in names
            assert resolve_criterion(name).name == name

    @pytest.mark.parametrize("criterion", ["mifs", "cife", "icap"])
    def test_engines_agree(self, corral, criterion):
        X, y = corral
        ref = fit(X, y, "reference", criterion=criterion)
        for encoding in ALL_ENCODINGS[1:]:
            got = fit(X, y, encoding, criterion=criterion)
            np.testing.assert_array_equal(got.selected_, ref.selected_)
            np.testing.assert_allclose(got.gains_, ref.gains_,
                                       rtol=5e-3, atol=1e-5)

    @pytest.mark.parametrize("criterion", ["mifs", "cife", "icap"])
    def test_streaming_matches_reference(self, corral, criterion):
        X, y = corral
        ref = fit(X, y, "reference", criterion=criterion)
        got = MRMRSelector(num_select=5, criterion=criterion,
                           block_obs=999).fit(ArraySource(X, y))
        assert got.plan_.encoding == "streaming"
        np.testing.assert_array_equal(got.selected_, ref.selected_)
        np.testing.assert_allclose(got.gains_, ref.gains_, rtol=1e-4,
                                   atol=1e-5)

    def test_mifs_diverges_from_mid_late(self, corral):
        # The growing un-normalised penalty must actually steer: on the
        # seed dataset MIFS and mid disagree somewhere in a longer fit.
        X, y = corral
        mid = fit(X, y, "reference", L=8, criterion="mid")
        mifs = fit(X, y, "reference", L=8, criterion="mifs")
        assert mid.selected_.tolist() != mifs.selected_.tolist()
