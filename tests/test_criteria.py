"""Criterion layer: fold semantics, registry, engine x criterion
equivalence (the api_redesign acceptance bar), and the selector read side.
"""

import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro import (
    Criterion,
    CustomScore,
    MIDCriterion,
    MIQCriterion,
    MIScore,
    MRMRSelector,
    MaxRelCriterion,
    available_criteria,
    register_criterion,
)
from repro.core import mrmr_reference
from repro.core.criteria import _CRITERIA, resolve_criterion
from repro.core.mrmr import MRMRResult
from repro.core.selector import check_num_select, register_engine
from repro.data.synthetic import corral_dataset
from repro.dist import make_mesh


@pytest.fixture(scope="module")
def corral():
    X, y = corral_dataset(2000, 32, seed=1, flip_prob=0.02)
    return np.asarray(X, np.int32), np.asarray(y)


ALL_ENCODINGS = ["reference", "conventional", "alternative", "grid"]


def fit(X, y, encoding, L=5, **kw):
    mesh = make_mesh((1, 1), ("data", "model")) if encoding == "grid" else None
    return MRMRSelector(num_select=L, encoding=encoding, mesh=mesh, **kw).fit(X, y)


class TestFoldSemantics:
    """The built-in folds compute exactly their documented formulas."""

    def test_mid_is_difference(self):
        crit = MIDCriterion()
        rel = jnp.asarray([1.0, 2.0, 3.0])
        st = crit.init_state(3)
        st = crit.update(st, jnp.asarray([0.5, 1.0, 0.0]), 0)
        st = crit.update(st, jnp.asarray([0.5, 1.0, 0.0]), 1)
        np.testing.assert_allclose(
            np.asarray(crit.objective(rel, st, 2)), [0.5, 1.0, 3.0]
        )
        # l=0: empty state, denominator clamps to 1 -> pure relevance
        np.testing.assert_allclose(
            np.asarray(crit.objective(rel, crit.init_state(3), 0)),
            np.asarray(rel),
        )

    def test_miq_is_quotient(self):
        crit = MIQCriterion()
        rel = jnp.asarray([1.0, 2.0])
        st = crit.update(crit.init_state(2), jnp.asarray([0.5, 4.0]), 0)
        np.testing.assert_allclose(
            np.asarray(crit.objective(rel, st, 1)), [2.0, 0.5]
        )

    def test_miq_first_pick_is_relevance_argmax(self, corral):
        X, y = corral
        miq = fit(X, y, "reference", criterion="miq")
        assert miq.selected_[0] == int(np.argmax(miq.scores_))

    def test_maxrel_needs_no_redundancy(self):
        crit = MaxRelCriterion()
        assert not crit.needs_redundancy
        rel = jnp.asarray([3.0, 1.0])
        st = crit.update(crit.init_state(2), jnp.asarray([9.0, 9.0]), 0)
        np.testing.assert_allclose(np.asarray(crit.objective(rel, st, 1)), rel)

    def test_maxrel_selects_top_relevance(self, corral):
        X, y = corral
        sel = fit(X, y, "reference", L=6, criterion="maxrel")
        # iterated masked argmax == stable descending relevance order
        want = np.argsort(-sel.scores_, kind="stable")[:6]
        np.testing.assert_array_equal(sel.selected_, want)


class TestRegistry:
    def test_builtins_registered(self):
        assert {"mid", "miq", "maxrel"} <= set(available_criteria())

    def test_resolve(self):
        assert resolve_criterion("mid").name == "mid"
        inst = MIQCriterion()
        assert resolve_criterion(inst) is inst
        assert resolve_criterion(None).name == "mid"
        with pytest.raises(ValueError, match="unknown criterion"):
            resolve_criterion("nope")

    def test_unnamed_criterion_rejected(self):
        with pytest.raises(ValueError, match="no name"):
            register_criterion(Criterion())

    def test_name_alias_syncs_instance_name(self):
        # Registering under name= must keep provenance (.name) in sync
        # with the registry key, or result_.criterion could not be
        # round-tripped through resolve_criterion.
        try:
            register_criterion(MIQCriterion(), name="_test_alias")
            crit = resolve_criterion("_test_alias")
            assert crit.name == "_test_alias"
        finally:
            _CRITERIA.pop("_test_alias", None)

    def test_register_round_trip(self, corral):
        # The user-extensibility bar: a registered criterion is resolvable
        # by name and runs end-to-end through the front door.
        X, y = corral

        @register_criterion
        @dataclasses.dataclass(frozen=True)
        class DoublePenalty(MIDCriterion):
            name = "_test_mid2x"

            def objective(self, rel, state, l):
                denom = jnp.maximum(l, 1).astype(jnp.float32)
                return rel - 2.0 * state["red_sum"] / denom

        try:
            assert "_test_mid2x" in available_criteria()
            sel = MRMRSelector(num_select=4, criterion="_test_mid2x").fit(X, y)
            assert sel.result_.criterion == "_test_mid2x"
            assert len(set(sel.selected_.tolist())) == 4
            # doubling the penalty is not a no-op on this dataset's gains
            mid = MRMRSelector(num_select=4, criterion="mid").fit(X, y)
            assert not np.allclose(sel.gains_[1:], mid.gains_[1:])
        finally:
            _CRITERIA.pop("_test_mid2x", None)


class TestMidReproducesLegacy:
    """`mid` through the Criterion layer == the pre-criterion fold.

    The default path IS the criterion path now, so the strongest pin is
    (a) default == explicit mid == fresh MIDCriterion instance, bitwise,
    and (b) the objective trajectory equals an independently computed
    rel - red_sum/l fold from the raw score primitives.
    """

    @pytest.mark.parametrize("encoding", ALL_ENCODINGS)
    def test_default_is_mid_bitwise(self, corral, encoding):
        X, y = corral
        a = fit(X, y, encoding)
        b = fit(X, y, encoding, criterion="mid")
        c = fit(X, y, encoding, criterion=MIDCriterion())
        np.testing.assert_array_equal(a.selected_, b.selected_)
        np.testing.assert_array_equal(a.selected_, c.selected_)
        np.testing.assert_array_equal(a.gains_, b.gains_)   # bitwise
        np.testing.assert_array_equal(a.gains_, c.gains_)   # bitwise

    def test_trajectory_matches_manual_fold(self, corral):
        X, y = corral
        L = 5
        score = MIScore(2, 2)
        sel = fit(X, y, "reference", L=L)
        # independent numpy fold over the same score primitives
        Xr = jnp.asarray(X.T)
        rel = np.asarray(score.relevance(Xr, jnp.asarray(y)), np.float32)
        red_sum = np.zeros_like(rel)
        mask = np.zeros(rel.shape, bool)
        for l in range(L):
            g = rel - red_sum / np.float32(max(l, 1))
            g[mask] = -np.inf
            k = int(np.argmax(g))
            assert sel.selected_[l] == k
            # in-loop vs out-of-loop XLA fusion wiggles the last ulp or two
            np.testing.assert_allclose(sel.gains_[l], g[k], rtol=1e-5,
                                       atol=1e-6)
            mask[k] = True
            red_sum = red_sum + np.asarray(
                score.redundancy(Xr, Xr[k]), np.float32
            )

    @pytest.mark.parametrize("encoding", ["reference", "conventional"])
    def test_recompute_path_mid(self, corral, encoding):
        X, y = corral
        a = fit(X, y, encoding, L=6, incremental=True)
        b = fit(X, y, encoding, L=6, incremental=False)
        np.testing.assert_array_equal(a.selected_, b.selected_)
        np.testing.assert_allclose(a.gains_, b.gains_, rtol=1e-5, atol=1e-6)


class TestCriterionEngineAgreement:
    """Every criterion selects identically on every engine."""

    @pytest.mark.parametrize("criterion", ["miq", "maxrel"])
    def test_engines_agree(self, corral, criterion):
        X, y = corral
        ref = fit(X, y, "reference", criterion=criterion)
        for encoding in ALL_ENCODINGS[1:]:
            got = fit(X, y, encoding, criterion=criterion)
            np.testing.assert_array_equal(got.selected_, ref.selected_)
            # the quotient amplifies cross-engine MI ulp differences when
            # mean redundancy is tiny; selections are the acceptance bar
            np.testing.assert_allclose(got.gains_, ref.gains_,
                                       rtol=5e-3, atol=1e-5)

    @pytest.mark.parametrize("encoding", ["reference", "conventional",
                                          "alternative"])
    def test_miq_incremental_equals_recompute(self, corral, encoding):
        X, y = corral
        a = fit(X, y, encoding, L=6, criterion="miq", incremental=True)
        b = fit(X, y, encoding, L=6, criterion="miq", incremental=False)
        np.testing.assert_array_equal(a.selected_, b.selected_)

    def test_miq_differs_from_mid_somewhere(self, corral):
        # The knob must actually steer: on this seed dataset the quotient
        # form picks a different set than the difference form.
        X, y = corral
        mid = fit(X, y, "reference", criterion="mid")
        miq = fit(X, y, "reference", criterion="miq")
        assert mid.selected_.tolist() != miq.selected_.tolist()


class TestGuards:
    def test_custom_score_rejects_non_mid(self, corral):
        X, y = corral
        score = CustomScore(get_result=lambda v, c, s, n: jnp.float32(0))
        with pytest.raises(ValueError, match="CustomScore"):
            MRMRSelector(num_select=2, score=score, criterion="miq").fit(X, y)

    def test_unknown_criterion_fails_at_fit(self, corral):
        X, y = corral
        with pytest.raises(ValueError, match="unknown criterion"):
            MRMRSelector(num_select=2, criterion="typo").fit(X, y)

    def test_check_num_select(self):
        check_num_select(1, 1)
        for bad in (0, -3, 5):
            with pytest.raises(ValueError, match="out of range"):
                check_num_select(bad, 4)


class TestResultReport:
    def test_rich_result_fields(self, corral):
        X, y = corral
        score = MIScore(2, 2)
        res = mrmr_reference(jnp.asarray(X.T), jnp.asarray(y), 4, score,
                             criterion="miq")
        assert res.criterion == "miq" and res.engine == "reference"
        assert res.relevance.shape == (X.shape[1],)
        np.testing.assert_allclose(
            np.asarray(res.relevance),
            np.asarray(score.relevance(jnp.asarray(X.T), jnp.asarray(y))),
            rtol=1e-6,
        )
        np.testing.assert_array_equal(
            np.asarray(res.objective_trajectory), np.asarray(res.gains)
        )

    def test_custom_score_nan_relevance(self, corral):
        from repro.core import mrmr_custom_score

        X, y = corral
        custom = mrmr_custom_score(MIScore(2, 2))
        sel = MRMRSelector(num_select=3, score=custom).fit(X, y)
        assert np.isnan(sel.scores_).all()
        assert sel.result_.engine == "alternative"  # custom -> alternative


class TestSelectorReadSide:
    @pytest.mark.parametrize("encoding", ALL_ENCODINGS)
    def test_in_memory_read_side(self, corral, encoding):
        X, y = corral
        L = 5
        sel = fit(X, y, encoding, L=L)
        n = X.shape[1]
        assert sel.n_features_in_ == n
        assert sel.scores_.shape == (n,) and sel.scores_.dtype == np.float32
        # relevance VALUES must survive sharded assembly (out_specs concat
        # order on feature-sharded engines under forced multi-device runs)
        want = np.asarray(
            MIScore(2, 2).relevance(jnp.asarray(X.T), jnp.asarray(y))
        )
        np.testing.assert_allclose(sel.scores_, want, rtol=1e-4, atol=1e-6)
        # ranking: selected get 1..L in pick order, the rest share L+1
        assert sel.ranking_.shape == (n,)
        for rank, feat in enumerate(sel.selected_, start=1):
            assert sel.ranking_[feat] == rank
        assert (sel.ranking_[sel.get_support() == False] == L + 1).all()  # noqa: E712
        # support: boolean mask <-> ascending indices
        mask = sel.get_support()
        assert mask.dtype == bool and mask.sum() == L
        np.testing.assert_array_equal(
            sel.get_support(indices=True), np.sort(sel.selected_)
        )

    def test_streaming_read_side(self, corral):
        from repro.data.sources import ArraySource

        X, y = corral
        sel = MRMRSelector(num_select=4, block_obs=300).fit(ArraySource(X, y))
        assert sel.plan_.encoding == "streaming"
        assert sel.scores_.shape == (X.shape[1],)
        assert sel.result_.engine == "streaming"
        assert sel.get_support().sum() == 4
        in_mem = MRMRSelector(num_select=4).fit(X, y)
        np.testing.assert_allclose(sel.scores_, in_mem.scores_,
                                   rtol=1e-5, atol=1e-6)

    def test_get_support_before_fit_raises(self):
        with pytest.raises(RuntimeError, match="fit"):
            MRMRSelector(num_select=2).get_support()

    def test_stub_engine_without_relevance(self, corral):
        # Engines predating the rich report return MRMRResult(sel, gains);
        # the selector must still populate ranking_/support and leave
        # scores_ None rather than crash.
        X, y = corral

        @register_engine("_test_stub_crit")
        def stub(X, y, *, num_select, plan, mesh):
            return MRMRResult(
                selected=jnp.arange(num_select, dtype=jnp.int32),
                gains=jnp.zeros((num_select,), jnp.float32),
            )

        try:
            sel = MRMRSelector(num_select=3, encoding="_test_stub_crit",
                               criterion="miq").fit(X, y)
            assert sel.scores_ is None
            assert sel.result_.engine == "_test_stub_crit"
            # criterion provenance backfills from the plan, not "mid"
            assert sel.result_.criterion == "miq"
            np.testing.assert_array_equal(sel.get_support(indices=True),
                                          [0, 1, 2])
        finally:
            from repro.core import selector as selector_mod

            selector_mod._ENGINES.pop("_test_stub_crit", None)
