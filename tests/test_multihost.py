"""Multi-host map-reduce: shard resolution, shard-windowed block streams,
spill namespacing, capability guards, and the 2-/4-process end-to-end
(selection bitwise-identical to the single-process streaming engine)."""

import json
import os
import pathlib
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro.core.scores import MIScore, PearsonMIScore, ScoreFn
from repro.data.binning import BinnedSource
from repro.data.block_cache import BlockCacheSource
from repro.data.sources import ArraySource, CorralSource, ShardSource
from repro.dist.multihost import (
    HostCollectives,
    factor_host_grid,
    resolve_host_shards,
    split_range,
)

_HERE = pathlib.Path(__file__).parent
_SRC = str(_HERE.parent / "src")


# ---------------------------------------------------------------------------
# split_range
# ---------------------------------------------------------------------------

def test_split_range_covers_contiguously_and_balances():
    for total in (1, 7, 24, 1024, 10001):
        for parts in (1, 2, 3, 4, 7):
            ranges = [split_range(total, parts, i) for i in range(parts)]
            assert ranges[0][0] == 0 and ranges[-1][1] == total
            for (_, hi), (lo, _) in zip(ranges, ranges[1:]):
                assert hi == lo  # contiguous, no gap or overlap
            widths = [hi - lo for lo, hi in ranges]
            assert max(widths) - min(widths) <= 1


def test_split_range_uneven_and_errors():
    assert split_range(10001, 2, 0) == (0, 5001)
    assert split_range(10001, 2, 1) == (5001, 10001)
    with pytest.raises(ValueError):
        split_range(10, 2, 2)
    with pytest.raises(ValueError):
        split_range(10, 2, -1)


# ---------------------------------------------------------------------------
# resolve_host_shards — the §III rule across hosts
# ---------------------------------------------------------------------------

def test_tall_partitions_observations():
    for i, obs in [(0, (0, 3000)), (1, (3000, 6000))]:
        s = resolve_host_shards(6000, 24, 2, i)
        assert s.grid == (2, 1)
        assert s.obs_range == obs and s.col_range == (0, 24)
        assert s.partitions_obs and not s.partitions_cols


def test_wide_partitions_columns():
    for i, cols in [(0, (0, 512)), (1, (512, 1024))]:
        s = resolve_host_shards(192, 1024, 2, i)
        assert s.grid == (1, 2)
        assert s.obs_range == (0, 192) and s.col_range == cols


def test_both_large_gets_2d_grid():
    for i in range(4):
        s = resolve_host_shards(5000, 5000, 4, i)
        assert s.grid == (2, 2)
        assert s.obs_range == split_range(5000, 2, i // 2)
        assert s.col_range == split_range(5000, 2, i % 2)
        assert (s.obs_coord, s.feat_coord) == (i // 2, i % 2)
    assert factor_host_grid(5000, 5000, 4) == (2, 2)


def test_both_large_two_hosts_falls_back_single_axis():
    # Square data, 2 hosts: no 2-D factorisation (min extent would be 1),
    # aspect >= 1 biases toward the observation split.
    s = resolve_host_shards(1200, 1200, 2, 0)
    assert s.grid == (2, 1)


def test_uneven_rows_split():
    a = resolve_host_shards(10001, 24, 2, 0)
    b = resolve_host_shards(10001, 24, 2, 1)
    assert a.obs_range == (0, 5001) and b.obs_range == (5001, 10001)
    assert a.local_obs - b.local_obs == 1


def test_single_host_degenerates_to_full_ranges():
    s = resolve_host_shards(100, 10, 1, 0)
    assert s.grid == (1, 1) and s.is_single_host
    assert s.obs_range == (0, 100) and s.col_range == (0, 10)


def test_explicit_grid_override_and_guards():
    s = resolve_host_shards(6000, 24, 2, 1, grid=(1, 2))
    assert s.grid == (1, 2) and s.col_range == (12, 24)
    with pytest.raises(ValueError, match="does not factor"):
        resolve_host_shards(100, 10, 4, 0, grid=(3, 1))
    with pytest.raises(ValueError, match="over-partitions"):
        resolve_host_shards(4, 10, 8, 0, grid=(8, 1))
    with pytest.raises(ValueError):
        resolve_host_shards(100, 10, 2, 2)  # host_id out of range


def test_spec_column_ownership_and_ragged_width():
    s = resolve_host_shards(30, 10, 3, 1, grid=(1, 3))
    # 10 cols over 3 hosts: widths 4, 3, 3; group 0 is the widest.
    assert s.col_range == (4, 7) and s.max_col_width == 4
    assert s.owns_col(4) and s.owns_col(6) and not s.owns_col(7)


# ---------------------------------------------------------------------------
# shard-windowed block streams
# ---------------------------------------------------------------------------

def _materialize(it):
    xs, ys = zip(*it)
    return np.concatenate(xs), np.concatenate(ys)


def test_array_source_shard_blocks_match_numpy_windows():
    rng = np.random.default_rng(0)
    X = rng.integers(0, 5, (101, 12)).astype(np.int32)
    y = rng.integers(0, 3, (101,)).astype(np.int32)
    src = ArraySource(X, y)
    for bo in (7, 32, 200):
        for obs, cols in [((0, 50), (0, 12)), ((13, 88), (3, 9)),
                          ((50, 101), (11, 12))]:
            Xw, yw = _materialize(src.iter_shard_blocks(bo, obs, cols))
            np.testing.assert_array_equal(Xw, X[slice(*obs), slice(*cols)])
            np.testing.assert_array_equal(yw, y[slice(*obs)])


def test_generic_source_shard_blocks_match_full_stream():
    # CorralSource has no override, so this exercises the DataSource
    # default: walk iter_blocks, slice the window, early-stop past it.
    src = CorralSource(500, 16, seed=3)
    Xf, yf = _materialize(src.iter_blocks(64))
    Xw, yw = _materialize(src.iter_shard_blocks(64, (100, 317), (4, 11)))
    np.testing.assert_array_equal(Xw, Xf[100:317, 4:11])
    np.testing.assert_array_equal(yw, yf[100:317])


def test_binned_source_shard_blocks_use_global_edges():
    rng = np.random.default_rng(1)
    X = rng.normal(size=(300, 8)).astype(np.float32)
    y = rng.integers(0, 2, (300,)).astype(np.int32)
    binned = BinnedSource(ArraySource(X, y), bins=4, fit_block_obs=64)
    Xf, _ = _materialize(binned.iter_blocks(64))
    Xw, yw = _materialize(binned.iter_shard_blocks(64, (50, 250), (2, 6)))
    # Window codes must come from edges fitted on the FULL data — a
    # shard-fitted binner would disagree with the single-host encode.
    np.testing.assert_array_equal(Xw, Xf[50:250, 2:6])


def test_shard_source_is_a_real_source():
    rng = np.random.default_rng(2)
    X = rng.integers(0, 4, (80, 10)).astype(np.int32)
    y = rng.integers(0, 2, (80,)).astype(np.int32)
    base = ArraySource(X, y)
    shard = ShardSource(base, (10, 60), (2, 8))
    assert (shard.num_obs, shard.num_features) == (50, 6)
    Xs, ys = _materialize(shard.iter_blocks(16))
    np.testing.assert_array_equal(Xs, X[10:60, 2:8])
    # Nested windows compose (offsets resolve into the base).
    Xn, _ = _materialize(shard.iter_shard_blocks(16, (5, 25), (1, 4)))
    np.testing.assert_array_equal(Xn, X[15:35, 3:6])
    # Distinct windows are distinct content addresses, none the base's.
    other = ShardSource(base, (10, 60), (0, 8))
    prints = {base.fingerprint(), shard.fingerprint(), other.fingerprint()}
    assert len(prints) == 3


# ---------------------------------------------------------------------------
# spill-cache namespacing (satellite: concurrent multi-host writers)
# ---------------------------------------------------------------------------

def test_block_cache_namespace_validated(tmp_path):
    src = ArraySource(np.zeros((4, 2), np.int32), np.zeros((4,), np.int32))
    with pytest.raises(ValueError, match="filesystem-safe"):
        BlockCacheSource(src, str(tmp_path), namespace="h0/evil")


def test_block_cache_namespaces_isolate_concurrent_writers(tmp_path):
    rng = np.random.default_rng(4)
    X = rng.integers(0, 4, (120, 8)).astype(np.int32)
    y = rng.integers(0, 2, (120,)).astype(np.int32)
    base = ArraySource(X, y)
    shards = [ShardSource(base, split_range(120, 2, i), (0, 8))
              for i in range(2)]
    caches = [
        BlockCacheSource(s, str(tmp_path), namespace=f"h{i}")
        for i, s in enumerate(shards)
    ]
    errors = []

    def stage(c):
        try:
            for _ in c.iter_blocks(32):
                pass
        except Exception as e:  # pragma: no cover - failure reporting
            errors.append(e)

    threads = [threading.Thread(target=stage, args=(c,)) for c in caches]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    entries = sorted(os.listdir(tmp_path))
    assert len(entries) == 2
    assert {e.rsplit("-", 1)[1] for e in entries} == {"h0", "h1"}
    # Both replay their own entry with the right content.
    for i, c in enumerate(caches):
        Xr, _ = _materialize(c.iter_blocks(32))
        np.testing.assert_array_equal(Xr, X[slice(*split_range(120, 2, i))])
        assert c.counters["replay_passes"] == 1


# ---------------------------------------------------------------------------
# capability flags and guards
# ---------------------------------------------------------------------------

def test_state_merge_capability_flags():
    assert ScoreFn.supports_state_merge is False
    assert MIScore.supports_state_merge is True
    assert PearsonMIScore.supports_state_merge is False


def test_obs_partitioned_multihost_rejects_unmergeable_score():
    from repro.core.streaming import mrmr_streaming

    rng = np.random.default_rng(5)
    X = rng.normal(size=(100, 8)).astype(np.float32)
    y = rng.integers(0, 2, (100,)).astype(np.int32)
    spec = resolve_host_shards(100, 8, 2, 0, grid=(2, 1))
    with pytest.raises(ValueError, match="supports_state_merge"):
        mrmr_streaming(
            ArraySource(X, y), 2, PearsonMIScore(), shards=spec
        )


def test_col_partitioned_multihost_rejects_device_feat_axes():
    from repro.core.streaming import mrmr_streaming

    rng = np.random.default_rng(6)
    X = rng.integers(0, 3, (40, 12)).astype(np.int32)
    y = rng.integers(0, 2, (40,)).astype(np.int32)
    spec = resolve_host_shards(40, 12, 2, 0, grid=(1, 2))
    with pytest.raises(ValueError, match="feat_axes"):
        mrmr_streaming(
            ArraySource(X, y), 2, MIScore(num_values=3, num_classes=2),
            feat_axes=("model",), shards=spec,
        )


def test_multihost_rejects_geometry_mismatch_and_prewrapped_cache(tmp_path):
    from repro.core.streaming import mrmr_streaming

    rng = np.random.default_rng(7)
    X = rng.integers(0, 3, (40, 12)).astype(np.int32)
    y = rng.integers(0, 2, (40,)).astype(np.int32)
    score = MIScore(num_values=3, num_classes=2)
    bad_spec = resolve_host_shards(41, 12, 2, 0, grid=(1, 2))
    with pytest.raises(ValueError, match="does not match the source"):
        mrmr_streaming(ArraySource(X, y), 2, score, shards=bad_spec)
    spec = resolve_host_shards(40, 12, 2, 0, grid=(1, 2))
    cached = BlockCacheSource(ArraySource(X, y), str(tmp_path))
    with pytest.raises(ValueError, match="spill_dir"):
        mrmr_streaming(cached, 2, score, shards=spec)


def test_selector_hosts_validation():
    from repro.core.selector import MRMRSelector

    X = np.zeros((10, 4), np.int32)
    y = np.zeros((10,), np.int32)
    with pytest.raises(ValueError, match="hosts"):
        MRMRSelector(num_select=2, hosts=0).fit(ArraySource(X, y))
    with pytest.raises(ValueError, match="streaming"):
        MRMRSelector(num_select=2, hosts=2).fit(X, y)


def test_single_host_collectives_are_identity():
    spec = resolve_host_shards(100, 10, 1, 0)
    coll = HostCollectives(spec)
    tree = dict(a=np.arange(6).reshape(2, 3))
    assert coll.psum(tree) is tree
    assert coll.psum_obs(tree) is tree
    assert coll.assemble(tree) is tree
    counts = coll.allgather_counts([5, 2**40])
    np.testing.assert_array_equal(counts, [[5, 2**40]])


# ---------------------------------------------------------------------------
# end-to-end: N jax.distributed processes vs the single-process engine
# ---------------------------------------------------------------------------

def _launch(extra, timeout=600):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.select_multihost",
         "--num-processes", "2", *extra],
        capture_output=True, text=True, timeout=timeout,
        env={**os.environ, "PYTHONPATH": _SRC},
    )
    if proc.returncode != 0:
        pytest.fail(
            f"launcher failed\n--- stdout ---\n{proc.stdout[-4000:]}"
            f"\n--- stderr ---\n{proc.stderr[-4000:]}"
        )
    return json.loads(proc.stdout.splitlines()[-1])


def _reference(rows, cols, select, **kw):
    from repro.core.selector import MRMRSelector
    from repro.data.synthetic import corral_dataset_np

    X, y = corral_dataset_np(rows, cols, seed=0)
    sel = MRMRSelector(
        num_select=select,
        score=MIScore(num_values=2, num_classes=2),
        **kw,
    ).fit(ArraySource(X, y))
    return sel.selected_.tolist(), [float(g) for g in sel.gains_]


@pytest.mark.slow
def test_multihost_e2e_tall_matches_single_process():
    out = _launch(["--rows", "6000", "--cols", "24", "--select", "4",
                   "--block-obs", "1500"])
    ref_sel, ref_gains = _reference(6000, 24, 4, block_obs=1500)
    assert out["selected"] == ref_sel
    assert out["gains"] == ref_gains          # bitwise, not approximate
    assert out["hosts"]["grid"] == [2, 1]
    agg = out["hosts"]["aggregate"]
    for h in out["hosts"]["per_host"]:
        # Each host reads its half of the rows, nothing more.
        assert 0.45 <= h["bytes_read"] / agg["bytes_read"] <= 0.55


@pytest.mark.slow
def test_multihost_e2e_wide_spill_batched_matches_single_process(tmp_path):
    spill = str(tmp_path / "spill")
    out = _launch(["--rows", "192", "--cols", "1024", "--select", "4",
                   "--block-obs", "64", "--batch-candidates", "2",
                   "--spill-dir", spill])
    ref_sel, ref_gains = _reference(
        192, 1024, 4, block_obs=64, batch_candidates=2,
    )
    assert out["selected"] == ref_sel
    assert out["gains"] == ref_gains
    assert out["hosts"]["grid"] == [1, 2]
    agg = out["hosts"]["aggregate"]
    for h in out["hosts"]["per_host"]:
        assert 0.4 <= h["bytes_read"] / agg["bytes_read"] <= 0.6
    # Spill entries are disjoint per process: shard fingerprints AND the
    # explicit h<i> namespace.
    entries = sorted(os.listdir(spill))
    assert len(entries) == 2
    assert {e.rsplit("-", 1)[1] for e in entries} == {"h0", "h1"}


@pytest.mark.slow
def test_multihost_e2e_2d_grid_matches_single_process():
    proc = subprocess.run(
        [sys.executable, str(_HERE / "multihost" / "mh_grid.py")],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": _SRC},
    )
    if proc.returncode != 0:
        pytest.fail(
            f"mh_grid.py failed\n--- stdout ---\n{proc.stdout[-4000:]}"
            f"\n--- stderr ---\n{proc.stderr[-4000:]}"
        )
