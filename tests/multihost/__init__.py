# Package marker: mh_grid.py in here is executed as a subprocess by
# tests/test_multihost.py, never collected by pytest.
