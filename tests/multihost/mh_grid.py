"""4-process 2x2 host-grid e2e: both-large data, JMI criterion.

Driver mode (no REPRO_PROCESS_ID in the environment) picks a loopback
coordinator port, spawns four worker copies of this script, computes the
single-process streaming reference in-process, and exits non-zero unless
every host committed the exact reference selection and gains.

The 2x2 grid is forced via an explicit ``grid=`` override on
``resolve_host_shards`` (the automatic rule would need larger data to
pick it), exercising BOTH collective axes in one run: ``psum_obs`` over
the observation-host axis merges the row-partitioned pair statistics,
and ``assemble`` sums the column groups' disjoint finalised slices.
"""

import json
import os
import socket
import subprocess
import sys

import numpy as np

ROWS, COLS, SELECT, BLOCK_OBS = 600, 600, 3, 128
NUM_VALUES, NUM_CLASSES, SEED = 4, 3, 7
CRITERION = "jmi"
_MARK = "GRIDRESULT:"


def _data():
    rng = np.random.default_rng(SEED)
    X = rng.integers(0, NUM_VALUES, (ROWS, COLS)).astype(np.int32)
    y = rng.integers(0, NUM_CLASSES, (ROWS,)).astype(np.int32)
    return X, y


def _fit(shards=None):
    from repro.core.scores import MIScore
    from repro.core.streaming import mrmr_streaming
    from repro.data.sources import ArraySource

    X, y = _data()
    res = mrmr_streaming(
        ArraySource(X, y),
        SELECT,
        MIScore(num_values=NUM_VALUES, num_classes=NUM_CLASSES),
        block_obs=BLOCK_OBS,
        criterion=CRITERION,
        shards=shards,
    )
    return (
        np.asarray(res.selected).tolist(),
        [float(g) for g in np.asarray(res.gains)],
        res.io,
    )


def worker() -> None:
    from repro.dist.multihost import init_multihost, resolve_host_shards

    ctx = init_multihost()
    spec = resolve_host_shards(
        ROWS, COLS, ctx.num_processes, ctx.process_id, grid=(2, 2)
    )
    sel, gains, io = _fit(spec)
    print(_MARK + json.dumps(
        dict(pid=ctx.process_id, sel=sel, gains=gains,
             bytes_read=io["bytes_read"], hosts=io["hosts"])
    ))


def driver() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    procs = []
    for pid in range(4):
        env = dict(
            os.environ,
            REPRO_COORDINATOR=f"127.0.0.1:{port}",
            REPRO_NUM_PROCESSES="4",
            REPRO_PROCESS_ID=str(pid),
        )
        procs.append(subprocess.Popen(
            [sys.executable, os.path.abspath(__file__)],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
            text=True,
        ))
    results = {}
    for pid, p in enumerate(procs):
        out, err = p.communicate(timeout=900)
        payload = next(
            (l[len(_MARK):] for l in out.splitlines()
             if l.startswith(_MARK)),
            None,
        )
        if p.returncode != 0 or payload is None:
            print(f"worker {pid} failed (rc={p.returncode})\n"
                  f"{out[-3000:]}\n{err[-3000:]}")
            return 1
        results[pid] = json.loads(payload)

    ref_sel, ref_gains, ref_io = _fit()
    print("reference:", ref_sel, ref_gains)
    ok = True
    for pid in range(4):
        r = results[pid]
        print(f"host {pid}:", r["sel"], r["gains"],
              f"bytes_read={r['bytes_read']}")
        if r["sel"] != ref_sel or r["gains"] != ref_gains:
            print(f"  MISMATCH vs reference")
            ok = False
    agg = results[0]["hosts"]["aggregate"]
    if results[0]["hosts"]["grid"] != [2, 2]:
        print("expected a 2x2 host grid, got", results[0]["hosts"]["grid"])
        ok = False
    for pid in range(4):
        # A 2x2 grid means each host streams ~a quarter of the bytes.
        frac = results[pid]["bytes_read"] / agg["bytes_read"]
        if not 0.2 <= frac <= 0.3:
            print(f"host {pid} read fraction {frac:.3f}, expected ~0.25")
            ok = False
    print("MATCH" if ok else "MISMATCH")
    return 0 if ok else 1


if __name__ == "__main__":
    if os.environ.get("REPRO_PROCESS_ID"):
        worker()
    else:
        sys.exit(driver())
