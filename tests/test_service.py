"""Selection-as-a-service: cache, coalescing, backpressure, lifecycle.

Covers the `repro.serve.selection` subsystem plus its substrate: the
content-addressed source fingerprints and fingerprint-keyed stats memo
(`repro.data.sources`), `MRMRResult` JSON round-trips, the warm jit
caches (`repro.core.selector` / `repro.core.streaming`) and
`retry_with_backoff` (`repro.runtime.resilience`).
"""

import json
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import streaming as streaming_mod
from repro.core.mrmr import MRMRResult, WarmJitCache
from repro.core.scores import MIScore
from repro.core.selector import (
    MRMRSelector,
    clear_engine_fn_cache,
    engine_fn_cache_stats,
)
from repro.data import sources as sources_mod
from repro.data.sources import ArraySource, CSVSource, CorralSource, NpySource
from repro.runtime.resilience import TransientError, retry_with_backoff
from repro.serve.selection import (
    Backpressure,
    CANCELLED,
    DONE,
    FAILED,
    JobCancelled,
    JobFailed,
    QUEUED,
    ResultCache,
    SelectionService,
    UnknownJob,
    parse_source_ref,
)


@pytest.fixture(autouse=True)
def _fresh_memos():
    sources_mod.clear_stats_memo()
    yield
    sources_mod.clear_stats_memo()


def _dummy_result(tag: int = 0) -> MRMRResult:
    return MRMRResult(
        selected=jnp.asarray([tag, tag + 1], jnp.int32),
        gains=jnp.asarray([1.5, 0.5], jnp.float32),
        relevance=jnp.asarray([0.1, 0.2, 0.3], jnp.float32),
        criterion="mid",
        engine="streaming",
    )


def _assert_results_equal(a: MRMRResult, b: MRMRResult):
    np.testing.assert_array_equal(np.asarray(a.selected), np.asarray(b.selected))
    np.testing.assert_allclose(np.asarray(a.gains), np.asarray(b.gains))
    if a.relevance is None:
        assert b.relevance is None
    else:
        np.testing.assert_allclose(
            np.asarray(a.relevance), np.asarray(b.relevance), equal_nan=True
        )
    assert a.criterion == b.criterion
    assert a.engine == b.engine


# ---------------------------------------------------------------------------
# MRMRResult JSON round-trip
# ---------------------------------------------------------------------------

class TestResultJSON:
    def test_roundtrip(self):
        res = _dummy_result()
        back = MRMRResult.from_json(res.to_json())
        _assert_results_equal(res, back)

    def test_roundtrip_nan_relevance_strict_json(self):
        # CustomScore fits NaN-fill the relevance; the payload must stay
        # strict JSON (no bare NaN tokens) and decode back to NaN.
        res = MRMRResult(
            selected=jnp.asarray([1], jnp.int32),
            gains=jnp.asarray([float("inf")], jnp.float32),
            relevance=jnp.asarray([float("nan"), 2.0], jnp.float32),
        )
        payload = res.to_json()
        json.loads(payload)  # strict parser accepts it
        assert "NaN" not in payload and "Infinity" not in payload
        back = MRMRResult.from_json(payload)
        assert np.isnan(np.asarray(back.relevance)[0])
        assert np.isinf(np.asarray(back.gains)[0])

    def test_roundtrip_none_relevance(self):
        res = MRMRResult(
            selected=jnp.asarray([0], jnp.int32),
            gains=jnp.asarray([1.0], jnp.float32),
        )
        back = MRMRResult.from_json(res.to_json())
        assert back.relevance is None


# ---------------------------------------------------------------------------
# source fingerprints + stats memo
# ---------------------------------------------------------------------------

class TestFingerprint:
    def test_corral_pure_function_of_params(self):
        assert (
            CorralSource(512, 16, seed=3).fingerprint()
            == CorralSource(512, 16, seed=3).fingerprint()
        )
        assert (
            CorralSource(512, 16, seed=3).fingerprint()
            != CorralSource(512, 16, seed=4).fingerprint()
        )

    def test_array_content_addressed(self):
        X = np.arange(32, dtype=np.int32).reshape(8, 4) % 2
        y = np.arange(8, dtype=np.int32) % 2
        assert (
            ArraySource(X, y).fingerprint()
            == ArraySource(X.copy(), y.copy()).fingerprint()
        )
        X2 = X.copy()
        X2[0, 0] ^= 1
        assert ArraySource(X, y).fingerprint() != ArraySource(X2, y).fingerprint()

    def test_npy_stat_based(self, tmp_path):
        xp, yp = str(tmp_path / "X.npy"), str(tmp_path / "y.npy")
        CorralSource(256, 16, seed=0).to_npy(xp, yp)
        assert NpySource(xp, yp).fingerprint() == NpySource(xp, yp).fingerprint()

    def test_csv_knobs_in_identity(self, tmp_path):
        p = str(tmp_path / "d.csv")
        with open(p, "w") as f:
            f.write("1,0,1\n0,1,0\n")
        assert (
            CSVSource(p, dtype=np.int32).fingerprint()
            != CSVSource(p, dtype=np.int32, target_col=0).fingerprint()
        )

    def test_stats_memoized_across_instances(self):
        class CountingCorral(CorralSource):
            scans = []

            def iter_blocks(self, block_obs):
                CountingCorral.scans.append(block_obs)
                return super().iter_blocks(block_obs)

        CountingCorral.scans = []
        s1 = CountingCorral(256, 16, seed=0)
        st1 = s1.stats()
        assert len(CountingCorral.scans) == 1  # one real scan
        # A FRESH instance on the same content: served from the
        # fingerprint-keyed memo, zero passes of I/O.
        s2 = CountingCorral(256, 16, seed=0)
        st2 = s2.stats()
        assert st2 == st1
        assert len(CountingCorral.scans) == 1


# ---------------------------------------------------------------------------
# result cache
# ---------------------------------------------------------------------------

class TestResultCache:
    def test_lru_eviction_bound(self):
        cache = ResultCache(capacity=2)
        for i in range(3):
            cache.put(f"k{i}", _dummy_result(i))
        assert len(cache) == 2
        st = cache.stats()
        assert st["evictions"] == 1
        assert cache.get("k0") is None  # oldest evicted
        assert cache.get("k1") is not None and cache.get("k2") is not None

    def test_lru_recency_on_get(self):
        cache = ResultCache(capacity=2)
        cache.put("a", _dummy_result(0))
        cache.put("b", _dummy_result(1))
        cache.get("a")  # refresh a; b becomes LRU
        cache.put("c", _dummy_result(2))
        assert cache.get("b") is None
        assert cache.get("a") is not None

    def test_persistence_roundtrip(self, tmp_path):
        d = str(tmp_path / "cache")
        ResultCache(capacity=4, persist_dir=d).put("k", _dummy_result(7))
        fresh = ResultCache(capacity=4, persist_dir=d)  # new "process"
        got = fresh.get("k")
        assert got is not None
        _assert_results_equal(got, _dummy_result(7))
        assert fresh.stats()["disk_hits"] == 1


# ---------------------------------------------------------------------------
# the service
# ---------------------------------------------------------------------------

def _probe_source(rows=64, cols=16):
    """ArraySource whose iter_blocks calls are counted — the I/O probe."""
    rng = np.random.default_rng(0)
    X = rng.integers(0, 2, size=(rows, cols)).astype(np.int32)
    y = rng.integers(0, 2, size=(rows,)).astype(np.int32)

    class Probe(ArraySource):
        passes = 0

        def iter_blocks(self, block_obs):
            Probe.passes += 1
            return super().iter_blocks(block_obs)

    return Probe(X, y), Probe


class TestServiceCache:
    def test_second_identical_submission_hits_cache_zero_io(self):
        source, Probe = _probe_source()
        score = MIScore(num_values=2, num_classes=2)  # skip the stats scan
        with SelectionService(workers=1, queue_capacity=4) as svc:
            j1 = svc.submit(source, num_select=2, score=score, block_obs=32)
            r1 = svc.result(j1, timeout=120)
            passes_after_first = Probe.passes
            assert passes_after_first >= 2  # fingerprint + >=1 scoring pass
            j2 = svc.submit(source, num_select=2, score=score, block_obs=32)
            r2 = svc.result(j2, timeout=10)
            # Zero additional engine or I/O passes: pure cache read.
            assert Probe.passes == passes_after_first
            info = svc.poll(j2)
            assert info.state == DONE and info.cache_hit
            assert svc.stats()["cache"]["hits"] == 1
            _assert_results_equal(r1, r2)

    def test_block_obs_not_in_cache_key(self):
        # Selections are block-size independent, so a different execution
        # geometry of the same fit must share the cache line.
        source, Probe = _probe_source()
        score = MIScore(num_values=2, num_classes=2)
        with SelectionService(workers=1) as svc:
            j1 = svc.submit(source, num_select=2, score=score, block_obs=32)
            svc.result(j1, timeout=120)
            j2 = svc.submit(source, num_select=2, score=score, block_obs=16)
            assert svc.poll(j2).cache_hit

    def test_binned_bin_counts_distinct_cache_keys(self):
        # Same file, different bin config -> different binned fingerprint
        # -> different result-cache line.  Binned vs pre-discretised of
        # the same base are distinct too.
        from repro.data.binning import BinnedSource

        rng = np.random.default_rng(31)
        X = rng.normal(size=(128, 8))
        y = rng.integers(0, 2, size=128)
        base = ArraySource(X, y)
        with SelectionService(workers=1) as svc:
            j16 = svc.submit(base, num_select=2, bins=16, block_obs=64)
            svc.result(j16, timeout=120)
            j64 = svc.submit(base, num_select=2, bins=64, block_obs=64)
            svc.result(j64, timeout=120)
            assert not svc.poll(j64).cache_hit
            # pre-discretised codes submitted as their own discrete source:
            # distinct content, distinct fingerprint, distinct key
            codes, labels = BinnedSource(base, 16).materialize()
            jd = svc.submit(ArraySource(codes, labels), num_select=2,
                            block_obs=64)
            svc.result(jd, timeout=120)
            st = svc.stats()["cache"]
            assert st["hits"] == 0 and st["misses"] == 3, st

    def test_binned_repeat_is_cache_hit_zero_io(self):
        # A repeated identical binned fit never touches the source again:
        # no sketch pass, no scoring passes — pure cache read.
        from repro.data.binning import clear_binner_memo

        clear_binner_memo()
        rng = np.random.default_rng(32)
        X = rng.normal(size=(96, 6))
        y = rng.integers(0, 2, size=96).astype(np.int32)

        class Probe(ArraySource):
            passes = 0

            def iter_blocks(self, block_obs):
                Probe.passes += 1
                return super().iter_blocks(block_obs)

        source = Probe(X, y)
        with SelectionService(workers=1) as svc:
            j1 = svc.submit(source, num_select=2, bins=8, block_obs=48)
            r1 = svc.result(j1, timeout=120)
            after_first = Probe.passes
            assert after_first >= 3  # stats + sketch + scoring passes
            j2 = svc.submit(source, num_select=2, bins=8, block_obs=48)
            r2 = svc.result(j2, timeout=10)
            assert Probe.passes == after_first  # zero additional I/O
            assert svc.poll(j2).cache_hit
            _assert_results_equal(r1, r2)
            # A FRESH instance of the same content pays exactly one pass —
            # the in-memory fingerprint content hash.  Stats memo, binner
            # memo and the result cache all key off it: no re-sketch, no
            # re-fit.
            j3 = svc.submit(Probe(X, y), num_select=2, bins=8, block_obs=48)
            svc.result(j3, timeout=10)
            assert Probe.passes == after_first + 1
            assert svc.poll(j3).cache_hit
        clear_binner_memo()

    def test_submit_source_ref_and_arrays(self):
        with SelectionService(workers=1, fit_fn=lambda req: _dummy_result()) as svc:
            j1 = svc.submit("corral:256x16:0", num_select=2)
            assert svc.result(j1, timeout=30) is not None
            X = np.zeros((8, 4), np.int32)
            y = np.zeros((8,), np.int32)
            j2 = svc.submit((X, y), num_select=2)
            assert svc.result(j2, timeout=30) is not None

    def test_parse_source_ref_errors(self):
        with pytest.raises(ValueError):
            parse_source_ref("lonely.npy")
        with pytest.raises(ValueError):
            parse_source_ref("corral:banana")


class TestServiceCoalescing:
    def test_stampede_runs_engine_exactly_once(self):
        n_threads = 6
        calls = []
        release = threading.Event()

        def slow_fit(request):
            calls.append(request.cache_key())
            release.wait(timeout=30)
            return _dummy_result()

        source = CorralSource(256, 16, seed=0)
        source.fingerprint()  # pre-memoise: submits race on it otherwise
        job_ids = [None] * n_threads
        barrier = threading.Barrier(n_threads)
        with SelectionService(
            workers=2, queue_capacity=8, fit_fn=slow_fit
        ) as svc:
            def submit(i):
                barrier.wait()
                job_ids[i] = svc.submit(
                    source, num_select=2,
                    score=MIScore(num_values=2, num_classes=2),
                )

            threads = [
                threading.Thread(target=submit, args=(i,))
                for i in range(n_threads)
            ]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=30)
            release.set()
            results = [svc.result(j, timeout=30) for j in job_ids]
            # Exactly ONE engine invocation; everyone shares its result.
            assert len(calls) == 1
            for r in results:
                _assert_results_equal(r, results[0])
            st = svc.stats()
            assert st["coalesced"] == n_threads - 1
            coalesced = [
                svc.poll(j).coalesced_into is not None for j in job_ids
            ]
            assert sum(coalesced) == n_threads - 1


class TestServiceBackpressure:
    def test_overflow_rejects_with_retry_after(self):
        started = threading.Event()
        release = threading.Event()

        def blocking_fit(request):
            started.set()
            release.wait(timeout=30)
            return _dummy_result()

        X = np.zeros((8, 4), np.int32)
        y = np.zeros((8,), np.int32)
        score = MIScore(num_values=2, num_classes=2)
        with SelectionService(
            workers=1, queue_capacity=1, fit_fn=blocking_fit
        ) as svc:
            # Distinct num_selects -> distinct keys (no coalescing).
            j1 = svc.submit(ArraySource(X, y), num_select=1, score=score)
            assert started.wait(timeout=10)  # worker holds job 1
            j2 = svc.submit(ArraySource(X, y), num_select=2, score=score)
            with pytest.raises(Backpressure) as exc:
                svc.submit(ArraySource(X, y), num_select=3, score=score)
            assert exc.value.retry_after_s > 0
            assert svc.stats()["queue"]["rejected"] == 1
            release.set()
            assert svc.result(j1, timeout=30) is not None
            assert svc.result(j2, timeout=30) is not None


class TestServiceLifecycle:
    def _blocking_service(self, **kw):
        started = threading.Event()
        release = threading.Event()

        def blocking_fit(request):
            started.set()
            release.wait(timeout=30)
            return _dummy_result()

        svc = SelectionService(workers=1, fit_fn=blocking_fit, **kw)
        return svc, started, release

    def test_cancel_queued_job(self):
        svc, started, release = self._blocking_service()
        X = np.zeros((8, 4), np.int32)
        y = np.zeros((8,), np.int32)
        score = MIScore(num_values=2, num_classes=2)
        try:
            j1 = svc.submit(ArraySource(X, y), num_select=1, score=score)
            assert started.wait(timeout=10)
            j2 = svc.submit(ArraySource(X, y), num_select=2, score=score)
            assert svc.poll(j2).state == QUEUED
            assert svc.cancel(j2)
            assert svc.poll(j2).state == CANCELLED
            with pytest.raises(JobCancelled):
                svc.result(j2, timeout=5)
            # A RUNNING primary cannot be cancelled.
            assert not svc.cancel(j1)
            release.set()
            assert svc.result(j1, timeout=30) is not None
        finally:
            release.set()
            svc.close()

    def test_unknown_job(self):
        with SelectionService(workers=1) as svc:
            with pytest.raises(UnknownJob):
                svc.poll("job-9999")

    def test_failed_job_reports_error(self):
        def bad_fit(request):
            raise ValueError("boom")

        with SelectionService(workers=1, fit_fn=bad_fit) as svc:
            j = svc.submit(
                "corral:256x16:0", num_select=2,
                score=MIScore(num_values=2, num_classes=2),
            )
            with pytest.raises(JobFailed, match="boom"):
                svc.result(j, timeout=30)
            info = svc.poll(j)
            assert info.state == FAILED and "boom" in info.error

    def test_transient_failure_retried_to_done(self):
        attempts = []

        def flaky_fit(request):
            attempts.append(1)
            if len(attempts) == 1:
                raise TransientError("worker preempted")
            return _dummy_result()

        with SelectionService(
            workers=1, fit_fn=flaky_fit, max_attempts=2,
            retry_sleep=lambda s: None,
        ) as svc:
            j = svc.submit(
                "corral:256x16:0", num_select=2,
                score=MIScore(num_values=2, num_classes=2),
            )
            assert svc.result(j, timeout=30) is not None
            assert svc.poll(j).attempts == 2


# ---------------------------------------------------------------------------
# retry_with_backoff
# ---------------------------------------------------------------------------

class TestRetryWithBackoff:
    def test_backs_off_then_succeeds(self):
        delays, calls = [], []

        def flaky():
            calls.append(1)
            if len(calls) < 3:
                raise TransientError("flake")
            return 42

        out = retry_with_backoff(
            flaky, max_attempts=3, base_delay_s=0.1, backoff=2.0,
            sleep=delays.append,
        )
        assert out == 42
        assert delays == [0.1, 0.2]  # exponential

    def test_exhaustion_raises_last(self):
        def always():
            raise TransientError("never")

        with pytest.raises(TransientError):
            retry_with_backoff(
                always, max_attempts=3, sleep=lambda s: None
            )

    def test_non_retryable_propagates_immediately(self):
        calls = []

        def bad():
            calls.append(1)
            raise ValueError("logic bug")

        with pytest.raises(ValueError):
            retry_with_backoff(bad, max_attempts=5, sleep=lambda s: None)
        assert len(calls) == 1


# ---------------------------------------------------------------------------
# warm jit caches
# ---------------------------------------------------------------------------

class TestWarmJitCaches:
    def test_warm_jit_cache_lru(self):
        cache = WarmJitCache(capacity=2)
        built = []

        def make(tag):
            def build():
                built.append(tag)
                return tag

            return build

        assert cache.get_or_build("a", make("a")) == "a"
        assert cache.get_or_build("a", make("a")) == "a"  # hit
        cache.get_or_build("b", make("b"))
        cache.get_or_build("c", make("c"))  # evicts a
        st = cache.stats()
        assert st["hits"] == 1 and st["evictions"] == 1
        cache.get_or_build("a", make("a"))  # rebuilt
        assert built == ["a", "b", "c", "a"]

    def test_warm_jit_cache_unhashable_key_bypasses(self):
        cache = WarmJitCache(capacity=2)
        assert cache.get_or_build(["not", "hashable"], lambda: 7) == 7
        assert cache.stats()["uncacheable"] == 1

    def test_repeat_in_memory_fit_reuses_engine_fn(self):
        rng = np.random.default_rng(0)
        X = rng.integers(0, 2, size=(64, 8)).astype(np.int32)
        y = rng.integers(0, 2, size=(64,)).astype(np.int32)
        clear_engine_fn_cache()
        MRMRSelector(num_select=3).fit(X, y)
        miss0 = engine_fn_cache_stats()["misses"]
        hits0 = engine_fn_cache_stats()["hits"]
        MRMRSelector(num_select=3).fit(X, y)
        st = engine_fn_cache_stats()
        assert st["misses"] == miss0  # nothing rebuilt
        assert st["hits"] == hits0 + 1

    def test_repeat_streaming_fit_reuses_acc_fn(self):
        source = CorralSource(512, 16, seed=0)
        streaming_mod.clear_acc_fn_cache()
        MRMRSelector(num_select=2, block_obs=128).fit(source)
        miss0 = streaming_mod.acc_fn_cache_stats()["misses"]
        MRMRSelector(num_select=2, block_obs=128).fit(
            CorralSource(512, 16, seed=0)
        )
        st = streaming_mod.acc_fn_cache_stats()
        assert st["misses"] == miss0
        assert st["hits"] >= 1
