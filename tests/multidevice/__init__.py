# Package marker: the md_* helper scripts in here are executed as
# subprocesses by tests/test_multidevice.py, never collected by pytest.
# Being a proper package keeps pytest from warning about invalid module
# names during collection.
