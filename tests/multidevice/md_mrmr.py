"""Multi-device mRMR semantics — run under 8 forced host devices.

Executed as a subprocess by tests/test_multidevice.py (so the main pytest
process keeps a single device, per the dry-run isolation rule).
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    FeatureSelector,
    MIScore,
    MRMRSelector,
    PearsonMIScore,
    mrmr_alternative,
    mrmr_conventional,
    mrmr_grid,
    mrmr_reference,
)
from repro.data.synthetic import corral_dataset  # noqa: E402


def main() -> None:
    assert jax.device_count() == 8, jax.devices()

    rng = np.random.default_rng(0)
    M, N, L = 512, 24, 8
    X = rng.integers(0, 3, (M, N)).astype(np.int32)
    y = (X[:, 5] % 2).astype(np.int32) ^ (rng.random(M) < 0.1)
    y = y.astype(np.int32)
    X[:, 6] = X[:, 5]  # exact duplicate: redundancy must suppress it
    score = MIScore(num_values=3, num_classes=2)

    ref = mrmr_reference(jnp.asarray(X.T), jnp.asarray(y), L, score)
    ref_sel = np.asarray(ref.selected)

    # --- conventional encoding over 8-way observation sharding ------------
    mesh8 = jax.make_mesh((8,), ("data",))
    conv = mrmr_conventional(
        jnp.asarray(X), jnp.asarray(y), L, score, mesh=mesh8, obs_axes=("data",)
    )
    np.testing.assert_array_equal(np.asarray(conv.selected), ref_sel)
    np.testing.assert_allclose(conv.gains, ref.gains, rtol=1e-4, atol=1e-5)
    print("conventional 8-way: OK")

    # --- conventional over a 2-axis (pod, data) product --------------------
    mesh_pd = jax.make_mesh((2, 4), ("pod", "data"))
    conv2 = mrmr_conventional(
        jnp.asarray(X), jnp.asarray(y), L, score,
        mesh=mesh_pd, obs_axes=("pod", "data"),
    )
    np.testing.assert_array_equal(np.asarray(conv2.selected), ref_sel)
    print("conventional (pod,data): OK")

    # --- alternative encoding over 8-way feature sharding ------------------
    mesh_m = jax.make_mesh((8,), ("model",))
    alt = mrmr_alternative(
        jnp.asarray(X.T), jnp.asarray(y), L, score,
        mesh=mesh_m, feat_axes=("model",),
    )
    np.testing.assert_array_equal(np.asarray(alt.selected), ref_sel)
    print("alternative 8-way: OK")

    # --- alternative with non-divisible N via FeatureSelector padding ------
    fs = FeatureSelector(
        num_select=L, score=score, layout="alternative",
        mesh=mesh_m, feat_axes=("model",),
    ).fit(X[:, :23], y)  # 23 % 8 != 0
    ref23 = mrmr_reference(jnp.asarray(X[:, :23].T), jnp.asarray(y), L, score)
    np.testing.assert_array_equal(fs.selected_, np.asarray(ref23.selected))
    print("alternative padded: OK")

    # --- grid encoding: observations x features ----------------------------
    mesh_g = jax.make_mesh((4, 2), ("data", "model"))
    grid = mrmr_grid(
        jnp.asarray(X), jnp.asarray(y), L, score,
        mesh=mesh_g, obs_axes=("data",), feat_axes=("model",),
    )
    np.testing.assert_array_equal(np.asarray(grid.selected), ref_sel)
    np.testing.assert_allclose(grid.gains, ref.gains, rtol=1e-4, atol=1e-5)
    print("grid 4x2: OK")

    # --- criterion layer on real meshes: miq agrees engine-for-engine ------
    miq_ref = mrmr_reference(jnp.asarray(X.T), jnp.asarray(y), L, score,
                             criterion="miq")
    miq_conv = mrmr_conventional(jnp.asarray(X), jnp.asarray(y), L, score,
                                 mesh=mesh8, criterion="miq")
    miq_alt = mrmr_alternative(jnp.asarray(X.T), jnp.asarray(y), L, score,
                               mesh=mesh_m, criterion="miq")
    miq_grid = mrmr_grid(jnp.asarray(X), jnp.asarray(y), L, score,
                         mesh=mesh_g, criterion="miq")
    for got in (miq_conv, miq_alt, miq_grid):
        np.testing.assert_array_equal(np.asarray(got.selected),
                                      np.asarray(miq_ref.selected))
    assert miq_conv.criterion == "miq" and miq_conv.engine == "conventional"
    print("criterion miq (8-way conv/alt/grid): OK")

    # --- paper-faithful (non-incremental) distributed path -----------------
    conv_f = mrmr_conventional(
        jnp.asarray(X), jnp.asarray(y), L, score,
        mesh=mesh8, incremental=False,
    )
    np.testing.assert_array_equal(np.asarray(conv_f.selected), ref_sel)
    print("conventional paper-faithful: OK")

    # --- Pearson score, feature-sharded, continuous data -------------------
    from repro.data.synthetic import continuous_wide_dataset

    Xc, yc = continuous_wide_dataset(256, 64, seed=3)
    p_ref = mrmr_reference(jnp.asarray(Xc.T), yc.astype(jnp.float32), 6,
                           PearsonMIScore())
    p_alt = mrmr_alternative(jnp.asarray(Xc.T), yc.astype(jnp.float32), 6,
                             PearsonMIScore(), mesh=mesh_m)
    np.testing.assert_array_equal(np.asarray(p_alt.selected),
                                  np.asarray(p_ref.selected))
    print("pearson alternative: OK")

    # --- CorrAL end-to-end on the grid --------------------------------------
    Xb, yb = corral_dataset(2048, 32, seed=7, flip_prob=0.02)
    res = FeatureSelector(
        num_select=8, score=MIScore(2, 2), layout="grid",
        mesh=mesh_g,
    ).fit(np.asarray(Xb, dtype=np.int32), np.asarray(yb))
    assert len(set(res.selected_.tolist()) & set(range(8))) >= 6
    print("corral grid e2e: OK")

    # --- MRMRSelector front door: every encoding on real 8-device meshes ---
    for encoding, msh in [
        ("conventional", mesh8),
        ("alternative", mesh_m),
        ("grid", mesh_g),
    ]:
        sel = MRMRSelector(
            num_select=L, score=score, encoding=encoding, mesh=msh
        ).fit(X, y)
        np.testing.assert_array_equal(sel.selected_, ref_sel)
        print(f"MRMRSelector {encoding} (explicit mesh): OK")

    # auto-planned: the selector builds its own mesh from the 8 devices
    for shape_hint, Xa, ya in [
        ("tall", X, y),
        ("wide", X[:20], y[:20]),
    ]:
        sel = MRMRSelector(num_select=4, score=score).fit(Xa, ya)
        want = mrmr_reference(
            jnp.asarray(Xa.T), jnp.asarray(ya), 4, score
        )
        np.testing.assert_array_equal(sel.selected_, np.asarray(want.selected))
        print(f"MRMRSelector auto ({shape_hint} -> "
              f"{sel.plan_.encoding}, mesh={sel.plan_.mesh_shape}): OK")

    print("ALL-MD-MRMR-OK")


if __name__ == "__main__":
    main()
