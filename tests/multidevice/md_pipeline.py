"""Pipeline parallelism semantics — 8 forced host devices.

The GPipe schedule over a 4-stage axis must be bit-equivalent to applying
the stages sequentially, for any microbatch count.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.dist.meshes import make_mesh  # noqa: E402
from repro.dist.pipeline import pipeline_apply  # noqa: E402


def main() -> None:
    assert jax.device_count() == 8
    S, B, D = 4, 16, 32
    key = jax.random.PRNGKey(0)
    params = {
        "w": jax.random.normal(key, (S, D, D)) * (D ** -0.5),
        "b": jax.random.normal(jax.random.fold_in(key, 1), (S, D)) * 0.1,
    }
    x = jax.random.normal(jax.random.fold_in(key, 2), (B, D))

    def stage_fn(p, h):
        return jnp.tanh(h @ p["w"] + p["b"])

    ref = x
    for s in range(S):
        ref = stage_fn({"w": params["w"][s], "b": params["b"][s]}, ref)

    mesh = make_mesh((4, 2), ("stage", "data"))
    for mb in (1, 2, 4, 8):
        out = pipeline_apply(
            stage_fn, params, x, mesh=mesh, axis="stage", microbatches=mb
        )
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=1e-6, atol=1e-6
        )
        print(f"pipeline microbatches={mb}: OK")

    print("ALL-MD-PIPELINE-OK")


if __name__ == "__main__":
    main()
