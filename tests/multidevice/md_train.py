"""Multi-device LM training semantics — 8 forced host devices.

Covers: (a) 3-axis (pod, data, model) training steps with finite loss,
(b) checkpoint save -> crash -> restore -> bitwise-identical continuation,
(c) elastic restore onto a DIFFERENT mesh shape.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import tempfile  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import smoke_config  # noqa: E402
from repro.data.pipeline import ShardedDataPipeline  # noqa: E402
from repro.dist.meshes import make_mesh  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.runtime.checkpoint import CheckpointManager  # noqa: E402
from repro.runtime.resilience import elastic_restore  # noqa: E402
from repro.train.optimizer import AdamWConfig  # noqa: E402
from repro.train.train_step import (  # noqa: E402
    TrainState,
    make_train_state_specs,
    make_train_step,
    train_state_shapes,
)


def _shardings(bundle, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        make_train_state_specs(bundle),
        is_leaf=lambda x: isinstance(x, P),
    )


def main() -> None:
    assert jax.device_count() == 8, jax.devices()
    cfg = smoke_config("qwen1.5-0.5b")
    opt = AdamWConfig(learning_rate=1e-3)

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    bundle = build_model(cfg, mesh)
    step_fn = jax.jit(make_train_step(bundle, opt), donate_argnums=0)
    pipe = ShardedDataPipeline(
        mesh=mesh, global_batch=8, seq_len=64, vocab=cfg.vocab_size
    )
    params = jax.jit(bundle.init,
                     out_shardings=_shardings(bundle, mesh).params)(
        jax.random.PRNGKey(0)
    )
    state = TrainState.create(params, opt)

    with tempfile.TemporaryDirectory() as d:
        ckpt = CheckpointManager(d, use_async=False)
        losses = []
        for step in range(4):
            state, metrics = step_fn(state, pipe.batch_at(step))
            losses.append(float(metrics["loss"]))
            if step == 1:
                ckpt.save(2, state)
        assert all(np.isfinite(losses)), losses
        print("3-axis train: OK", [round(x, 3) for x in losses])

        # --- restore and replay: must match the original continuation -----
        like = train_state_shapes(bundle, opt)
        restored = ckpt.restore(2, like, _shardings(bundle, mesh))
        r_losses = []
        st2 = restored
        for step in range(2, 4):
            st2, metrics = step_fn(st2, pipe.batch_at(step))
            r_losses.append(float(metrics["loss"]))
        np.testing.assert_array_equal(np.asarray(r_losses),
                                      np.asarray(losses[2:]))
        print("checkpoint replay bitwise: OK")

        # --- elastic: same checkpoint onto a (4, 2) mesh -------------------
        mesh2 = make_mesh((4, 2), ("data", "model"))
        new_bundle, st3 = elastic_restore(ckpt, 2, bundle, opt, mesh2)
        step2 = jax.jit(make_train_step(new_bundle, opt), donate_argnums=0)
        pipe2 = ShardedDataPipeline(
            mesh=mesh2, global_batch=8, seq_len=64, vocab=cfg.vocab_size
        )
        e_losses = []
        for step in range(2, 4):
            st3, metrics = step2(st3, pipe2.batch_at(step))
            e_losses.append(float(metrics["loss"]))
        np.testing.assert_allclose(
            np.asarray(e_losses), np.asarray(losses[2:]), rtol=2e-4, atol=1e-5
        )
        print("elastic reshard (2,2,2)->(4,2): OK")

    print("ALL-MD-TRAIN-OK")


if __name__ == "__main__":
    main()
