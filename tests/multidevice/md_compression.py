"""int8 error-feedback gradient compression under real data parallelism."""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.dist import compat  # noqa: E402
from repro.dist.meshes import make_mesh  # noqa: E402
from repro.train.compression import (  # noqa: E402
    GradCompression,
    compressed_psum,
)


def main() -> None:
    n = jax.device_count()
    assert n == 8
    mesh = make_mesh((n,), ("data",))
    key = jax.random.PRNGKey(0)

    # --- one-shot psum parity ------------------------------------------------
    grads = jax.random.normal(key, (n, 512)) * 3.0

    def body(g, r):
        st = GradCompression(residual={"g": r.reshape(512)})
        out, new = compressed_psum({"g": g.reshape(512)}, ("data",), st, n)
        return out["g"], new.residual["g"][None]

    fn = jax.jit(
        compat.shard_map(
            body, mesh=mesh,
            in_specs=(P("data", None), P("data", None)),
            out_specs=(P(), P("data", None)),
        )
    )
    out, resid = fn(grads, jnp.zeros_like(grads))
    ref = np.asarray(grads.mean(axis=0))
    tol = float(np.abs(np.asarray(grads)).max()) / 127 + 1e-6
    assert np.abs(np.asarray(out) - ref).max() <= tol
    print("compressed psum parity: OK")

    # --- convergence: SGD on a least-squares problem, compressed vs exact ----
    w_true = jax.random.normal(jax.random.fold_in(key, 1), (64,))
    X = jax.random.normal(jax.random.fold_in(key, 2), (n, 32, 64))
    yv = jnp.einsum("dbf,f->db", X, w_true)

    def grad_local(w, Xl, yl):
        r = Xl @ w - yl
        return Xl.T @ r / Xl.shape[0]

    def run(compressed: bool, steps=150, lr=0.1):
        def body(Xl, yl):
            Xl, yl = Xl[0], yl[0]
            w = jnp.zeros((64,))
            # the error-feedback residual is per-shard state (VMA: varying)
            r = compat.pvary(jnp.zeros((64,)), ("data",))

            def step(carry, _):
                w, r = carry
                g = grad_local(w, Xl, yl)
                if compressed:
                    st = GradCompression(residual={"g": r})
                    out, new = compressed_psum(
                        {"g": g}, ("data",), st, n
                    )
                    g, r = out["g"], new.residual["g"]
                else:
                    g = jax.lax.pmean(g, "data")
                return (w - lr * g, r), None

            (w, _), _ = jax.lax.scan(step, (w, r), None, length=steps)
            return w

        fn = jax.jit(
            compat.shard_map(
                body, mesh=mesh,
                in_specs=(P("data", None, None), P("data", None)),
                out_specs=P(),
            )
        )
        return np.asarray(fn(X, yv))

    w_exact = run(False)
    w_comp = run(True)
    err_exact = np.linalg.norm(w_exact - np.asarray(w_true))
    err_comp = np.linalg.norm(w_comp - np.asarray(w_true))
    print(f"exact err {err_exact:.4f}  compressed err {err_comp:.4f}")
    # error feedback keeps compressed SGD converging to the same solution
    assert err_comp <= err_exact + 0.05
    print("ALL-MD-COMPRESSION-OK")


if __name__ == "__main__":
    main()
