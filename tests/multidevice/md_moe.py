"""MoE expert-parallel exactness under 8 forced host devices.

Checks the shard_map EP path (including the weight-stationary ff_axis
level added in §Perf) and the einsum decode path against the dense
reference, at a capacity factor high enough that no token drops.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import smoke_config  # noqa: E402
from repro.dist.meshes import make_mesh  # noqa: E402
from repro.models import moe as moe_mod  # noqa: E402
from repro.models.layers import init_params  # noqa: E402


def main() -> None:
    assert jax.device_count() == 8
    cfg = smoke_config("dbrx-132b")
    cfg = dataclasses.replace(
        cfg, num_experts=4, experts_per_token=2, capacity_factor=8.0,
        d_ff=64, d_model=32, fsdp=True,
    )
    defs = moe_mod.moe_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32), jnp.float32)

    ref = moe_mod.moe_dense_reference(params, x, cfg=cfg)

    mesh = make_mesh((2, 4), ("data", "model"))
    assert cfg.d_ff % mesh.shape["data"] == 0  # ff_axis path engaged
    y_ep, aux = moe_mod.moe_apply(
        params, x, cfg=cfg, mesh=mesh, batch_axes=("data",)
    )
    np.testing.assert_allclose(
        np.asarray(y_ep), np.asarray(ref), rtol=2e-4, atol=2e-5
    )
    assert np.isfinite(float(aux))
    print("EP shard_map (ff_axis=data) == dense reference: OK")

    y_es, _ = moe_mod.moe_einsum(params, x, cfg=cfg)
    np.testing.assert_allclose(
        np.asarray(y_es), np.asarray(ref), rtol=2e-4, atol=2e-5
    )
    print("einsum path == dense reference: OK")

    # decode-style single position through the einsum path
    x1 = x[:, :1]
    ref1 = moe_mod.moe_dense_reference(params, x1, cfg=cfg)
    y1, _ = moe_mod.moe_apply(params, x1, cfg=cfg, mesh=mesh,
                              batch_axes=("data",))
    np.testing.assert_allclose(
        np.asarray(y1), np.asarray(ref1), rtol=2e-4, atol=2e-5
    )
    print("decode einsum path: OK")

    print("ALL-MD-MOE-OK")


if __name__ == "__main__":
    main()
