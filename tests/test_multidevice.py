"""Launch the multi-device semantics suites as subprocesses.

The main pytest process must keep ONE device (the 512-device flag is
reserved for the dry-run), so every multi-device test runs in a child
process with ``--xla_force_host_platform_device_count=8``.
"""

import pathlib
import subprocess
import sys

import pytest

_HERE = pathlib.Path(__file__).parent
_SRC = str(_HERE.parent / "src")


def _run(script: str) -> None:
    proc = subprocess.run(
        [sys.executable, str(_HERE / "multidevice" / script)],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": _SRC, "PATH": "/usr/bin:/bin:/usr/local/bin",
             "HOME": "/root"},
        timeout=900,
    )
    if proc.returncode != 0:
        pytest.fail(
            f"{script} failed\n--- stdout ---\n{proc.stdout[-4000:]}"
            f"\n--- stderr ---\n{proc.stderr[-4000:]}"
        )


@pytest.mark.slow
def test_multidevice_mrmr():
    _run("md_mrmr.py")


@pytest.mark.slow
def test_multidevice_train_checkpoint_elastic():
    _run("md_train.py")


@pytest.mark.slow
def test_multidevice_grad_compression():
    _run("md_compression.py")


@pytest.mark.slow
def test_multidevice_moe_exactness():
    _run("md_moe.py")


@pytest.mark.slow
def test_multidevice_pipeline_parallelism():
    _run("md_pipeline.py")
