"""Ecosystem interop: the sklearn ``MRMRTransformer`` adapter and the
columnar ``ParquetSource``/``ArrowSource`` readers, plus their composition
(Parquet -> streamed selection, transformer inside Pipeline/GridSearchCV).

Both third-party deps are soft: the whole module skips cleanly when
sklearn or pyarrow is absent from the environment.
"""

import numpy as np
import pytest

from repro import MIScore, MRMRSelector
from repro.data.binning import BinnedSource
from repro.data.sources import ArraySource
from repro.data.synthetic import corral_dataset

sklearn = pytest.importorskip("sklearn")
pa = pytest.importorskip("pyarrow")
import pyarrow.parquet as pq  # noqa: E402
from sklearn.base import clone  # noqa: E402
from sklearn.linear_model import LogisticRegression  # noqa: E402
from sklearn.model_selection import GridSearchCV  # noqa: E402
from sklearn.pipeline import make_pipeline  # noqa: E402

from repro.interop.sklearn import MRMRTransformer  # noqa: E402


@pytest.fixture(scope="module")
def corral():
    X, y = corral_dataset(1500, 24, seed=3, flip_prob=0.02)
    return np.asarray(X, np.int32), np.asarray(y)


def _table(X, y, target_name="label"):
    cols = {f"f{j}": X[:, j] for j in range(X.shape[1])}
    cols[target_name] = y
    return pa.table(cols)


class TestMRMRTransformer:
    def test_fit_transform_roundtrip(self, corral):
        X, y = corral
        tr = MRMRTransformer(num_select=5).fit(X, y)
        ref = MRMRSelector(num_select=5).fit(X, y)
        np.testing.assert_array_equal(tr.selected_, ref.selected_)
        np.testing.assert_array_equal(tr.gains_, ref.gains_)
        # sklearn contract: transform keeps ascending column order
        keep = np.sort(tr.selected_)
        np.testing.assert_array_equal(
            np.flatnonzero(tr.get_support()), keep
        )
        np.testing.assert_array_equal(tr.transform(X), X[:, keep])
        assert tr.n_features_in_ == X.shape[1]

    def test_requires_y(self, corral):
        X, _ = corral
        with pytest.raises(ValueError, match="supervised"):
            MRMRTransformer(num_select=3).fit(X)

    def test_clone_roundtrip(self):
        tr = MRMRTransformer(
            num_select=7, criterion="jmi", bins=16, block_obs=1024
        )
        params = clone(tr).get_params()
        assert params["num_select"] == 7
        assert params["criterion"] == "jmi"
        assert params["bins"] == 16
        assert params["block_obs"] == 1024

    @pytest.mark.parametrize("criterion", ["jmi", "cmim"])
    def test_criterion_passthrough(self, corral, criterion):
        X, y = corral
        tr = MRMRTransformer(num_select=5, criterion=criterion).fit(X, y)
        ref = MRMRSelector(num_select=5, criterion=criterion).fit(X, y)
        np.testing.assert_array_equal(tr.selected_, ref.selected_)
        assert tr.selector_.result_ is not None

    def test_bins_route_on_floats(self):
        rng = np.random.default_rng(11)
        X = rng.normal(size=(800, 12)).astype(np.float32)
        y = (X[:, 2] - X[:, 7] > 0).astype(np.int32)
        tr = MRMRTransformer(num_select=4, criterion="jmi", bins=8)
        Xt = tr.fit_transform(X, y)
        assert Xt.shape == (800, 4)
        assert {2, 7} <= set(tr.selected_.tolist())

    def test_pipeline(self, corral):
        X, y = corral
        pipe = make_pipeline(
            MRMRTransformer(num_select=6), LogisticRegression(max_iter=200)
        )
        pipe.fit(X, y)
        assert pipe.score(X, y) > 0.6
        assert pipe[:-1].transform(X).shape == (X.shape[0], 6)

    def test_grid_search_over_num_select(self, corral):
        X, y = corral
        pipe = make_pipeline(
            MRMRTransformer(num_select=2), LogisticRegression(max_iter=200)
        )
        gs = GridSearchCV(
            pipe,
            {"mrmrtransformer__num_select": [2, 6]},
            cv=2,
            error_score="raise",
        )
        gs.fit(X, y)
        assert gs.best_params_["mrmrtransformer__num_select"] in (2, 6)

    def test_score_passthrough(self, corral):
        X, y = corral
        tr = MRMRTransformer(num_select=4, score=MIScore(2, 2)).fit(X, y)
        ref = MRMRSelector(num_select=4, score=MIScore(2, 2)).fit(X, y)
        np.testing.assert_array_equal(tr.selected_, ref.selected_)


class TestParquetSource:
    def test_roundtrip_matches_array_source(self, tmp_path, corral):
        X, y = corral
        path = str(tmp_path / "d.parquet")
        pq.write_table(_table(X, y), path)
        from repro.data.sources import ParquetSource

        src = ParquetSource(path)
        assert src.num_obs == X.shape[0]
        assert src.num_features == X.shape[1]
        assert src.feature_dtype == np.int32
        Xm, ym = src.materialize()
        np.testing.assert_array_equal(Xm, X)
        np.testing.assert_array_equal(ym, y)

    def test_block_size_independence(self, tmp_path, corral):
        X, y = corral
        path = str(tmp_path / "d.parquet")
        # small row groups so iter_batches crosses group boundaries
        pq.write_table(_table(X, y), path, row_group_size=100)
        from repro.data.sources import ParquetSource

        src = ParquetSource(path)
        for block_obs in (64, 999, 10_000):
            got_x, got_y = [], []
            for xb, yb in src.iter_blocks(block_obs):
                assert xb.shape[0] <= block_obs
                assert xb.flags["C_CONTIGUOUS"]
                got_x.append(xb)
                got_y.append(yb)
            np.testing.assert_array_equal(np.concatenate(got_x), X)
            np.testing.assert_array_equal(np.concatenate(got_y), y)

    def test_named_target_column(self, tmp_path, corral):
        X, y = corral
        path = str(tmp_path / "d.parquet")
        # target written FIRST: name-based resolution must not care
        tbl = _table(X, y).select(
            ["label"] + [f"f{j}" for j in range(X.shape[1])]
        )
        pq.write_table(tbl, path)
        from repro.data.sources import ParquetSource

        src = ParquetSource(path, target_col="label")
        Xm, ym = src.materialize()
        np.testing.assert_array_equal(Xm, X)
        np.testing.assert_array_equal(ym, y)

    def test_missing_target_raises(self, tmp_path, corral):
        X, y = corral
        path = str(tmp_path / "d.parquet")
        pq.write_table(_table(X, y), path)
        from repro.data.sources import ParquetSource

        with pytest.raises(ValueError, match="nope"):
            ParquetSource(path, target_col="nope")

    def test_fingerprint_tracks_knobs(self, tmp_path, corral):
        X, y = corral
        path = str(tmp_path / "d.parquet")
        pq.write_table(_table(X, y), path)
        from repro.data.sources import ParquetSource

        a = ParquetSource(path).fingerprint()
        assert a == ParquetSource(path).fingerprint()
        assert a != ParquetSource(path, target_col="f0").fingerprint()
        assert a != ParquetSource(path, dtype=np.float32).fingerprint()

    def test_streamed_fit_matches_in_memory(self, tmp_path, corral):
        X, y = corral
        path = str(tmp_path / "d.parquet")
        pq.write_table(_table(X, y), path, row_group_size=256)
        from repro.data.sources import ParquetSource

        ref = MRMRSelector(num_select=5, criterion="jmi").fit(X, y)
        got = MRMRSelector(num_select=5, criterion="jmi",
                           block_obs=500).fit(ParquetSource(path))
        assert got.plan_.encoding == "streaming"
        np.testing.assert_array_equal(got.selected_, ref.selected_)
        np.testing.assert_allclose(got.gains_, ref.gains_, rtol=1e-4,
                                   atol=1e-5)

    def test_float_parquet_with_bins(self, tmp_path):
        rng = np.random.default_rng(5)
        X = rng.normal(size=(700, 10)).astype(np.float32)
        y = (X[:, 1] + X[:, 6] > 0).astype(np.int32)
        path = str(tmp_path / "f.parquet")
        pq.write_table(_table(X, y), path)
        from repro.data.sources import ParquetSource

        src = ParquetSource(path)
        assert src.feature_dtype == np.float32
        a = MRMRSelector(num_select=3, criterion="cmim", bins=8,
                         block_obs=200).fit(src)
        b = MRMRSelector(num_select=3, criterion="cmim", bins=8,
                         block_obs=200).fit(ArraySource(X, y))
        np.testing.assert_array_equal(a.selected_, b.selected_)

    def test_binned_source_composition(self, tmp_path):
        rng = np.random.default_rng(9)
        X = rng.normal(size=(600, 8)).astype(np.float32)
        y = (X[:, 4] > 0).astype(np.int32)
        path = str(tmp_path / "f.parquet")
        pq.write_table(_table(X, y), path)
        from repro.data.sources import ParquetSource

        binned = BinnedSource(ParquetSource(path), 6)
        st = binned.stats(block_obs=250)
        assert st.discrete and st.num_values == 6
        got = MRMRSelector(num_select=3, block_obs=250).fit(binned)
        assert 4 in got.selected_.tolist()


class TestArrowSource:
    def test_table_roundtrip(self, corral):
        X, y = corral
        from repro.data.sources import ArrowSource

        src = ArrowSource(_table(X, y))
        assert src.num_obs == X.shape[0]
        assert src.num_features == X.shape[1]
        Xm, ym = src.materialize(block_obs=333)
        np.testing.assert_array_equal(Xm, X)
        np.testing.assert_array_equal(ym, y)

    def test_record_batch_accepted(self, corral):
        X, y = corral
        from repro.data.sources import ArrowSource

        batch = _table(X, y).to_batches()[0]
        src = ArrowSource(batch, target_col="label")
        Xm, ym = src.materialize()
        np.testing.assert_array_equal(Xm, X)
        np.testing.assert_array_equal(ym, y)

    def test_fit_matches_array_source(self, corral):
        X, y = corral
        from repro.data.sources import ArrowSource

        a = MRMRSelector(num_select=5, criterion="cmim",
                         block_obs=400).fit(ArrowSource(_table(X, y)))
        b = MRMRSelector(num_select=5, criterion="cmim",
                         block_obs=400).fit(ArraySource(X, y))
        np.testing.assert_array_equal(a.selected_, b.selected_)
        np.testing.assert_array_equal(a.gains_, b.gains_)
