"""DataSource protocol + streaming engine: block-size invariance of
sources, streaming-vs-in-memory selection equivalence (the out-of-core
acceptance bar), placement, and the front-door API guards."""

import numpy as np
import jax
import pytest

from repro import CustomScore, MIScore, MRMRSelector, PearsonMIScore
from repro.core.streaming import mrmr_streaming
from repro.data.binning import BinnedSource
from repro.data.sources import (
    ArraySource,
    CSVSource,
    CorralSource,
    DataSource,
    NpySource,
    SyntheticTokenSource,
    as_source,
)
from repro.dist import BlockPlacer, PrefetchPlacer, factor_mesh, make_mesh


@pytest.fixture(scope="module")
def corral():
    X, y = CorralSource(1500, 24, seed=3).materialize()
    return X, y


@pytest.fixture(scope="module")
def corral_selected(corral):
    X, y = corral
    sel = MRMRSelector(num_select=5, score=MIScore(2, 2)).fit(X, y)
    return sel.selected_, sel.gains_


class TestSources:
    @pytest.mark.parametrize("block_obs", [1, 7, 100, 1500, 4096])
    def test_array_source_blocks_concatenate(self, corral, block_obs):
        X, y = corral
        src = ArraySource(X, y)
        assert (src.num_obs, src.num_features) == X.shape
        blocks = list(src.iter_blocks(block_obs))
        assert all(b[0].shape[0] <= block_obs for b in blocks)
        np.testing.assert_array_equal(np.concatenate([b[0] for b in blocks]), X)
        np.testing.assert_array_equal(np.concatenate([b[1] for b in blocks]), y)

    def test_corral_block_size_invariance(self):
        # The generated dataset must be a pure function of (seed, shape),
        # independent of how it is blocked — including sizes that straddle
        # the internal generation-chunk boundary.
        src = CorralSource(10_000, 16, seed=7)
        a = src.materialize(block_obs=613)
        b = src.materialize(block_obs=8192)
        c = src.materialize(block_obs=10_000)
        np.testing.assert_array_equal(a[0], b[0])
        np.testing.assert_array_equal(a[1], b[1])
        np.testing.assert_array_equal(a[0], c[0])

    def test_npy_source_memmap_roundtrip(self, tmp_path, corral):
        X, y = corral
        src = CorralSource(1500, 24, seed=3)
        xp, yp = src.to_npy(str(tmp_path / "X.npy"), str(tmp_path / "y.npy"),
                            block_obs=600)
        npy = NpySource(xp, yp)
        # The backing array must stay a memmap, not a loaded copy.
        assert isinstance(npy.X, np.memmap)
        Xr, yr = npy.materialize(block_obs=333)
        np.testing.assert_array_equal(Xr, X)
        np.testing.assert_array_equal(yr, y)

    def test_csv_source(self, tmp_path):
        rng = np.random.default_rng(0)
        X = rng.integers(0, 3, size=(57, 4))
        y = rng.integers(0, 2, size=57)
        path = tmp_path / "data.csv"
        header = "f0,f1,f2,f3,target\n"
        rows = "\n".join(
            ",".join(map(str, list(xr) + [yi])) for xr, yi in zip(X, y)
        )
        path.write_text(header + rows + "\n")
        src = CSVSource(str(path), dtype=np.int32)
        assert src.num_obs == 57 and src.num_features == 4
        Xr, yr = src.materialize(block_obs=13)
        np.testing.assert_array_equal(Xr, X)
        np.testing.assert_array_equal(yr, y)

    def test_csv_blank_runs_do_not_truncate(self, tmp_path):
        # A run of blank lines longer than the block must not read as EOF.
        X = np.arange(40).reshape(20, 2)
        y = np.arange(20)
        body = []
        for xr, yi in zip(X, y):
            body.append(",".join(map(str, list(xr) + [yi])))
            if yi == 9:
                body.extend([""] * 8)  # blank run wider than block_obs=5
        path = tmp_path / "gaps.csv"
        path.write_text("\n".join(body) + "\n")
        src = CSVSource(str(path), dtype=np.int32)
        Xr, yr = src.materialize(block_obs=5)
        np.testing.assert_array_equal(Xr, X)
        np.testing.assert_array_equal(yr, y)

    def test_stats_discrete(self, corral):
        X, y = corral
        st = ArraySource(X, y).stats(block_obs=256)
        assert st.discrete and st.num_values == 2 and st.num_classes == 2
        st2 = ArraySource(X.astype(np.float32), y).stats()
        assert not st2.discrete

    def test_as_source_guards(self, corral):
        X, y = corral
        src = ArraySource(X, y)
        assert as_source(src) is src
        with pytest.raises(ValueError, match="alone"):
            as_source(src, y)
        with pytest.raises(ValueError, match="target"):
            as_source(X)

    def test_token_source_is_step_pure(self):
        src = SyntheticTokenSource(32, 8, 100, seed=1)
        full = src.block(3, 0, 32)
        assert full.shape == (32, 9) and full.dtype == np.int32
        np.testing.assert_array_equal(src.block(3, 10, 20), full[10:20])


class TestStreamingEquivalence:
    # 999 does not divide 1500; 4096 exceeds it — both must still match.
    @pytest.mark.parametrize("block_obs", [128, 999, 4096])
    def test_mi_matches_in_memory(self, corral, corral_selected, block_obs):
        X, y = corral
        sel = MRMRSelector(
            num_select=5, score=MIScore(2, 2), block_obs=block_obs
        ).fit(ArraySource(X, y))
        np.testing.assert_array_equal(sel.selected_, corral_selected[0])
        np.testing.assert_allclose(sel.gains_, corral_selected[1],
                                   rtol=1e-4, atol=1e-5)
        assert sel.plan_.encoding == "streaming"

    @pytest.mark.parametrize("block_obs", [100, 257, 2048])
    def test_pearson_matches_in_memory(self, block_obs):
        from repro.data.synthetic import continuous_wide_dataset

        X, y = continuous_wide_dataset(1024, 32, seed=2)
        X, y = np.asarray(X), np.asarray(y)
        want = MRMRSelector(num_select=5, score=PearsonMIScore()).fit(X, y)
        got = MRMRSelector(
            num_select=5, score=PearsonMIScore(), block_obs=block_obs
        ).fit(ArraySource(X, y))
        np.testing.assert_array_equal(got.selected_, want.selected_)
        np.testing.assert_allclose(got.gains_, want.gains_,
                                   rtol=1e-3, atol=1e-4)

    def test_pearson_large_mean_no_cancellation(self):
        # Uncentered f32 moments cancel catastrophically when |mean| >> std
        # (sxx ~ n·mu^2 swamps the signal); the shifted accumulation must
        # keep streaming selections identical to in-memory ones.
        rng = np.random.default_rng(9)
        X = (1e4 + rng.normal(size=(50_000, 12))).astype(np.float32)
        y = (0.5 * X[:, 3] + 0.3 * X[:, 7]
             + rng.normal(size=50_000)).astype(np.float32)
        want = MRMRSelector(num_select=4, score=PearsonMIScore()).fit(X, y)
        got = MRMRSelector(
            num_select=4, score=PearsonMIScore(), block_obs=8192
        ).fit(ArraySource(X, y))
        np.testing.assert_array_equal(got.selected_, want.selected_)
        np.testing.assert_allclose(got.gains_, want.gains_,
                                   rtol=5e-2, atol=1e-3)

    def test_npy_memmap_end_to_end(self, tmp_path, corral_selected):
        # The acceptance bar: a memmapped on-disk dataset streamed in
        # blocks far smaller than the data selects identical features.
        src = CorralSource(1500, 24, seed=3)
        xp, yp = src.to_npy(str(tmp_path / "X.npy"), str(tmp_path / "y.npy"))
        sel = MRMRSelector(num_select=5, block_obs=256).fit(NpySource(xp, yp))
        np.testing.assert_array_equal(sel.selected_, corral_selected[0])
        assert sel.plan_.encoding == "streaming"
        assert sel.plan_.block_obs == 256
        # auto score resolution came from the source's streaming scan
        assert isinstance(sel.plan_.score, MIScore)

    def test_streaming_on_mesh(self, corral, corral_selected):
        X, y = corral
        n_dev = len(jax.devices())
        mesh = make_mesh((n_dev,), ("data",))
        sel = MRMRSelector(
            num_select=5, score=MIScore(2, 2), mesh=mesh, block_obs=200
        ).fit(ArraySource(X, y))
        np.testing.assert_array_equal(sel.selected_, corral_selected[0])
        # block_obs is rounded up to the mesh extent by the placer
        assert sel.mesh_ is mesh

    def test_arrays_with_streaming_encoding(self, corral, corral_selected):
        X, y = corral
        sel = MRMRSelector(
            num_select=5, score=MIScore(2, 2), encoding="streaming",
            block_obs=512,
        ).fit(X, y)
        np.testing.assert_array_equal(sel.selected_, corral_selected[0])
        assert sel.plan_.encoding == "streaming"

    def test_transform_from_source(self, corral):
        X, y = corral
        sel = MRMRSelector(num_select=4, block_obs=300).fit(ArraySource(X, y))
        Xt = sel.transform(ArraySource(X, y))
        np.testing.assert_array_equal(Xt, X[:, sel.selected_])

    def test_fit_transform_from_source_alone(self, corral):
        X, y = corral
        Xt = MRMRSelector(num_select=3, block_obs=300).fit_transform(
            ArraySource(X, y)
        )
        assert Xt.shape == (X.shape[0], 3)

    def test_driver_function_direct(self, corral, corral_selected):
        X, y = corral
        res = mrmr_streaming((X, y), 5, MIScore(2, 2), block_obs=500)
        np.testing.assert_array_equal(np.asarray(res.selected),
                                      corral_selected[0])


class TestCriterionStreaming:
    """Criterion x streaming acceptance: every criterion's streamed
    selections match the same criterion's in-memory selections at every
    tested block size and mesh."""

    # 999 does not divide 1500; 4096 exceeds it — both must still match.
    @pytest.mark.parametrize("block_obs", [128, 999, 4096])
    def test_miq_matches_in_memory(self, corral, block_obs):
        X, y = corral
        want = MRMRSelector(num_select=5, score=MIScore(2, 2),
                            criterion="miq").fit(X, y)
        got = MRMRSelector(
            num_select=5, score=MIScore(2, 2), criterion="miq",
            block_obs=block_obs,
        ).fit(ArraySource(X, y))
        np.testing.assert_array_equal(got.selected_, want.selected_)
        # gains: the quotient amplifies the tiny bf16-onehot-vs-int32-counts
        # MI differences when mean redundancy is near zero; selection
        # identity is the acceptance bar
        np.testing.assert_allclose(got.gains_, want.gains_,
                                   rtol=5e-2, atol=1e-5)
        assert got.plan_.encoding == "streaming"
        assert got.result_.criterion == "miq"

    def test_miq_on_obs_mesh(self, corral):
        X, y = corral
        n_dev = len(jax.devices())
        mesh = make_mesh((n_dev,), ("data",))
        want = MRMRSelector(num_select=5, score=MIScore(2, 2),
                            criterion="miq").fit(X, y)
        got = MRMRSelector(
            num_select=5, score=MIScore(2, 2), criterion="miq", mesh=mesh,
            block_obs=200,
        ).fit(ArraySource(X, y))
        np.testing.assert_array_equal(got.selected_, want.selected_)

    def test_miq_feature_sharded_wide(self):
        # wide regime: statistics state sharded over features, miq fold on
        # the host — must match the in-memory alternative engine.
        X, y = CorralSource(256, 1024, seed=5).materialize()
        want = MRMRSelector(num_select=5, score=MIScore(2, 2),
                            criterion="miq", encoding="alternative").fit(X, y)
        mesh = make_mesh((len(jax.devices()),), ("model",))
        got = MRMRSelector(
            num_select=5, score=MIScore(2, 2), criterion="miq", mesh=mesh,
            block_obs=100,
        ).fit(ArraySource(X, y))
        np.testing.assert_array_equal(got.selected_, want.selected_)

    def test_maxrel_single_pass_io(self, corral):
        # needs_redundancy=False must collapse streaming I/O to ONE pass
        # over the source (plus nothing else: score given explicitly, so
        # no stats() scan either).
        X, y = corral
        passes = []

        class Counting(ArraySource):
            def iter_blocks(self, block_obs):
                passes.append(block_obs)
                return super().iter_blocks(block_obs)

        sel = MRMRSelector(
            num_select=5, score=MIScore(2, 2), criterion="maxrel",
            block_obs=300,
        ).fit(Counting(X, y))
        assert len(passes) == 1
        want = MRMRSelector(num_select=5, score=MIScore(2, 2),
                            criterion="maxrel").fit(X, y)
        np.testing.assert_array_equal(sel.selected_, want.selected_)

    def test_mid_trajectory_identical_to_in_memory(self, corral,
                                                   corral_selected):
        # mid through the criterion layer keeps the pre-criterion
        # streaming contract: selections equal the in-memory engines.
        X, y = corral
        sel = MRMRSelector(
            num_select=5, score=MIScore(2, 2), criterion="mid",
            block_obs=300,
        ).fit(ArraySource(X, y))
        np.testing.assert_array_equal(sel.selected_, corral_selected[0])
        np.testing.assert_allclose(sel.gains_, corral_selected[1],
                                   rtol=1e-4, atol=1e-5)


class TestStreamingPrimitives:
    def test_mi_accumulate_equals_batch(self, corral):
        import jax.numpy as jnp

        X, y = corral
        score = MIScore(2, 2)
        state = score.init_state(X.shape[1], "class")
        state = score.accumulate(state, jnp.asarray(X[:700]), jnp.asarray(y[:700]))
        state = score.accumulate(state, jnp.asarray(X[700:]), jnp.asarray(y[700:]))
        got = np.asarray(score.finalize(state))
        want = np.asarray(score.relevance(jnp.asarray(X.T), jnp.asarray(y)))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_pearson_valid_mask_drops_padding(self):
        import jax.numpy as jnp

        rng = np.random.default_rng(5)
        X = rng.normal(size=(64, 6)).astype(np.float32)
        t = rng.normal(size=64).astype(np.float32)
        score = PearsonMIScore()
        full = score.accumulate(score.init_state(6), jnp.asarray(X),
                                jnp.asarray(t))
        Xp = np.concatenate([X, np.full((16, 6), 1e6, np.float32)])
        tp = np.concatenate([t, np.full((16,), -1e6, np.float32)])
        valid = np.arange(80) < 64
        masked = score.accumulate(
            score.init_state(6), jnp.asarray(Xp), jnp.asarray(tp),
            jnp.asarray(valid),
        )
        np.testing.assert_allclose(
            np.asarray(score.finalize(masked)),
            np.asarray(score.finalize(full)), rtol=1e-5, atol=1e-6,
        )

    def test_block_placer_rounds_up_to_mesh(self):
        n_dev = len(jax.devices())
        mesh = make_mesh((n_dev,), ("data",))
        placer = BlockPlacer(100, mesh, ("data",))
        assert placer.block_obs % n_dev == 0
        X, t, valid = placer(np.zeros((37, 3), np.int8), np.zeros(37, np.int8))
        assert X.shape[0] == placer.block_obs
        assert int(np.asarray(valid).sum()) == 37

    def test_block_placer_rejects_oversized(self):
        placer = BlockPlacer(16)
        with pytest.raises(ValueError, match="exceeds"):
            placer(np.zeros((17, 2), np.int8), np.zeros(17, np.int8))

    def test_block_placer_rejects_axisless_mesh(self):
        mesh = make_mesh((1,), ("model",))
        with pytest.raises(ValueError, match="no axis"):
            BlockPlacer(16, mesh, ("data",))

    def test_block_placer_pads_features(self):
        mesh = make_mesh((len(jax.devices()),), ("model",))
        placer = BlockPlacer(8, mesh, (), ("model",), num_features=5)
        n_pad = placer.padded_features
        assert n_pad % len(jax.devices()) == 0 and n_pad >= 5
        X, t, valid = placer(np.ones((8, 5), np.int8), np.zeros(8, np.int8))
        assert X.shape == (8, n_pad)
        # pad columns are zero-filled, real columns intact
        assert np.asarray(X)[:, :5].all()
        assert not np.asarray(X)[:, 5:].any()

    def test_block_placer_rejects_feature_mismatch(self):
        placer = BlockPlacer(8, num_features=5)
        with pytest.raises(ValueError, match="features"):
            placer(np.zeros((4, 7), np.int8), np.zeros(4, np.int8))

    def test_block_placer_feature_sharding_needs_num_features(self):
        # Feature sharding without the global feature count would fail
        # late (opaque device_put error) or silently replicate the state.
        mesh = make_mesh((len(jax.devices()),), ("model",))
        with pytest.raises(ValueError, match="num_features"):
            BlockPlacer(8, mesh, (), ("model",))

    def test_state_sharded_over_features(self):
        # The wide-regime memory claim: per-device statistics hold
        # padded_features / shards feature rows, not all of them.
        n_dev = len(jax.devices())
        mesh = make_mesh((n_dev,), ("model",))
        placer = BlockPlacer(64, mesh, (), ("model",), num_features=32)
        state = placer.place_state(
            MIScore(2, 2).init_state(placer.padded_features)
        )
        shard_rows = {s.data.shape[0] for s in state.addressable_shards}
        assert shard_rows == {placer.padded_features // n_dev}


@pytest.fixture(scope="module")
def wide():
    # 256 obs x 1024 feat: m/n = 0.25, the paper's wide/bioinformatics
    # regime where statistics must shard over features.
    X, y = CorralSource(256, 1024, seed=5).materialize()
    return X, y


@pytest.fixture(scope="module")
def wide_alternative(wide):
    X, y = wide
    sel = MRMRSelector(
        num_select=5, score=MIScore(2, 2), encoding="alternative"
    ).fit(X, y)
    return sel.selected_, sel.gains_


class TestWideStreaming:
    """Wide-regime acceptance: feature-sharded and 2-D streaming selections
    identical to the in-memory alternative engine at every block size."""

    # 64 divides 256; 100 doesn't; 999 exceeds it — all must match.
    @pytest.mark.parametrize("block_obs", [64, 100, 999])
    def test_feature_sharded_matches_alternative(
        self, wide, wide_alternative, block_obs
    ):
        X, y = wide
        mesh = make_mesh((len(jax.devices()),), ("model",))
        sel = MRMRSelector(
            num_select=5, score=MIScore(2, 2), mesh=mesh, block_obs=block_obs
        ).fit(ArraySource(X, y))
        np.testing.assert_array_equal(sel.selected_, wide_alternative[0])
        np.testing.assert_allclose(sel.gains_, wide_alternative[1],
                                   rtol=1e-4, atol=1e-5)
        assert sel.plan_.encoding == "streaming"
        assert sel.plan_.obs_axes == () and sel.plan_.feat_axes == ("model",)

    def test_non_divisible_feature_count(self):
        # 30 features don't divide a multi-device feature mesh: the placer
        # pads columns, the engine slices the junk statistics rows off.
        X, y = CorralSource(200, 30, seed=1).materialize()
        want = MRMRSelector(
            num_select=4, score=MIScore(2, 2), encoding="alternative"
        ).fit(X, y)
        mesh = make_mesh((len(jax.devices()),), ("model",))
        got = MRMRSelector(
            num_select=4, score=MIScore(2, 2), mesh=mesh, block_obs=64
        ).fit(ArraySource(X, y))
        np.testing.assert_array_equal(got.selected_, want.selected_)

    def test_grid_2d_matches_alternative(self, wide, wide_alternative):
        X, y = wide
        od, fd = factor_mesh(len(jax.devices()))
        mesh = make_mesh((od, fd), ("data", "model"))
        sel = MRMRSelector(
            num_select=5, score=MIScore(2, 2), mesh=mesh, block_obs=100
        ).fit(ArraySource(X, y))
        np.testing.assert_array_equal(sel.selected_, wide_alternative[0])
        assert sel.plan_.obs_axes == ("data",)
        assert sel.plan_.feat_axes == ("model",)
        # the plan reports the EFFECTIVE block size (rounded to obs extent)
        assert sel.plan_.block_obs == -(-100 // od) * od

    def test_pearson_feature_sharded(self):
        rng = np.random.default_rng(7)
        X = rng.normal(size=(200, 600)).astype(np.float32)
        y = (0.5 * X[:, 3] + 0.3 * X[:, 10]
             + 0.1 * rng.normal(size=200)).astype(np.float32)
        want = MRMRSelector(
            num_select=4, score=PearsonMIScore(), encoding="alternative"
        ).fit(X, y)
        mesh = make_mesh((len(jax.devices()),), ("model",))
        got = MRMRSelector(
            num_select=4, score=PearsonMIScore(), mesh=mesh, block_obs=64
        ).fit(ArraySource(X, y))
        np.testing.assert_array_equal(got.selected_, want.selected_)
        np.testing.assert_allclose(got.gains_, want.gains_,
                                   rtol=1e-3, atol=1e-4)

    def test_auto_wide_plan_runs_feature_sharded(self, wide, wide_alternative):
        # No user mesh: the aspect rule itself must route a wide source to
        # feature sharding (or unsharded on one device) and still match.
        X, y = wide
        sel = MRMRSelector(num_select=5, score=MIScore(2, 2),
                           block_obs=100).fit(ArraySource(X, y))
        np.testing.assert_array_equal(sel.selected_, wide_alternative[0])
        assert sel.plan_.obs_axes == ()
        if len(jax.devices()) > 1:
            assert sel.plan_.feat_axes == ("model",)

    def test_stream_plan_aspect_rule(self):
        # §III rule on an 8-device budget (plan-only, no mesh built):
        # tall -> obs-sharded, wide -> feat-sharded, both-large -> 2-D.
        score = MIScore(2, 2)
        sel = MRMRSelector(num_select=2, devices=8)
        z = lambda m, n: ArraySource(
            np.zeros((m, n), np.int8), np.zeros(m, np.int8)
        )
        tall = sel._resolve_stream_plan(z(4096, 64), score)
        assert tall.obs_axes == ("data",) and tall.feat_axes == ()
        assert tall.mesh_shape == (8,)
        wide = sel._resolve_stream_plan(z(64, 4096), score)
        assert wide.obs_axes == () and wide.feat_axes == ("model",)
        assert wide.mesh_shape == (8,)
        grid = sel._resolve_stream_plan(z(1024, 1024), score)
        assert grid.obs_axes == ("data",) and grid.feat_axes == ("model",)
        assert len(grid.mesh_shape) == 2 and min(grid.mesh_shape) > 1

    def test_plan_records_effective_block_obs(self):
        # Satellite: plan_ must report the placer's rounded block size,
        # not the user's requested one.
        score = MIScore(2, 2)
        sel = MRMRSelector(num_select=2, devices=8, block_obs=100)
        src = ArraySource(np.zeros((4096, 64), np.int8),
                          np.zeros(4096, np.int8))
        plan = sel._resolve_stream_plan(src, score)
        assert plan.block_obs == 104  # rounded up to the 8-way obs extent

    def test_effective_block_obs_end_to_end(self, corral):
        X, y = corral
        n_dev = len(jax.devices())
        mesh = make_mesh((n_dev,), ("data",))
        sel = MRMRSelector(num_select=2, score=MIScore(2, 2), mesh=mesh,
                           block_obs=200).fit(ArraySource(X, y))
        assert sel.plan_.block_obs == -(-200 // n_dev) * n_dev


class TestPrefetch:
    def test_prefetch_depths_match_synchronous(self, corral, corral_selected):
        X, y = corral
        for prefetch in (0, 1, 3):
            sel = MRMRSelector(
                num_select=5, score=MIScore(2, 2), block_obs=300,
                prefetch=prefetch,
            ).fit(ArraySource(X, y))
            np.testing.assert_array_equal(sel.selected_, corral_selected[0])

    def test_prefetch_propagates_source_errors(self, corral):
        X, y = corral

        class Boom(ArraySource):
            def iter_blocks(self, block_obs):
                it = super().iter_blocks(block_obs)
                yield next(it)
                raise RuntimeError("disk died")

        with pytest.raises(RuntimeError, match="disk died"):
            MRMRSelector(
                num_select=2, score=MIScore(2, 2), block_obs=300, prefetch=2
            ).fit(Boom(X, y))

    def test_prefetch_placer_stream(self):
        placer = BlockPlacer(4, num_features=3)
        blocks = [
            (np.full((4, 3), i, np.int8), np.full((4,), i, np.int8))
            for i in range(5)
        ]
        out = list(PrefetchPlacer(placer, depth=2).stream(iter(blocks)))
        assert len(out) == 5
        for i, (X, t, valid) in enumerate(out):
            assert int(np.asarray(X)[0, 0]) == i
            assert np.asarray(valid).all()

    def test_prefetch_placer_abandoned_consumer_stops_worker(self):
        import threading

        placer = BlockPlacer(2, num_features=1)
        produced = []

        def blocks():
            for i in range(1000):
                produced.append(i)
                yield np.zeros((2, 1), np.int8), np.zeros(2, np.int8)

        stream = PrefetchPlacer(placer, depth=1).stream(blocks())
        next(stream)
        stream.close()  # abandon: the worker must stop, not run to 1000
        deadline = len(produced)
        assert deadline < 1000
        # no stray prefetch threads left running
        assert not any(
            t.name == "block-prefetch" and t.is_alive()
            for t in threading.enumerate()
        )

    def test_depth_guard(self):
        with pytest.raises(ValueError, match="depth"):
            PrefetchPlacer(BlockPlacer(8), depth=0)
        with pytest.raises(ValueError, match="prefetch"):
            mrmr_streaming(
                (np.zeros((8, 4), np.int8), np.zeros(8, np.int8)),
                2, MIScore(2, 2), prefetch=-1,
            )


class TestSatelliteRegressions:
    def test_array_source_rejects_2d_target(self, corral):
        # (M, k) targets used to slip through the leading-dim check and
        # mis-shape Pearson streaming accumulation downstream.
        X, y = corral
        with pytest.raises(ValueError, match="bad shapes"):
            ArraySource(X, np.stack([y, y], axis=1))
        with pytest.raises(ValueError, match="bad shapes"):
            ArraySource(X, y[:, None])

    def test_to_npy_closes_peek_iterator(self, tmp_path, corral):
        # The one-row dtype peek must close its block iterator explicitly
        # (an abandoned generator holds e.g. CSVSource's file open until
        # GC).  A non-generator iterator never gets auto-closed, so this
        # fails without the explicit close.
        X, y = corral
        closed = []

        class PeekTrackingSource(ArraySource):
            def iter_blocks(self, block_obs):
                inner = super().iter_blocks(block_obs)

                class It:
                    def __iter__(self):
                        return self

                    def __next__(self):
                        return next(inner)

                    def close(self):
                        closed.append(block_obs)

                return It()

        src = PeekTrackingSource(X, y)
        src.to_npy(str(tmp_path / "X.npy"), str(tmp_path / "y.npy"))
        assert 1 in closed  # the block_obs=1 peek iterator was closed

    def test_stats_rejects_negative_categories(self):
        y = np.array([0, 1], np.int32)
        bad_x = ArraySource(np.array([[0, 1], [-1, 2]], np.int32), y)
        with pytest.raises(ValueError, match="negative category"):
            bad_x.stats()
        bad_y = ArraySource(np.array([[0, 1], [1, 2]], np.int32),
                            np.array([0, -1], np.int32))
        with pytest.raises(ValueError, match="negative category"):
            bad_y.stats()
        # continuous data may be negative — no validation there
        ok = ArraySource(np.array([[-1.0, 1.0]], np.float32),
                         np.array([0.5], np.float32))
        assert not ok.stats().discrete

    def test_streaming_fit_rejects_negative_categories(self):
        X = np.array([[0, 1], [-1, 2], [1, 0]], np.int32)
        y = np.array([0, 1, 0], np.int32)
        with pytest.raises(ValueError, match="negative category"):
            MRMRSelector(num_select=1).fit(ArraySource(X, y))

    def test_in_memory_fit_rejects_negative_categories(self):
        X = np.array([[0, 1], [2, -3], [1, 0]], np.int32)
        y = np.array([0, 1, 0], np.int32)
        with pytest.raises(ValueError, match="negative category"):
            MRMRSelector(num_select=1).fit(X, y)


class TestFrontDoorGuards:
    def test_y_with_source_raises(self, corral):
        X, y = corral
        with pytest.raises(ValueError, match="alone"):
            MRMRSelector(num_select=2).fit(ArraySource(X, y), y)

    def test_missing_y_raises(self, corral):
        X, _ = corral
        with pytest.raises(ValueError, match="required"):
            MRMRSelector(num_select=2).fit(X)

    def test_in_memory_encoding_rejects_source(self, corral):
        X, y = corral
        with pytest.raises(ValueError, match="in-memory"):
            MRMRSelector(num_select=2, encoding="grid").fit(ArraySource(X, y))

    def test_custom_score_cannot_stream(self, corral):
        X, y = corral
        score = CustomScore(get_result=lambda v, c, s, n: 0.0)
        with pytest.raises(ValueError, match="stream"):
            MRMRSelector(num_select=2, score=score).fit(ArraySource(X, y))

    def test_num_select_out_of_range(self, corral):
        X, y = corral
        with pytest.raises(ValueError, match="out of range"):
            MRMRSelector(num_select=99).fit(ArraySource(X, y))

    def test_mesh_without_any_shardable_axis_raises(self, corral):
        # A user-supplied mesh the streaming engine can't shard over (no
        # observation OR feature axis) must fail loudly, not silently run
        # single-device.
        X, y = corral
        mesh = make_mesh((1,), ("pipe",))
        with pytest.raises(ValueError, match="obs_axes"):
            MRMRSelector(num_select=2, score=MIScore(2, 2),
                         mesh=mesh).fit(ArraySource(X, y))


class TestBinnedStreaming:
    """Binned (continuous -> on-the-fly codes) streaming equivalence: the
    fused device-side encode must reproduce the in-memory binned fit at
    every block size and mesh regime."""

    def _data(self, n=1800, f=12, seed=21):
        rng = np.random.default_rng(seed)
        y = rng.integers(0, 2, size=n)
        X = rng.normal(size=(n, f))
        for j in range(4):
            X[:, j] += y * (1.6 - 0.35 * j)
        return X, y

    @pytest.mark.parametrize("block_obs", [128, 999, 4096])
    def test_matches_in_memory(self, block_obs):
        X, y = self._data()
        want = MRMRSelector(num_select=4, bins=16).fit(X, y)
        got = MRMRSelector(num_select=4, bins=16, block_obs=block_obs).fit(
            ArraySource(X, y)
        )
        assert got.plan_.encoding == "streaming" and got.plan_.bins == 16
        np.testing.assert_array_equal(got.selected_, want.selected_)
        np.testing.assert_allclose(got.gains_, want.gains_, rtol=1e-5,
                                   atol=1e-6)

    def test_obs_sharded_mesh(self):
        X, y = self._data(seed=22)
        want = MRMRSelector(num_select=4, bins=8).fit(X, y)
        mesh = make_mesh((len(jax.devices()),), ("data",))
        got = MRMRSelector(num_select=4, bins=8, mesh=mesh,
                           block_obs=256).fit(ArraySource(X, y))
        np.testing.assert_array_equal(got.selected_, want.selected_)

    def test_feature_sharded_wide(self):
        # wide regime: raw float blocks AND the fitted edges shard over
        # feat_axes; device-side codes must still equal the host encode.
        rng = np.random.default_rng(23)
        n, f = 256, 1024
        y = rng.integers(0, 2, size=n)
        X = rng.normal(size=(n, f))
        for j in range(5):
            X[:, j] += y * (1.8 - 0.3 * j)
        want = MRMRSelector(num_select=5, bins=8).fit(X, y)
        mesh = make_mesh((len(jax.devices()),), ("model",))
        got = MRMRSelector(num_select=5, bins=8, mesh=mesh,
                           block_obs=64).fit(ArraySource(X, y))
        assert got.plan_.feat_axes == ("model",)
        np.testing.assert_array_equal(got.selected_, want.selected_)

    def test_grid_mesh(self):
        rng = np.random.default_rng(24)
        n, f = 400, 512
        y = rng.integers(0, 2, size=n)
        X = rng.normal(size=(n, f))
        for j in range(4):
            X[:, j] += y * (1.5 - 0.3 * j)
        want = MRMRSelector(num_select=4, bins=8).fit(X, y)
        n_dev = len(jax.devices())
        od = 2 if n_dev % 2 == 0 else 1
        mesh = make_mesh((od, n_dev // od), ("data", "model"))
        got = MRMRSelector(num_select=4, bins=8, mesh=mesh,
                           block_obs=100).fit(ArraySource(X, y))
        np.testing.assert_array_equal(got.selected_, want.selected_)

    def test_sketch_pass_costs_one_extra_io_pass(self):
        # Binning adds exactly ONE extra pass (the sketch) to streaming's
        # L scoring passes.  For an in-memory ArraySource the binner memo
        # key also reads once — the fingerprint content hash (iter at
        # 65536; file-backed sources hash stat() metadata instead).  The
        # discrete-vs-continuous routing itself is free: feature_dtype
        # answers without touching iter_blocks.
        from repro.data.binning import clear_binner_memo
        from repro.data.sources import clear_stats_memo

        clear_binner_memo()
        clear_stats_memo()
        X, y = self._data(seed=25)
        passes = []

        class Counting(ArraySource):
            def iter_blocks(self, block_obs):
                passes.append(block_obs)
                return super().iter_blocks(block_obs)

        MRMRSelector(num_select=3, bins=8, block_obs=300).fit(
            Counting(X, y)
        )
        # fingerprint + sketch + relevance + 2 redundancy (the scoring
        # passes may round 300 up to the mesh's obs extent)
        assert len(passes) == 5 and passes[0] == 65536, passes
        clear_binner_memo()

    def test_pearson_on_binned_codes_streams_unfused(self):
        # A non-MI score on a BinnedSource takes the host-encode path
        # (wrapper iter_blocks) and still fits fine.
        X, y = self._data(seed=26)
        src = BinnedSource(ArraySource(X, y), 8)
        got = MRMRSelector(num_select=3, score=PearsonMIScore(),
                           block_obs=500).fit(src)
        codes, labels = src.materialize()
        want = MRMRSelector(num_select=3, score=PearsonMIScore()).fit(
            codes.astype(np.float32), labels
        )
        np.testing.assert_array_equal(got.selected_, want.selected_)
