"""DataSource protocol + streaming engine: block-size invariance of
sources, streaming-vs-in-memory selection equivalence (the out-of-core
acceptance bar), placement, and the front-door API guards."""

import numpy as np
import jax
import pytest

from repro import CustomScore, MIScore, MRMRSelector, PearsonMIScore
from repro.core.streaming import mrmr_streaming
from repro.data.sources import (
    ArraySource,
    CSVSource,
    CorralSource,
    NpySource,
    SyntheticTokenSource,
    as_source,
)
from repro.dist import BlockPlacer, make_mesh


@pytest.fixture(scope="module")
def corral():
    X, y = CorralSource(1500, 24, seed=3).materialize()
    return X, y


@pytest.fixture(scope="module")
def corral_selected(corral):
    X, y = corral
    sel = MRMRSelector(num_select=5, score=MIScore(2, 2)).fit(X, y)
    return sel.selected_, sel.gains_


class TestSources:
    @pytest.mark.parametrize("block_obs", [1, 7, 100, 1500, 4096])
    def test_array_source_blocks_concatenate(self, corral, block_obs):
        X, y = corral
        src = ArraySource(X, y)
        assert (src.num_obs, src.num_features) == X.shape
        blocks = list(src.iter_blocks(block_obs))
        assert all(b[0].shape[0] <= block_obs for b in blocks)
        np.testing.assert_array_equal(np.concatenate([b[0] for b in blocks]), X)
        np.testing.assert_array_equal(np.concatenate([b[1] for b in blocks]), y)

    def test_corral_block_size_invariance(self):
        # The generated dataset must be a pure function of (seed, shape),
        # independent of how it is blocked — including sizes that straddle
        # the internal generation-chunk boundary.
        src = CorralSource(10_000, 16, seed=7)
        a = src.materialize(block_obs=613)
        b = src.materialize(block_obs=8192)
        c = src.materialize(block_obs=10_000)
        np.testing.assert_array_equal(a[0], b[0])
        np.testing.assert_array_equal(a[1], b[1])
        np.testing.assert_array_equal(a[0], c[0])

    def test_npy_source_memmap_roundtrip(self, tmp_path, corral):
        X, y = corral
        src = CorralSource(1500, 24, seed=3)
        xp, yp = src.to_npy(str(tmp_path / "X.npy"), str(tmp_path / "y.npy"),
                            block_obs=600)
        npy = NpySource(xp, yp)
        # The backing array must stay a memmap, not a loaded copy.
        assert isinstance(npy.X, np.memmap)
        Xr, yr = npy.materialize(block_obs=333)
        np.testing.assert_array_equal(Xr, X)
        np.testing.assert_array_equal(yr, y)

    def test_csv_source(self, tmp_path):
        rng = np.random.default_rng(0)
        X = rng.integers(0, 3, size=(57, 4))
        y = rng.integers(0, 2, size=57)
        path = tmp_path / "data.csv"
        header = "f0,f1,f2,f3,target\n"
        rows = "\n".join(
            ",".join(map(str, list(xr) + [yi])) for xr, yi in zip(X, y)
        )
        path.write_text(header + rows + "\n")
        src = CSVSource(str(path), dtype=np.int32)
        assert src.num_obs == 57 and src.num_features == 4
        Xr, yr = src.materialize(block_obs=13)
        np.testing.assert_array_equal(Xr, X)
        np.testing.assert_array_equal(yr, y)

    def test_csv_blank_runs_do_not_truncate(self, tmp_path):
        # A run of blank lines longer than the block must not read as EOF.
        X = np.arange(40).reshape(20, 2)
        y = np.arange(20)
        body = []
        for xr, yi in zip(X, y):
            body.append(",".join(map(str, list(xr) + [yi])))
            if yi == 9:
                body.extend([""] * 8)  # blank run wider than block_obs=5
        path = tmp_path / "gaps.csv"
        path.write_text("\n".join(body) + "\n")
        src = CSVSource(str(path), dtype=np.int32)
        Xr, yr = src.materialize(block_obs=5)
        np.testing.assert_array_equal(Xr, X)
        np.testing.assert_array_equal(yr, y)

    def test_stats_discrete(self, corral):
        X, y = corral
        st = ArraySource(X, y).stats(block_obs=256)
        assert st.discrete and st.num_values == 2 and st.num_classes == 2
        st2 = ArraySource(X.astype(np.float32), y).stats()
        assert not st2.discrete

    def test_as_source_guards(self, corral):
        X, y = corral
        src = ArraySource(X, y)
        assert as_source(src) is src
        with pytest.raises(ValueError, match="alone"):
            as_source(src, y)
        with pytest.raises(ValueError, match="target"):
            as_source(X)

    def test_token_source_is_step_pure(self):
        src = SyntheticTokenSource(32, 8, 100, seed=1)
        full = src.block(3, 0, 32)
        assert full.shape == (32, 9) and full.dtype == np.int32
        np.testing.assert_array_equal(src.block(3, 10, 20), full[10:20])


class TestStreamingEquivalence:
    # 999 does not divide 1500; 4096 exceeds it — both must still match.
    @pytest.mark.parametrize("block_obs", [128, 999, 4096])
    def test_mi_matches_in_memory(self, corral, corral_selected, block_obs):
        X, y = corral
        sel = MRMRSelector(
            num_select=5, score=MIScore(2, 2), block_obs=block_obs
        ).fit(ArraySource(X, y))
        np.testing.assert_array_equal(sel.selected_, corral_selected[0])
        np.testing.assert_allclose(sel.gains_, corral_selected[1],
                                   rtol=1e-4, atol=1e-5)
        assert sel.plan_.encoding == "streaming"

    @pytest.mark.parametrize("block_obs", [100, 257, 2048])
    def test_pearson_matches_in_memory(self, block_obs):
        from repro.data.synthetic import continuous_wide_dataset

        X, y = continuous_wide_dataset(1024, 32, seed=2)
        X, y = np.asarray(X), np.asarray(y)
        want = MRMRSelector(num_select=5, score=PearsonMIScore()).fit(X, y)
        got = MRMRSelector(
            num_select=5, score=PearsonMIScore(), block_obs=block_obs
        ).fit(ArraySource(X, y))
        np.testing.assert_array_equal(got.selected_, want.selected_)
        np.testing.assert_allclose(got.gains_, want.gains_,
                                   rtol=1e-3, atol=1e-4)

    def test_pearson_large_mean_no_cancellation(self):
        # Uncentered f32 moments cancel catastrophically when |mean| >> std
        # (sxx ~ n·mu^2 swamps the signal); the shifted accumulation must
        # keep streaming selections identical to in-memory ones.
        rng = np.random.default_rng(9)
        X = (1e4 + rng.normal(size=(50_000, 12))).astype(np.float32)
        y = (0.5 * X[:, 3] + 0.3 * X[:, 7]
             + rng.normal(size=50_000)).astype(np.float32)
        want = MRMRSelector(num_select=4, score=PearsonMIScore()).fit(X, y)
        got = MRMRSelector(
            num_select=4, score=PearsonMIScore(), block_obs=8192
        ).fit(ArraySource(X, y))
        np.testing.assert_array_equal(got.selected_, want.selected_)
        np.testing.assert_allclose(got.gains_, want.gains_,
                                   rtol=5e-2, atol=1e-3)

    def test_npy_memmap_end_to_end(self, tmp_path, corral_selected):
        # The acceptance bar: a memmapped on-disk dataset streamed in
        # blocks far smaller than the data selects identical features.
        src = CorralSource(1500, 24, seed=3)
        xp, yp = src.to_npy(str(tmp_path / "X.npy"), str(tmp_path / "y.npy"))
        sel = MRMRSelector(num_select=5, block_obs=256).fit(NpySource(xp, yp))
        np.testing.assert_array_equal(sel.selected_, corral_selected[0])
        assert sel.plan_.encoding == "streaming"
        assert sel.plan_.block_obs == 256
        # auto score resolution came from the source's streaming scan
        assert isinstance(sel.plan_.score, MIScore)

    def test_streaming_on_mesh(self, corral, corral_selected):
        X, y = corral
        n_dev = len(jax.devices())
        mesh = make_mesh((n_dev,), ("data",))
        sel = MRMRSelector(
            num_select=5, score=MIScore(2, 2), mesh=mesh, block_obs=200
        ).fit(ArraySource(X, y))
        np.testing.assert_array_equal(sel.selected_, corral_selected[0])
        # block_obs is rounded up to the mesh extent by the placer
        assert sel.mesh_ is mesh

    def test_arrays_with_streaming_encoding(self, corral, corral_selected):
        X, y = corral
        sel = MRMRSelector(
            num_select=5, score=MIScore(2, 2), encoding="streaming",
            block_obs=512,
        ).fit(X, y)
        np.testing.assert_array_equal(sel.selected_, corral_selected[0])
        assert sel.plan_.encoding == "streaming"

    def test_transform_from_source(self, corral):
        X, y = corral
        sel = MRMRSelector(num_select=4, block_obs=300).fit(ArraySource(X, y))
        Xt = sel.transform(ArraySource(X, y))
        np.testing.assert_array_equal(Xt, X[:, sel.selected_])

    def test_fit_transform_from_source_alone(self, corral):
        X, y = corral
        Xt = MRMRSelector(num_select=3, block_obs=300).fit_transform(
            ArraySource(X, y)
        )
        assert Xt.shape == (X.shape[0], 3)

    def test_driver_function_direct(self, corral, corral_selected):
        X, y = corral
        res = mrmr_streaming((X, y), 5, MIScore(2, 2), block_obs=500)
        np.testing.assert_array_equal(np.asarray(res.selected),
                                      corral_selected[0])


class TestStreamingPrimitives:
    def test_mi_accumulate_equals_batch(self, corral):
        import jax.numpy as jnp

        X, y = corral
        score = MIScore(2, 2)
        state = score.init_state(X.shape[1], "class")
        state = score.accumulate(state, jnp.asarray(X[:700]), jnp.asarray(y[:700]))
        state = score.accumulate(state, jnp.asarray(X[700:]), jnp.asarray(y[700:]))
        got = np.asarray(score.finalize(state))
        want = np.asarray(score.relevance(jnp.asarray(X.T), jnp.asarray(y)))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_pearson_valid_mask_drops_padding(self):
        import jax.numpy as jnp

        rng = np.random.default_rng(5)
        X = rng.normal(size=(64, 6)).astype(np.float32)
        t = rng.normal(size=64).astype(np.float32)
        score = PearsonMIScore()
        full = score.accumulate(score.init_state(6), jnp.asarray(X),
                                jnp.asarray(t))
        Xp = np.concatenate([X, np.full((16, 6), 1e6, np.float32)])
        tp = np.concatenate([t, np.full((16,), -1e6, np.float32)])
        valid = np.arange(80) < 64
        masked = score.accumulate(
            score.init_state(6), jnp.asarray(Xp), jnp.asarray(tp),
            jnp.asarray(valid),
        )
        np.testing.assert_allclose(
            np.asarray(score.finalize(masked)),
            np.asarray(score.finalize(full)), rtol=1e-5, atol=1e-6,
        )

    def test_block_placer_rounds_up_to_mesh(self):
        n_dev = len(jax.devices())
        mesh = make_mesh((n_dev,), ("data",))
        placer = BlockPlacer(100, mesh, ("data",))
        assert placer.block_obs % n_dev == 0
        X, t, valid = placer(np.zeros((37, 3), np.int8), np.zeros(37, np.int8))
        assert X.shape[0] == placer.block_obs
        assert int(np.asarray(valid).sum()) == 37

    def test_block_placer_rejects_oversized(self):
        placer = BlockPlacer(16)
        with pytest.raises(ValueError, match="exceeds"):
            placer(np.zeros((17, 2), np.int8), np.zeros(17, np.int8))

    def test_block_placer_rejects_axisless_mesh(self):
        mesh = make_mesh((1,), ("model",))
        with pytest.raises(ValueError, match="no axis"):
            BlockPlacer(16, mesh, ("data",))


class TestFrontDoorGuards:
    def test_y_with_source_raises(self, corral):
        X, y = corral
        with pytest.raises(ValueError, match="alone"):
            MRMRSelector(num_select=2).fit(ArraySource(X, y), y)

    def test_missing_y_raises(self, corral):
        X, _ = corral
        with pytest.raises(ValueError, match="required"):
            MRMRSelector(num_select=2).fit(X)

    def test_in_memory_encoding_rejects_source(self, corral):
        X, y = corral
        with pytest.raises(ValueError, match="in-memory"):
            MRMRSelector(num_select=2, encoding="grid").fit(ArraySource(X, y))

    def test_custom_score_cannot_stream(self, corral):
        X, y = corral
        score = CustomScore(get_result=lambda v, c, s, n: 0.0)
        with pytest.raises(ValueError, match="stream"):
            MRMRSelector(num_select=2, score=score).fit(ArraySource(X, y))

    def test_num_select_out_of_range(self, corral):
        X, y = corral
        with pytest.raises(ValueError, match="out of range"):
            MRMRSelector(num_select=99).fit(ArraySource(X, y))

    def test_mesh_without_obs_axis_raises(self, corral):
        # A user-supplied mesh the streaming engine can't shard over must
        # fail loudly, not silently run single-device.
        X, y = corral
        mesh = make_mesh((1,), ("model",))
        with pytest.raises(ValueError, match="obs_axes"):
            MRMRSelector(num_select=2, score=MIScore(2, 2),
                         mesh=mesh).fit(ArraySource(X, y))
