"""Property-based tests (hypothesis) for mRMR system invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st, assume, HealthCheck  # noqa: E402

from repro.core import MIScore, mrmr_reference, mi_from_counts

_SETTINGS = dict(
    max_examples=10,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


def _dataset(draw, max_n=10, max_m=96, num_values=2):
    n = draw(st.integers(4, max_n))
    m = draw(st.integers(16, max_m))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    X = rng.integers(0, num_values, (n, m)).astype(np.int32)  # feature-major
    y = rng.integers(0, 2, m).astype(np.int32)
    return X, y


@st.composite
def datasets(draw):
    return _dataset(draw)


@st.composite
def datasets_v3(draw):
    return _dataset(draw, num_values=3)


@given(datasets())
@settings(**_SETTINGS)
def test_selection_unique_and_in_range(data):
    X, y = data
    n = X.shape[0]
    L = min(4, n)
    res = mrmr_reference(jnp.asarray(X), jnp.asarray(y), L, MIScore(2, 2))
    sel = np.asarray(res.selected)
    assert len(np.unique(sel)) == L
    assert sel.min() >= 0 and sel.max() < n


@given(datasets())
@settings(**_SETTINGS)
def test_incremental_equals_faithful(data):
    X, y = data
    L = min(5, X.shape[0])
    a = mrmr_reference(jnp.asarray(X), jnp.asarray(y), L, MIScore(2, 2),
                       incremental=True)
    b = mrmr_reference(jnp.asarray(X), jnp.asarray(y), L, MIScore(2, 2),
                       incremental=False)
    np.testing.assert_array_equal(np.asarray(a.selected), np.asarray(b.selected))
    np.testing.assert_allclose(a.gains, b.gains, rtol=1e-4, atol=1e-5)


def _np_mrmr_with_gaps(X, y, L, v=2):
    """Numpy mRMR returning (selection, min top-2 score gap across steps)."""
    from tests.test_scores import np_mi, np_pair_counts

    n = X.shape[0]
    rel = np.array([np_mi(np_pair_counts(X[k], y, v, 2)) for k in range(n)])
    pair = np.array(
        [[np_mi(np_pair_counts(X[k], X[j], v, v)) for j in range(n)]
         for k in range(n)]
    )
    selected, min_gap = [], np.inf
    for l in range(L):
        red = (pair[:, selected].mean(axis=1) if selected else np.zeros(n))
        g = rel - red
        g[selected] = -np.inf
        order = np.argsort(g)[::-1]
        gap = g[order[0]] - g[order[1]] if n - len(selected) > 1 else np.inf
        min_gap = min(min_gap, gap)
        selected.append(int(order[0]))
    return selected, min_gap


@given(datasets(), st.integers(0, 2**31 - 1))
@settings(**_SETTINGS)
def test_selection_permutation_equivariant(data, perm_seed):
    """With no score ties at any greedy step, permuting feature order maps
    the selection exactly through the permutation (ties legitimately fork
    the greedy trajectory, so tied examples are discarded)."""
    X, y = data
    n = X.shape[0]
    L = min(4, n)
    sel_np, gap = _np_mrmr_with_gaps(X, y, L)
    assume(gap > 1e-4)
    score = MIScore(2, 2)
    res = mrmr_reference(jnp.asarray(X), jnp.asarray(y), L, score)
    np.testing.assert_array_equal(np.asarray(res.selected), sel_np)
    perm = np.random.default_rng(perm_seed).permutation(n)
    res_p = mrmr_reference(jnp.asarray(X[perm]), jnp.asarray(y), L, score)
    np.testing.assert_array_equal(
        perm[np.asarray(res_p.selected)], np.asarray(res.selected)
    )


@given(datasets_v3(), st.integers(0, 2**31 - 1))
@settings(**_SETTINGS)
def test_selection_invariant_to_category_relabeling(data, seed):
    """MI is invariant under per-feature category permutation, so (absent
    score ties, which float32 row-order effects can flip) the whole greedy
    trajectory must be identical."""
    X, y = data
    n = X.shape[0]
    L = min(4, n)
    _, gap = _np_mrmr_with_gaps(X, y, L, v=3)
    assume(gap > 1e-4)
    score = MIScore(3, 2)
    relabel = np.random.default_rng(seed).permutation(3)
    X2 = relabel[X]
    a = mrmr_reference(jnp.asarray(X), jnp.asarray(y), L, score)
    b = mrmr_reference(jnp.asarray(X2), jnp.asarray(y), L, score)
    np.testing.assert_array_equal(np.asarray(a.selected), np.asarray(b.selected))
    np.testing.assert_allclose(a.gains, b.gains, rtol=1e-4, atol=1e-5)


@given(st.integers(0, 2**31 - 1), st.integers(2, 6), st.integers(2, 6))
@settings(**_SETTINGS)
def test_mi_nonnegative_symmetric(seed, v, c):
    rng = np.random.default_rng(seed)
    counts = rng.integers(0, 40, (v, c)).astype(np.float32)
    assume(counts.sum() > 0)
    a = float(mi_from_counts(jnp.asarray(counts)))
    b = float(mi_from_counts(jnp.asarray(counts.T)))
    assert a >= -1e-6
    assert abs(a - b) < 1e-5


@given(st.integers(0, 2**31 - 1), st.integers(16, 200))
@settings(**_SETTINGS)
def test_mi_data_processing(seed, m):
    """I(x; y) <= H(x): MI bounded by the entropy of either variable."""
    from repro.core import entropy_from_counts
    from repro.core.contingency import pair_counts

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 4, m))
    y = jnp.asarray(rng.integers(0, 3, m))
    counts = pair_counts(x, y, 4, 3)
    mi = float(mi_from_counts(counts))
    hx = float(entropy_from_counts(counts.sum(axis=1)))
    hy = float(entropy_from_counts(counts.sum(axis=0)))
    assert mi <= min(hx, hy) + 1e-5
