"""static_inner (dry-run cost mode) must not change mRMR semantics."""

import jax.numpy as jnp
import numpy as np

from repro.core.mrmr import make_conventional_fn
from repro.core.scores import MIScore
from repro.data.synthetic import corral_dataset


def test_static_inner_matches_dynamic():
    X, y = corral_dataset(2000, 24, seed=3)
    X, y = jnp.asarray(X, jnp.int32), jnp.asarray(y)
    score = MIScore(num_values=2, num_classes=2)
    dyn = make_conventional_fn(8, score, incremental=False)(X, y)
    sta = make_conventional_fn(8, score, incremental=False, static_inner=True)(X, y)
    np.testing.assert_array_equal(np.asarray(dyn[0]), np.asarray(sta[0]))
    np.testing.assert_allclose(
        np.asarray(dyn[1]), np.asarray(sta[1]), rtol=1e-5, atol=1e-6
    )


def test_bf16_onehot_counts_exact():
    X, y = corral_dataset(4096, 16, seed=1)
    X, y = jnp.asarray(X, jnp.int32), jnp.asarray(y)
    score = MIScore(num_values=2, num_classes=2)
    bf = make_conventional_fn(6, score, onehot_dtype=jnp.bfloat16)(X, y)
    f32 = make_conventional_fn(6, score, onehot_dtype=jnp.float32)(X, y)
    np.testing.assert_array_equal(np.asarray(bf[0]), np.asarray(f32[0]))
    np.testing.assert_allclose(
        np.asarray(bf[1]), np.asarray(f32[1]), rtol=1e-6, atol=1e-7
    )
