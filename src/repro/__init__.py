"""repro — distributed mRMR feature selection (Reggiani et al., 2017) in JAX.

A production-grade JAX framework reproducing and extending
"Feature selection in high-dimensional dataset using MapReduce":

* ``repro.core``    — the paper's contribution: distributed mRMR with both
  data encodings (conventional = observation-sharded, alternative =
  feature-sharded), pluggable feature-score functions, and an incremental
  redundancy optimisation.
* ``repro.kernels`` — Pallas TPU kernels for the scoring hot spots.
* ``repro.models``  — architecture zoo (dense / MoE / SSM / hybrid / enc-dec
  / VLM backbones) used as workloads for the distribution substrate.
* ``repro.launch``  — production mesh, multi-pod dry-run, train/serve CLIs.
"""

__version__ = "1.0.0"
