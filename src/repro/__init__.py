"""repro — distributed mRMR feature selection (Reggiani et al., 2017) in JAX.

A production-grade JAX framework reproducing and extending
"Feature selection in high-dimensional dataset using MapReduce".

Quickstart
----------

One front door, ``MRMRSelector`` — inputs are always (observations ×
features); the distribution strategy is planned from the dataset's aspect
ratio and the available devices (paper §III: tall/narrow -> observation
sharding, wide/short -> feature sharding, both-large -> 2-D grid)::

    from repro import MRMRSelector
    from repro.data.synthetic import corral_dataset

    X, y = corral_dataset(20_000, 64, seed=0)
    sel = MRMRSelector(num_select=10).fit(X, y)
    print(sel.selected_)        # feature ids, in selection order
    print(sel.plan_)            # the resolved SelectionPlan
    X_small = sel.transform(X)  # selected columns, selection order

Force an encoding or a mesh instead of auto-planning::

    from repro.dist import make_mesh

    mesh = make_mesh((4, 2), ("data", "model"))
    sel = MRMRSelector(num_select=10, encoding="grid", mesh=mesh).fit(X, y)

Out-of-core data — the paper's actual regime — fits from disk in 4 lines.
A ``DataSource`` streams observation-blocks (memmapped ``.npy``, CSV, or
the synthetic generators) and the ``streaming`` engine accumulates each
score's sufficient statistics block-by-block, so peak device memory is
``block_obs × num_features``, never ``num_obs × num_features``::

    from repro.data.sources import NpySource

    source = NpySource("X.npy", "y.npy")   # memmapped, never loaded whole
    sel = MRMRSelector(num_select=10, block_obs=65_536).fit(source)
    X_small = sel.transform(source)        # also streams

``block_obs`` is the memory/throughput dial: larger blocks amortise
per-block dispatch and host-to-device transfer (faster, more device
memory), smaller blocks cap memory for a fixed ~``L`` passes of I/O over
the source.  Selections are identical to the in-memory engines at every
block size.

Streamed fits follow the same §III aspect rule as in-memory plans: a tall
source shards blocks over observations, a **wide** source (``m/n <=
0.25``, the bioinformatics case) shards blocks *and the per-pair
statistics state* over features — bounding per-device statistics memory
by ``N/shards`` pairs — and a both-large source runs a 2-D grid.
``prefetch`` (default ``"auto"``: off on CPU, 2 elsewhere) double-buffers
placement: a host thread reads and pads the next block while the device
accumulates the current one (``prefetch=0`` restores the synchronous
placer).

The I/O tax
-----------

A streamed fit reads the source ``L`` times — 1 relevance pass plus
``num_select - 1`` redundancy passes — and at production scale that pass
count, not FLOPs, is the wall-clock story.  Three composable knobs attack
it; under every combination selections stay **bitwise-identical** to the
plain engine (a tested invariant, so the service's result cache treats
all execution geometries of one fit as the same content)::

    sel = MRMRSelector(
        num_select=32,
        batch_candidates=8,        # ~ceil(31/8) redundancy passes, not 31
        spill_dir="/tmp/spill",    # parse/encode paid once, then replay
        readahead=2,               # pass l+1 reads overlap pass l's tail
    ).fit(source)
    sel.result_.io                 # {'passes': 5, 'blocks_read': ...,
                                   #  'bytes_read': ..., 'cache': {...}}

``batch_candidates=q`` makes each redundancy pass score the needed column
plus the top ``q-1`` current candidates in one sweep (the statistics
state grows a ``q``-sized leading axis, sharded like the rest), then
commits picks with exact criterion folds — a speculated redundancy vector
is a pairwise property of the data, never invalidated by later picks.
``spill_dir=`` wraps the source in :class:`~repro.data.block_cache.
BlockCacheSource`: pass 1 spills each parsed/encoded block as compact
``.npy`` chunks (atomic rename, manifest-last, corruption-checked on
replay, LRU byte budget), passes 2..L replay them memmapped — a binned
source spills its *int codes*, so quantile-encode is also paid once.
``readahead=`` starts reading the next pass's blocks before the current
pass drains (block reads never depend on the just-picked column).  Every
streamed ``MRMRResult`` carries the measured ``io`` ledger, so the pass
math is asserted by tests and benchmarks, not eyeballed.  (CLI: ``python
-m repro.launch.select --batch-candidates 8 --spill-dir /tmp/spill
--readahead 2``.)

Multi-host
----------

The paper's headline regime is *cluster* scale: MapReduce workers each
reading only their partition, one reduce merging the per-partition
statistics.  ``repro.dist.multihost`` is that layer on
``jax.distributed``: ``hosts=N`` (or ``"auto"`` under a launcher) applies
the same §III aspect rule across *processes* — tall partitions the
observation range, wide partitions the column range, both-large gets the
2-D host grid — and each host's block iteration walks ONLY its own
ranges (:meth:`~repro.data.sources.DataSource.iter_shard_blocks`), so a
host streams ``1/N`` of the bytes.  The per-pass reduce is an explicit
``shard_map``-ped psum of the exact integer statistics
(:class:`~repro.dist.multihost.HostCollectives`), after which every host
folds the criterion identically and commits the identical pick — a
genuine map-reduce with no designated master, and selections stay
**bitwise-identical** to the single-process streaming engine (a tested
invariant, including under ``spill_dir`` + ``batch_candidates``, whose
spill entries are namespaced per process)::

    # per process, after jax.distributed is up (or init_multihost()):
    from repro.dist.multihost import init_multihost
    init_multihost()                        # env-driven; idempotent
    sel = MRMRSelector(num_select=10, hosts="auto").fit(source)
    sel.result_.io["host"]                  # this host's shard ranges
    sel.result_.io["hosts"]["aggregate"]    # exact cluster-wide ledger

``python -m repro.launch.select_multihost --num-processes N ...`` spawns
an N-process loopback cluster (or joins a real one via ``--coordinator``
/ ``--process-id`` or the ``REPRO_*`` env vars) and asserts every host
committed the same selection.

Custom scores (paper §IV.D) run through the same front door::

    from repro import CustomScore
    sel = MRMRSelector(5, score=CustomScore(get_result=my_score)).fit(X, y)

Criteria
--------

The greedy *objective* is a pluggable :class:`~repro.core.criteria.
Criterion`, orthogonal to both the score function and the encoding: the
engines compute relevance/redundancy statistics and the criterion folds
them into the per-candidate objective that is argmaxed.  Built-ins:
``mid`` (the paper's difference form, Eq. 1 — the default), ``miq``
(the quotient form), ``maxrel`` (relevance only; the streaming engine
then needs a single pass of I/O), and the class-conditioned pair —
``jmi`` (joint mutual information: mean of ``I(x_k; x_j | y) -
I(x_k; x_j)`` over the selected set, added to relevance) and ``cmim``
(Fleuret's conditional MI maximisation: the *min* of those gaps — a
candidate is only as good as its most-redundant pairing).  Every
criterion runs on every engine, in-memory or streaming, and selections
agree engine-for-engine::

    sel = MRMRSelector(num_select=10, criterion="miq").fit(X, y)
    sel.result_.criterion, sel.result_.engine   # ("miq", "conventional")
    sel.scores_                                 # per-feature relevance
    sel.ranking_                                # 1-based selection rank
    sel.get_support()                           # boolean feature mask

    sel = MRMRSelector(num_select=10, criterion="jmi").fit(X, y)
    sel = MRMRSelector(num_select=10, criterion="cmim", bins=32).fit(src)

``jmi``/``cmim`` declare ``needs_conditional_redundancy = True``: each
redundancy sweep then counts the 3-way ``(x_k value, x_j value, class)``
table — the pair target fuses with the class into one code, so it is the
SAME blocked one-hot einsum (and the same Pallas kernel tiling), just
``d_c×`` wider — and both ``I(x_k; x_j)`` (class-summed) and ``I(x_k;
x_j | y)`` fall out of that one sweep.  Criteria that never ask (mid/
miq/maxrel) keep the exact pre-conditional graph: same state shapes,
same bytes (streamed fits assert it via ``result_.io["state_bytes"]``).
They need a score with a conditional decomposition — ``MIScore``, or
``bins=`` to discretise first; anything else fails actionably at fit
time.  (CLI: ``python -m repro.launch.select --criterion miq|jmi|
cmim``.)

Writing a criterion
~~~~~~~~~~~~~~~~~~~

Register your own fold with :func:`~repro.core.criteria.
register_criterion`.  A criterion is three pure-jnp hooks — ``init_state
(n)`` (per-candidate running state), ``update(state, terms, l)`` (fold
redundancy statistics of pick ``l`` in), ``objective(rel, state, l)``
(the vector that is argmaxed) — plus two declarative flags.  ``terms``
is the marginal redundancy vector, or a ``{"marginal", "conditional"}``
dict when the criterion declares ``needs_conditional_redundancy``; the
helpers accept both forms::

    from repro import Criterion, register_criterion
    from repro.core.criteria import conditional_terms, marginal_terms

    @register_criterion
    class WorstGap(Criterion):
        name = "worstgap"  # then: MRMRSelector(10, criterion="worstgap")
        needs_conditional_redundancy = True   # ask for I(x_k; x_j | y)
        def init_state(self, n): ...          # pytree of (n,) leaves
        def update(self, state, terms, l):
            gap = conditional_terms(terms) - marginal_terms(terms)
            ...                               # fold, pure jnp
        def objective(self, rel, state, l): ...

Interop
-------

``repro.interop.sklearn`` adapts the selector to scikit-learn's
composition machinery (soft dependency — the import tells you to
install sklearn if missing)::

    from repro.interop.sklearn import MRMRTransformer
    from sklearn.pipeline import make_pipeline

    pipe = make_pipeline(
        MRMRTransformer(num_select=10, criterion="jmi", bins=32), clf
    )
    pipe.fit(X, y)          # SelectorMixin: get_support / transform
    GridSearchCV(pipe, {"mrmrtransformer__num_select": [5, 10, 20]})

Columnar data streams natively (soft-gated on pyarrow):
:class:`~repro.data.sources.ParquetSource` decodes Parquet row batches
block-by-block from the file's row groups (geometry from the footer, no
data read before the first pass) and :class:`~repro.data.sources.
ArrowSource` wraps an in-memory Arrow table; both compose with
``bins=``, ``spill_dir=`` and the rest of the streaming stack.  (CLI:
``python -m repro.launch.select --input data.parquet``.)

Binning
-------

MI scoring is discrete, but most numeric-tabular data is continuous.
``bins=`` discretises on the fly at streaming scale: one cheap pass
accumulates a mergeable per-feature quantile sketch
(:class:`~repro.data.binning.QuantileSketch` — KLL-style bounded buffers,
``merge()``-able across blocks and shards), ``bins - 1`` equal-frequency
edges are cut from it, and every subsequent block encodes to int codes in
``[0, bins)`` on the way into the contingency sums — on the device, fused
with the accumulate (Pallas searchsorted kernel on TPU), so raw float
blocks never round-trip through host memory as codes::

    sel = MRMRSelector(num_select=10, bins=32).fit(source)   # float source
    sel = MRMRSelector(num_select=10, bins=32).fit(X, y)     # float array
    sel.plan_.bins                                           # 32

Selections agree between the in-memory and streaming paths at every block
size (the sketch compacts at exact capacity boundaries, so the edges are
a pure function of the row stream).  Wrap explicitly with
:class:`~repro.data.binning.BinnedSource` to reuse one fitted binner; its
``fingerprint()`` derives from the base source's fingerprint × the bin
config, so the service's result cache distinguishes ``bins=16`` from
``bins=64`` for free, and fitted binners are memoised per fingerprint
(repeat submissions never re-sketch).  A float input headed down the MI
path *without* ``bins=`` fails at fit time with a pointer here instead of
scoring truncated categories.  (CLI: ``python -m repro.launch.select
--input floats.csv --bins 32``.)

Service
-------

Selection-as-a-service: :class:`~repro.serve.selection.SelectionService`
runs fits as managed jobs behind a bounded work queue, a worker pool, a
content-addressed result cache and idempotency-key request coalescing.
Identical requests (same source *content*, score, criterion and
``num_select`` — execution geometry like ``block_obs`` deliberately
excluded) share one cache line; a stampede of identical in-flight
submissions runs the engine exactly once; a full queue rejects with
``Backpressure(retry_after_s=...)`` instead of blocking::

    from repro.serve import SelectionService

    with SelectionService(workers=2, cache_dir="/tmp/selcache") as svc:
        job = svc.submit("X.npy::y.npy", num_select=10)
        result = svc.result(job)     # blocks; MRMRResult
        again = svc.submit("X.npy::y.npy", num_select=10)
        svc.poll(again).cache_hit    # True — zero engine or I/O passes
        svc.stats()                  # queue / coalescing / cache counters

The cache is backed by every ``DataSource``'s ``fingerprint()`` (content
hash for in-memory arrays, ``(path, size, mtime)`` for file-backed
sources, generator params for synthetics) — the same fingerprint that
memoises repeated ``stats()`` scans.  ``MRMRResult.to_json()`` /
``from_json()`` round-trip results for the persistent cache and the
``--output`` flag of ``python -m repro.launch.select``; transient worker
failures retry with exponential backoff
(:func:`~repro.runtime.resilience.retry_with_backoff`).  (CLI demo:
``python -m repro.launch.serve_select --repeat 2 --distinct-select 3``.)

Layers
------

* ``repro.core``    — the paper's contribution: ``MRMRSelector`` /
  ``SelectionPlan`` / ``plan_selection`` on top of the five drivers
  (reference, conventional, alternative, grid, streaming) in an open
  engine registry; pluggable feature-score functions AND pluggable
  selection criteria (``repro.core.criteria``); incremental fold
  optimisation.
* ``repro.dist``    — the distribution substrate: named meshes, logical
  sharding rules, multi-host map-reduce (``repro.dist.multihost``),
  pipeline parallelism, jax version compat.
* ``repro.kernels`` — Pallas TPU kernels for the scoring hot spots.
* ``repro.models``  — architecture zoo (dense / MoE / SSM / hybrid /
  enc-dec / VLM backbones) used as workloads for the substrate.
* ``repro.serve``   — selection-as-a-service: job manager, coalescing
  work queue, content-addressed result cache (plus the LM serving demo).
* ``repro.launch``  — production mesh, multi-pod dry-run, CLIs
  (``python -m repro.launch.select`` runs selection end-to-end,
  ``python -m repro.launch.serve_select`` drives the service).
"""

from repro.core import (  # noqa: F401
    CIFECriterion,
    CMIMCriterion,
    Criterion,
    CustomScore,
    FeatureSelector,
    ICAPCriterion,
    JMICriterion,
    MIDCriterion,
    MIFSCriterion,
    MIQCriterion,
    MIScore,
    MRMRResult,
    MRMRSelector,
    MaxRelCriterion,
    PearsonMIScore,
    ScoreFn,
    SelectionPlan,
    available_criteria,
    available_encodings,
    mrmr_select,
    plan_selection,
    register_criterion,
    register_engine,
)

__version__ = "1.6.0"

__all__ = [
    "CIFECriterion",
    "CMIMCriterion",
    "Criterion",
    "CustomScore",
    "FeatureSelector",
    "ICAPCriterion",
    "JMICriterion",
    "MIDCriterion",
    "MIFSCriterion",
    "MIQCriterion",
    "MIScore",
    "MRMRResult",
    "MRMRSelector",
    "MaxRelCriterion",
    "PearsonMIScore",
    "ScoreFn",
    "SelectionPlan",
    "available_criteria",
    "available_encodings",
    "mrmr_select",
    "plan_selection",
    "register_criterion",
    "register_engine",
    "__version__",
]
