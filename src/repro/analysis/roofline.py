"""Three-term roofline model from the compiled dry-run artifacts.

Per (arch × shape × mesh) cell::

    compute_s    = HLO_FLOPs_global    / (chips × PEAK_FLOPS)
    memory_s     = HLO_bytes_global    / (chips × HBM_BW)
    collective_s = collective_bytes_pd / ICI_BW        # per-device operand
                                                        # bytes over one link

``cost_analysis()`` counts the *per-device* SPMD program, so global values
are per-device × chips; the collective term uses per-device operand bytes
directly (equivalent to the assignment's global/(chips·link_bw)).

``model_flops`` is the analytic useful-work count (6·N_active·D train,
2·N_active·D inference, plus the attention/SSD mixing terms).  The ratio
MODEL_FLOPS / HLO_FLOPs exposes remat recompute and sharding-induced
redundancy.

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (one link charged per collective hop).
"""

from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


# ---------------------------------------------------------------------------
# analytic parameter / FLOP counts
# ---------------------------------------------------------------------------

def _mamba_dims(cfg: ModelConfig) -> tuple[int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    heads = d_in // cfg.ssm_headdim
    groups = max(1, cfg.num_kv_heads) if cfg.family == "ssm" else 1
    # mirror repro.models.mamba.mamba_dims (groups=1 there)
    return d_in, heads, 1


def _layer_param_counts(cfg: ModelConfig, l: int) -> tuple[float, float]:
    """(total, active) matmul params of layer ``l`` (biases/norms ignored)."""
    d, hd = cfg.d_model, cfg.head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    total = active = 0.0
    if cfg.layer_kind(l) == "attn":
        qkv = d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
        total += qkv
        active += qkv
    else:
        d_in, heads, g = _mamba_dims(cfg)
        s = cfg.ssm_state
        inp = d * (2 * d_in + 2 * g * s + heads)  # in_proj (zxBCdt fused)
        out = d_in * d
        total += inp + out
        active += inp + out
    fk = cfg.ffn_kind(l)
    if fk == "dense":
        m = (3 if cfg.mlp_gated else 2) * d * cfg.d_ff
        total += m
        active += m
    elif fk == "moe":
        e_par = 3 * d * cfg.d_ff  # gated expert
        total += cfg.num_experts * e_par + d * cfg.num_experts
        active += (
            (cfg.experts_per_token + cfg.num_shared_experts) * e_par
            + d * cfg.num_experts
        )
    return total, active


def param_counts(cfg: ModelConfig) -> tuple[float, float]:
    """(total, active) matmul params incl. unembed, excl. embedding gather."""
    if cfg.is_encdec:
        d, hd, h = cfg.d_model, cfg.head_dim, cfg.num_heads
        attn = 4 * d * h * hd
        mlp = 2 * d * cfg.d_ff  # whisper: GELU, 2 matmuls
        enc = cfg.encoder_layers * (attn + mlp)
        dec = cfg.decoder_layers * (2 * attn + mlp)  # self + cross
        unemb = d * cfg.vocab_size
        n = enc + dec + unemb
        return n, n
    total = active = 0.0
    for l in range(cfg.num_layers):
        t, a = _layer_param_counts(cfg, l)
        total += t
        active += a
    if not cfg.tie_embeddings:
        total += cfg.d_model * cfg.vocab_size
        active += cfg.d_model * cfg.vocab_size
    else:
        # tied: the unembed matmul still runs
        active += cfg.d_model * cfg.vocab_size
        total += cfg.d_model * cfg.vocab_size
    return total, active


def _mixing_flops_per_layer(
    cfg: ModelConfig, l: int, batch: int, s_q: int, s_kv: int, causal: bool
) -> float:
    """Forward FLOPs of the attention-score/SSD part (not projections)."""
    if cfg.layer_kind(l) == "attn":
        f = 4.0 * batch * s_q * s_kv * cfg.num_heads * cfg.head_dim
        if causal and s_q == s_kv:
            f *= 0.5
        return f
    d_in, heads, g = _mamba_dims(cfg)
    # SSD: state update + output contraction, ~6 flops per (channel, state)
    return 6.0 * batch * s_q * d_in * cfg.ssm_state


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic useful FLOPs of one step of this cell (global)."""
    b = shape.global_batch
    n_total, n_active = param_counts(cfg)
    if shape.kind == "train":
        tokens = b * shape.seq_len
        mix = sum(
            _mixing_flops_per_layer(cfg, l, b, shape.seq_len, shape.seq_len, True)
            for l in range(cfg.num_layers if not cfg.is_encdec else 0)
        )
        if cfg.is_encdec:
            mix = cfg.encoder_layers * _mixing_flops_per_layer(
                cfg, 0, b, shape.seq_len, shape.seq_len, False
            ) + cfg.decoder_layers * (
                _mixing_flops_per_layer(cfg, 0, b, shape.seq_len, shape.seq_len, True)
                + _mixing_flops_per_layer(cfg, 0, b, shape.seq_len, shape.seq_len, False)
            )
        return 6.0 * n_active * tokens + 3.0 * mix
    if shape.kind == "prefill":
        tokens = b * shape.seq_len
        mix = sum(
            _mixing_flops_per_layer(cfg, l, b, shape.seq_len, shape.seq_len, True)
            for l in range(cfg.num_layers if not cfg.is_encdec else 0)
        )
        if cfg.is_encdec:
            mix = cfg.encoder_layers * _mixing_flops_per_layer(
                cfg, 0, b, shape.seq_len, shape.seq_len, False
            ) + cfg.decoder_layers * (
                _mixing_flops_per_layer(cfg, 0, b, shape.seq_len, shape.seq_len, True)
                + _mixing_flops_per_layer(cfg, 0, b, shape.seq_len, shape.seq_len, False)
            )
        return 2.0 * n_active * tokens + mix
    # decode: one token per sequence against an S-long cache/state
    mix = sum(
        _mixing_flops_per_layer(cfg, l, b, 1, shape.seq_len, False)
        for l in range(cfg.num_layers if not cfg.is_encdec else 0)
    )
    if cfg.is_encdec:
        mix = cfg.decoder_layers * 2 * _mixing_flops_per_layer(
            cfg, 0, b, 1, shape.seq_len, False
        )
    return 2.0 * n_active * b + mix


# ---------------------------------------------------------------------------
# the three terms
# ---------------------------------------------------------------------------

def roofline_terms(
    *,
    flops_per_device: float,
    bytes_per_device: float,
    collective_operand_bytes: float,
    n_devices: int,
    model_flops_global: float,
) -> dict:
    compute_s = flops_per_device / PEAK_FLOPS
    memory_s = bytes_per_device / HBM_BW
    collective_s = collective_operand_bytes / ICI_BW
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    dominant = max(terms, key=terms.get)
    hlo_global = flops_per_device * n_devices
    bound_s = max(terms.values())
    useful = model_flops_global / hlo_global if hlo_global else 0.0
    # achievable MFU if the dominant term were perfectly overlapped with the
    # others: useful model flops / (bound time × fleet peak)
    mfu_bound = (
        model_flops_global / (bound_s * n_devices * PEAK_FLOPS)
        if bound_s > 0
        else 0.0
    )
    return {
        **terms,
        "dominant": dominant,
        "hlo_flops_global": hlo_global,
        "model_flops": model_flops_global,
        "useful_flops_ratio": useful,
        "roofline_mfu_bound": mfu_bound,
    }
