from repro.analysis.hlo_analysis import collective_stats  # noqa: F401
from repro.analysis.roofline import roofline_terms, model_flops  # noqa: F401
