"""Rank compiled-HLO ops by bytes / collective traffic (trip-aware).

The §Perf profiling loop on a CPU-only container: instead of a wall-clock
trace, rank every op site by its contribution to the roofline terms and
attribute it back to model code via the ``op_name`` metadata.

    PYTHONPATH=src python -m repro.analysis.hlo_top results/dryrun/single/X.hlo.txt
"""

from __future__ import annotations

import re
import sys
from collections import defaultdict

from repro.analysis.hlo_analysis import (
    COLLECTIVE_KINDS,
    _collective_from_line,
    _fusion_call_bytes,
    _line_bytes,
    _dot_flops,
    _parse_computations,
    _parse_rhs,
    _trip_count,
    _OP_LINE_RE,
    _NUM_PARTITIONS_RE,
    _WHILE_ATTR_RE,
    _CALLS_RE,
    _TO_APPLY_RE,
)

_META_RE = re.compile(r'op_name="([^"]+)"')


def _short(meta: str, maxlen: int = 70) -> str:
    meta = re.sub(r"jit\(\w+\)/", "", meta)
    return meta[-maxlen:]


def collect(text: str, bf16_model: bool = False):
    comps = _parse_computations(text)
    mw = _NUM_PARTITIONS_RE.search(text)
    world = int(mw.group(1)) if mw else 1
    sites = []  # (bytes, flops, coll_bytes, kind, meta)

    def walk(name: str, mult: int, flops_only: bool, seen: tuple):
        comp = comps.get(name)
        if comp is None or name in seen:
            return
        for line in comp.lines:
            om = _OP_LINE_RE.match(line)
            if not om:
                continue
            shape_seg, op, operand_seg = _parse_rhs(om.group(2))
            if not op:
                continue
            meta = _META_RE.search(line)
            meta = _short(meta.group(1)) if meta else ""
            own = om.group(1)
            own_ex = comp.exempt.get(own, False)
            base = op[:-6] if op.endswith("-start") else op
            if base in COLLECTIVE_KINDS and not flops_only:
                c = _collective_from_line(
                    base, shape_seg, line, world, bf16_model and not own_ex
                )
                sites.append(
                    (0.0, 0.0, c.operand_bytes * mult, f"{base}(g={c.group_size})", meta)
                )
                continue
            if op == "while":
                wm = _WHILE_ATTR_RE.search(line)
                if wm:
                    trips = _trip_count(line, comps, wm.group(1))
                    walk(wm.group(2), mult * trips, flops_only, seen + (name,))
                continue
            if op == "call":
                tm = _TO_APPLY_RE.search(line)
                if tm:
                    walk(tm.group(1), mult, flops_only, seen + (name,))
                continue
            if op == "fusion":
                fm = _CALLS_RE.search(line)
                callee = comps.get(fm.group(1)) if fm else None
                if fm:
                    walk(fm.group(1), mult, True, seen + (name,))
                if not flops_only:
                    if (bf16_model and callee is not None
                            and callee.is_identity_convert()):
                        continue
                    b = _fusion_call_bytes(comp, callee, shape_seg,
                                           operand_seg, bf16_model, own_ex)
                    sites.append((b * mult, 0.0, 0.0, op, meta))
                continue
            fl = _dot_flops(comp, operand_seg, shape_seg, line) if op == "dot" else 0.0
            b = 0.0 if flops_only else _line_bytes(
                comp, op, shape_seg, operand_seg, bf16_model, own_ex
            )
            if b or fl:
                sites.append((b * mult, fl * mult, 0.0, op, meta))

    walk("__entry__", 1, False, ())
    return sites


def main() -> None:
    path = sys.argv[1]
    top_n = int(sys.argv[2]) if len(sys.argv) > 2 else 25
    bf16 = "--bf16" in sys.argv
    sites = collect(open(path).read(), bf16_model=bf16)

    print("== top ops by HBM bytes (per device, trips unrolled) ==")
    agg = defaultdict(lambda: [0.0, 0])
    for b, fl, cb, kind, meta in sites:
        if b:
            key = (kind, meta)
            agg[key][0] += b
            agg[key][1] += 1
    total_b = sum(v[0] for v in agg.values())
    for (kind, meta), (b, n) in sorted(agg.items(), key=lambda kv: -kv[1][0])[:top_n]:
        print(f"  {b/1e9:10.2f} GB {100*b/total_b:5.1f}% x{n:<4d} {kind:<18s} {meta}")
    print(f"  total: {total_b/1e9:.2f} GB")

    print("\n== collectives (per device) ==")
    agg2 = defaultdict(lambda: [0.0, 0])
    for b, fl, cb, kind, meta in sites:
        if cb:
            agg2[(kind, meta)][0] += cb
            agg2[(kind, meta)][1] += 1
    total_c = sum(v[0] for v in agg2.values()) or 1.0
    for (kind, meta), (cb, n) in sorted(agg2.items(), key=lambda kv: -kv[1][0])[:top_n]:
        print(f"  {cb/1e9:10.3f} GB {100*cb/total_c:5.1f}% x{n:<4d} {kind:<24s} {meta}")
    print(f"  total: {total_c/1e9:.2f} GB")

    print("\n== top dots by FLOPs (per device) ==")
    agg3 = defaultdict(lambda: [0.0, 0])
    for b, fl, cb, kind, meta in sites:
        if fl:
            agg3[meta][0] += fl
            agg3[meta][1] += 1
    total_f = sum(v[0] for v in agg3.values()) or 1.0
    for meta, (fl, n) in sorted(agg3.items(), key=lambda kv: -kv[1][0])[:top_n]:
        print(f"  {fl/1e12:10.3f} TF {100*fl/total_f:5.1f}% x{n:<4d} {meta}")
    print(f"  total: {total_f/1e12:.2f} TFLOP")


if __name__ == "__main__":
    main()
