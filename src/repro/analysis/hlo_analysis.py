"""Trip-count-aware cost + collective accounting from compiled HLO text.

Why this exists: ``compiled.cost_analysis()`` visits each computation once,
so everything inside a ``while`` body — which is *the whole layer stack*
under scan-over-layers — is counted for ONE trip instead of ``num_groups``
trips, and collective bytes are not reported at all.  This module re-derives
per-device costs from the scheduled HLO text:

* **flops** — 2 · |out| · |contracting| for every ``dot`` (operand shapes are
  resolved through a per-computation symbol table, since scheduled HLO
  prints operands by name only);
* **bytes** — Σ (operand + output bytes) per op, fusions charged at their
  call site only (fused internals stay in registers), bookkeeping ops free;
* **collectives** — operand bytes per op derived from the *output* shape
  (all-reduce: out, all-gather: out/g, reduce-scatter: out·g, all-to-all /
  collective-permute: out) plus a ring-algorithm wire-byte estimate;
* every quantity is multiplied by the enclosing ``while`` trip counts
  (``known_trip_count`` backend config, else the loop-condition constant).

All shapes in the compiled module are per-device (SPMD), so totals here are
per-device; the roofline layer converts to fleet-level terms.

**bf16 correction.**  The CPU backend has no bf16 compute units, so XLA's
float-normalization pass rewrites every bf16 value to f32 between explicit
converts — the lowered module carries activations, partial sums and
collective payloads at TWICE the width a TPU (native-bf16 MXU) would move.
``analyze_hlo(..., bf16_model=True)`` therefore counts f32 tensors at 2
bytes/element, EXCEPT ops that are f32 *by design* in the model (and would
be f32 on TPU too): softmax/logsumexp internals, the f32 attention-score
einsums, RMSNorm/LayerNorm statistics, and the optimizer update — matched
via ``op_name`` metadata.  Both raw and corrected totals are reported in
the dry-run records; EXPERIMENTS.md §Roofline uses the corrected ones and
discusses the residual (~±6%) bias.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e5m2": 1, "f8e4m3": 1, "f8e4m3fn": 1, "f8e4m3b11fnuz": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
    "f4e2m1fn": 1,
}

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

# ops that move no bytes of their own
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "domain", "opt-barrier",
    "custom-call",  # CPU topk/etc: operands counted by producers; keep free
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_LINE_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_HEADER_RE = re.compile(
    r"^\s*(ENTRY\s+)?%?([\w\.\-]+)\s+\((?P<params>.*)\)\s*->\s*.*\{\s*$"
)
_PARAM_RE = re.compile(r"([\w\.\-]+):\s*((?:\([^)]*\))|(?:[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?))")
_TRIP_RE = re.compile(r'"known_trip_count":\s*\{\s*"n":\s*"(\d+)"')
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_NUM_PARTITIONS_RE = re.compile(r"num_partitions=(\d+)")
_META_NAME_RE = re.compile(r'op_name="([^"]+)"')
# ops that are f32 BY DESIGN in the model (f32 on TPU as well): exempt from
# the bf16 width correction.
_F32_BY_DESIGN_RE = re.compile(
    r"softmax|logsumexp|log_softmax|bkgst|rsqrt|reduce_max"
    r"|adamw|optimizer|global_norm"
)
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_WHILE_ATTR_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _shape_bytes(seg: str, halve_f32: bool = False) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(seg):
        size = _DTYPE_BYTES.get(dtype)
        if size is None:
            continue
        if halve_f32 and dtype == "f32":
            size = 2  # counted at the bf16 width a TPU lowering would move
        n = 1
        for d in dims.split(","):
            d = d.strip()
            if d:
                n *= int(d)
        total += n * size
    return total


def _shape_dims(seg: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(seg)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d.strip()]
    return dims


@dataclasses.dataclass
class _Comp:
    name: str
    lines: List[str]
    symbols: Dict[str, str]  # op name -> shape segment (output)
    exempt: Dict[str, bool] = dataclasses.field(default_factory=dict)
    params: List[str] = dataclasses.field(default_factory=list)
    is_entry: bool = False

    def is_identity_convert(self) -> bool:
        """Body is only convert/copy/bitcast AND the output type equals the
        (single) input type: a convert round-trip (f32->bf16->f32) that the
        CPU float-normalization pass creates and TPU algsimp folds away.
        Counted as zero traffic under the bf16 model.  A genuine
        f32->bf16 cast (different dtypes) still counts."""
        kinds = set()
        root_shape = None
        for line in self.lines:
            om = _OP_LINE_RE.match(line)
            if not om:
                continue
            shape_seg, op, _ = _parse_rhs(om.group(2))
            kinds.add(op)
            if "ROOT" in line:
                root_shape = shape_seg.strip()
        allowed = {"parameter", "convert", "copy", "bitcast", ""}
        if not kinds or not kinds <= allowed or "convert" not in kinds:
            return False
        return (
            root_shape is not None
            and len(self.params) == 1
            and _SHAPE_RE.search(self.params[0]) is not None
            and _SHAPE_RE.search(root_shape) is not None
            and _SHAPE_RE.search(self.params[0]).groups()
            == _SHAPE_RE.search(root_shape).groups()
        )


def _matching_paren(s: str, start: int) -> int:
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i
    return len(s) - 1


def _parse_rhs(rhs: str) -> Tuple[str, str, str]:
    """rhs of '=' -> (shape_segment, op_name, operand_segment)."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        end = _matching_paren(rhs, 0)
        shape_seg = rhs[: end + 1]
        rest = rhs[end + 1 :].strip()
    else:
        m = re.match(r"^([a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?(?:\s*)?)", rhs)
        if not m:
            return "", "", ""
        shape_seg = m.group(1)
        rest = rhs[m.end() :].strip()
    m = re.match(r"^([\w\-]+)\(", rest)
    if not m:
        return shape_seg, "", ""
    op = m.group(1)
    p0 = rest.find("(")
    p1 = _matching_paren(rest, p0)
    return shape_seg, op, rest[p0 + 1 : p1]


def _parse_computations(text: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    for line in text.splitlines():
        m = _HEADER_RE.match(line)
        if m and "->" in line and not line.lstrip().startswith("//"):
            cur = _Comp(name=m.group(2), lines=[], symbols={},
                        is_entry=bool(m.group(1)))
            for pname, pshape in _PARAM_RE.findall(m.group("params")):
                cur.symbols[pname] = pshape
                cur.params.append(pshape)
            comps[cur.name] = cur
            if cur.is_entry:
                comps["__entry__"] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        om = _OP_LINE_RE.match(line)
        if om:
            shape_seg, _, _ = _parse_rhs(om.group(2))
            cur.symbols[om.group(1)] = shape_seg
            mm = _META_NAME_RE.search(line)
            cur.exempt[om.group(1)] = bool(
                mm and _F32_BY_DESIGN_RE.search(mm.group(1))
            )
            cur.lines.append(line)
    return comps


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    operand_bytes: float
    group_size: int
    trip_mult: int = 1

    @property
    def wire_bytes(self) -> float:
        g = max(self.group_size, 1)
        if self.kind == "all-reduce":
            f = 2 * (g - 1) / g
        elif self.kind == "collective-permute":
            f = 1.0
        else:  # all-gather / reduce-scatter / all-to-all per-operand ring
            f = (g - 1) / g
        return self.operand_bytes * f


@dataclasses.dataclass
class _Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: List[CollectiveOp] = dataclasses.field(default_factory=list)

    def scaled(self, k: int) -> "_Cost":
        return _Cost(
            self.flops * k,
            self.bytes * k,
            [
                dataclasses.replace(c, trip_mult=c.trip_mult * k)
                for c in self.collectives
            ],
        )

    def add(self, other: "_Cost") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        self.collectives.extend(other.collectives)


def _group_size(line: str, world: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([t for t in m.group(1).split(",") if t.strip()])
    return world


def _collective_from_line(
    kind: str, shape_seg: str, line: str, world: int,
    halve_f32: bool = False,
) -> CollectiveOp:
    out_bytes = _shape_bytes(shape_seg, halve_f32)
    g = _group_size(line, world)
    if kind == "all-gather":
        operand = out_bytes / max(g, 1)
    elif kind == "reduce-scatter":
        operand = out_bytes * max(g, 1)
    else:  # all-reduce, all-to-all, collective-permute, broadcast
        operand = float(out_bytes)
    return CollectiveOp(kind=kind, operand_bytes=operand, group_size=g)


def _dot_flops(comp: _Comp, operand_seg: str, shape_seg: str, line: str) -> float:
    out_dims = _shape_dims(shape_seg) or []
    out = 1
    for d in out_dims:
        out *= d
    names = re.findall(r"%([\w\.\-]+)", operand_seg)
    lhs_dims = _shape_dims(comp.symbols.get(names[0], "")) if names else None
    cm = _CONTRACT_RE.search(line)
    contract = 1
    if lhs_dims and cm:
        for tok in cm.group(1).split(","):
            tok = tok.strip()
            if tok:
                i = int(tok)
                if i < len(lhs_dims):
                    contract *= lhs_dims[i]
    return 2.0 * out * contract


def _line_bytes(
    comp: _Comp, op: str, shape_seg: str, operand_seg: str,
    bf16_model: bool = False, out_exempt: bool = False,
) -> float:
    if op in _FREE_OPS or op == "while":
        return 0.0
    out_bytes = float(_shape_bytes(shape_seg, bf16_model and not out_exempt))
    # Sliced reads/writes touch the slice, not the whole buffer (this is
    # what makes scan-over-layers cheap: each trip reads ONE layer's slice
    # of the stacked weights).  dynamic-update-slice aliases in place:
    # read update + write region.
    if op in ("dynamic-slice", "gather"):
        return 2.0 * out_bytes
    names = re.findall(r"%([\w\.\-]+)", operand_seg)
    if op in ("dynamic-update-slice", "scatter"):
        upd = names[1] if len(names) > 1 else None
        halve = bf16_model and not comp.exempt.get(upd, False)
        return 2.0 * _shape_bytes(comp.symbols.get(upd, ""), halve) + (
            out_bytes if op == "scatter" else 0.0
        )
    total = out_bytes
    for name in names:
        halve = bf16_model and not comp.exempt.get(name, False)
        total += _shape_bytes(comp.symbols.get(name, ""), halve)
    return total


def _callee_param_reads(callee: _Comp):
    """Per-parameter effective read segments for a fusion body.

    Returns a list (indexed by parameter number) of either ``None`` (full
    read) or a list of output shape segments of the dynamic-slice/gather
    ops that are the parameter's ONLY consumers — the fused loads only
    touch the sliced region.
    """
    if not hasattr(callee, "_param_reads"):
        pidx: Dict[str, int] = {}
        for line in callee.lines:
            pm = re.match(
                r"\s*(?:ROOT\s+)?%([\w\.\-]+) = .*? parameter\((\d+)\)", line
            )
            if pm:
                pidx[pm.group(1)] = int(pm.group(2))
        reads: Dict[int, object] = {}
        for line in callee.lines:
            om = _OP_LINE_RE.match(line)
            if not om:
                continue
            shape_seg, op, operand_seg = _parse_rhs(om.group(2))
            if not op or op == "parameter":
                continue
            names = re.findall(r"%([\w\.\-]+)", operand_seg)
            for j, nm in enumerate(names):
                if nm not in pidx:
                    continue
                i = pidx[nm]
                sliced = op in ("dynamic-slice", "gather") and j == 0
                if sliced and reads.get(i) is not False:
                    reads.setdefault(i, [])
                    if isinstance(reads[i], list):
                        reads[i].append(shape_seg)
                else:
                    reads[i] = False  # some non-sliced use: full read
        out = []
        for i in range(len(callee.params)):
            r = reads.get(i)
            out.append(r if isinstance(r, list) else None)
        callee._param_reads = out
    return callee._param_reads


def _fusion_call_bytes(
    comp: _Comp, callee: Optional[_Comp], shape_seg: str, operand_seg: str,
    bf16_model: bool, out_exempt: bool,
) -> float:
    """Call-site bytes for a fusion, honouring sliced parameter reads."""
    total = float(_shape_bytes(shape_seg, bf16_model and not out_exempt))
    names = re.findall(r"%([\w\.\-]+)", operand_seg)
    reads = _callee_param_reads(callee) if callee is not None else None
    for i, name in enumerate(names):
        halve = bf16_model and not comp.exempt.get(name, False)
        if reads is not None and i < len(reads) and reads[i] is not None:
            total += sum(_shape_bytes(s, halve) for s in reads[i])
        else:
            total += _shape_bytes(comp.symbols.get(name, ""), halve)
    return total


def _trip_count(line: str, comps: Dict[str, _Comp], cond_name: str) -> int:
    m = _TRIP_RE.search(line)
    if m:
        return int(m.group(1))
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts: Dict[str, int] = {}
    for ln in cond.lines:
        cm = re.search(r"%([\w\.\-]+) = s32\[\] constant\((\d+)\)", ln)
        if cm:
            consts[cm.group(1)] = int(cm.group(2))
    for ln in cond.lines:
        if "compare(" in ln and ("ROOT" in ln or "direction=LT" in ln):
            for name, val in consts.items():
                if f"%{name}" in ln:
                    return val
    return 1


def _walk(
    name: str,
    comps: Dict[str, _Comp],
    world: int,
    memo: Dict[Tuple[str, bool], _Cost],
    stack: set,
    flops_only: bool = False,
    bf16_model: bool = False,
) -> _Cost:
    key = (name, flops_only)
    if key in memo:
        return memo[key]
    comp = comps.get(name)
    if comp is None or name in stack:
        return _Cost()
    stack.add(name)
    cost = _Cost()

    def lbytes(op, shape_seg, operand_seg, own):
        return _line_bytes(
            comp, op, shape_seg, operand_seg, bf16_model,
            comp.exempt.get(own, False),
        )

    for line in comp.lines:
        om = _OP_LINE_RE.match(line)
        if not om:
            continue
        own = om.group(1)
        shape_seg, op, operand_seg = _parse_rhs(om.group(2))
        if not op:
            continue
        base = op[:-6] if op.endswith("-start") else op
        if base in COLLECTIVE_KINDS:
            if not flops_only:
                halve = bf16_model and not comp.exempt.get(own, False)
                cost.collectives.append(
                    _collective_from_line(base, shape_seg, line, world, halve)
                )
                cost.bytes += lbytes(base, shape_seg, operand_seg, own)
            continue
        if op == "while":
            wm = _WHILE_ATTR_RE.search(line)
            if wm:
                trips = _trip_count(line, comps, wm.group(1))
                body = _walk(wm.group(2), comps, world, memo, stack,
                             flops_only, bf16_model)
                cost.add(body.scaled(trips))
            continue
        if op == "conditional":
            bm = _BRANCHES_RE.search(line)
            if bm:
                best = _Cost()
                for b in bm.group(1).split(","):
                    sub = _walk(
                        b.strip().lstrip("%"), comps, world, memo, stack,
                        flops_only, bf16_model,
                    )
                    if sub.flops + sub.bytes > best.flops + best.bytes:
                        best = sub
                cost.add(best)
            if not flops_only:
                cost.bytes += lbytes(op, shape_seg, operand_seg, own)
            continue
        if op == "call":
            tm = _TO_APPLY_RE.search(line)
            if tm:
                cost.add(_walk(tm.group(1), comps, world, memo, stack,
                               flops_only, bf16_model))
            continue
        if op == "fusion":
            # fused internals are register-resident: bytes at call site only,
            # but any dot inside still runs on the MXU.
            fm = _CALLS_RE.search(line)
            callee = comps.get(fm.group(1)) if fm else None
            if fm:
                cost.add(
                    _walk(fm.group(1), comps, world, memo, stack, True,
                          bf16_model)
                )
            if not flops_only:
                if (
                    bf16_model
                    and callee is not None
                    and callee.is_identity_convert()
                ):
                    continue  # convert round-trip: free on TPU (see _Comp)
                cost.bytes += _fusion_call_bytes(
                    comp, callee, shape_seg, operand_seg, bf16_model,
                    comp.exempt.get(own, False),
                )
            continue
        if op == "dot":
            cost.flops += _dot_flops(comp, operand_seg, shape_seg, line)
            if not flops_only:
                cost.bytes += lbytes(op, shape_seg, operand_seg, own)
            continue
        # plain op (reduce/sort/map keep their scalar regions un-descended)
        if not flops_only:
            cost.bytes += lbytes(op, shape_seg, operand_seg, own)
    stack.discard(name)
    memo[key] = cost
    return cost


def analyze_hlo(text: str, bf16_model: bool = False) -> dict:
    """Per-device {flops, bytes, collectives} with while-loops unrolled.

    ``bf16_model=True`` applies the TPU width correction (module docstring).
    """
    comps = _parse_computations(text)
    mw = _NUM_PARTITIONS_RE.search(text)
    world = int(mw.group(1)) if mw else 1
    cost = _walk("__entry__", comps, world, {}, set(), False, bf16_model)
    by_type: Dict[str, dict] = {}
    total = 0.0
    wire = 0.0
    for c in cost.collectives:
        b = c.operand_bytes * c.trip_mult
        w = c.wire_bytes * c.trip_mult
        total += b
        wire += w
        slot = by_type.setdefault(
            c.kind, {"operand_bytes": 0.0, "wire_bytes": 0.0, "count": 0}
        )
        slot["operand_bytes"] += b
        slot["wire_bytes"] += w
        slot["count"] += c.trip_mult
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "num_partitions": world,
        "collectives": {
            "operand_bytes": total,
            "wire_bytes": wire,
            "by_type": by_type,
            "num_static_sites": len(cost.collectives),
        },
    }


def collective_stats(hlo_text: str) -> dict:
    """Back-compat wrapper: just the collective block of ``analyze_hlo``."""
    return analyze_hlo(hlo_text)["collectives"]
