"""Pallas TPU kernel: fused standardize + correlate (alternative encoding).

The alternative-encoding score hot loop (paper Listing 8) computes, for every
local candidate feature row, its Pearson correlation with the class vector
and with each selected feature.  Batched over candidates that is

    corr = ((X - mu_x)/sd_x) @ ((Y - mu_y)/sd_y)^T / M

A naive implementation materialises standardized copies of X (2x the HBM
traffic of the dominant operand).  This kernel fuses the standardization
into the matmul tiles: X tiles are centered/scaled in VMEM right before the
MXU contraction, so X is read exactly once.

Grid: (F/TF, T/TT, M/TM) with M innermost (output block revisited across the
reduction axis).  A zero/one column mask handles M padding exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(x_ref, y_ref, mx_ref, rx_ref, my_ref, ry_ref, mask_ref, out_ref, *,
            inv_m: float):
    m_idx = pl.program_id(2)

    mask = mask_ref[...]  # (1, TM)
    x = (x_ref[...] - mx_ref[...]) * rx_ref[...] * mask  # (TF, TM)
    yv = (y_ref[...] - my_ref[...]) * ry_ref[...] * mask  # (TT, TM)

    part = jax.lax.dot_general(
        x, yv, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (TF, TT)

    @pl.when(m_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += part * inv_m


def _row_stats(X: Array, m_real: int) -> tuple[Array, Array]:
    """Row mean and 1/std over the first ``m_real`` columns."""
    Xr = X[:, :m_real].astype(jnp.float32)
    mu = Xr.mean(axis=1, keepdims=True)
    var = ((Xr - mu) ** 2).mean(axis=1, keepdims=True)
    return mu, 1.0 / jnp.maximum(jnp.sqrt(var), 1e-12)


def pearson_corr_pallas(
    X: Array,
    Y: Array,
    *,
    tile_f: int = 128,
    tile_t: int = 128,
    tile_m: int = 512,
    interpret: bool = False,
) -> Array:
    """(F, M), (T, M) -> (F, T) Pearson correlation of rows (float32)."""
    F, M = X.shape
    T, My = Y.shape
    assert M == My, (M, My)
    tile_f = min(tile_f, F)
    tile_t = min(tile_t, T)
    tile_m = min(tile_m, M)

    pad_f = (-F) % tile_f
    pad_t = (-T) % tile_t
    pad_m = (-M) % tile_m
    Xp = jnp.pad(X.astype(jnp.float32), ((0, pad_f), (0, pad_m)))
    Yp = jnp.pad(Y.astype(jnp.float32), ((0, pad_t), (0, pad_m)))
    mask = jnp.pad(jnp.ones((1, M), jnp.float32), ((0, 0), (0, pad_m)))

    mx, rx = _row_stats(Xp, M)
    my, ry = _row_stats(Yp, M)

    fp, mp = Xp.shape
    tp = Yp.shape[0]
    grid = (fp // tile_f, tp // tile_t, mp // tile_m)

    out = pl.pallas_call(
        functools.partial(_kernel, inv_m=1.0 / M),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_f, tile_m), lambda f, t, m: (f, m)),
            pl.BlockSpec((tile_t, tile_m), lambda f, t, m: (t, m)),
            pl.BlockSpec((tile_f, 1), lambda f, t, m: (f, 0)),
            pl.BlockSpec((tile_f, 1), lambda f, t, m: (f, 0)),
            pl.BlockSpec((tile_t, 1), lambda f, t, m: (t, 0)),
            pl.BlockSpec((tile_t, 1), lambda f, t, m: (t, 0)),
            pl.BlockSpec((1, tile_m), lambda f, t, m: (0, m)),
        ],
        out_specs=pl.BlockSpec((tile_f, tile_t), lambda f, t, m: (f, t)),
        out_shape=jax.ShapeDtypeStruct((fp, tp), jnp.float32),
        interpret=interpret,
    )(Xp, Yp, mx, rx, my, ry, mask)

    return out[:F, :T]
