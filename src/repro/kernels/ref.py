"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are tested against
(``tests/test_kernels.py`` sweeps shapes/dtypes with ``interpret=True``).
They delegate to the core library where the math already exists, so the
kernel contract and the algorithm stay in lock-step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import contingency as _contingency
from repro.core import scores as _scores

Array = jax.Array


def contingency_tables(X: Array, y: Array, num_values: int, num_classes: int) -> Array:
    """(M, F) int, (M,) int -> (F, V, C) float32 contingency tables.

    Out-of-range entries (padding) contribute zero counts.
    """
    return _contingency.batched_counts(
        X, y, num_values, num_classes, block=max(1, min(64, X.shape[1]))
    )


def conditional_tables(
    X: Array, xj: Array, y: Array, num_values: int, num_classes: int
) -> Array:
    """(M, F), (M,), (M,) -> (F, V, V, C) class-conditioned pair tables.

    ``counts[f, v, w, c]`` counts rows where ``X[:, f] == v``,
    ``xj == w`` and ``y == c``; out-of-range entries contribute zero.
    """
    return _contingency.conditional_counts(
        X, xj, y, num_values, num_values, num_classes,
        block=max(1, min(64, X.shape[1])),
    )


def pearson_corr(X: Array, Y: Array) -> Array:
    """(F, M), (T, M) -> (F, T) Pearson correlation of rows."""
    return _scores.pearson_rows(X, Y)


def mi_scores(counts: Array) -> Array:
    """(F, V, C) counts -> (F,) mutual information in nats."""
    return _scores.mi_from_counts(counts)


def bin_codes(X: Array, edges: Array) -> Array:
    """(B, N) floats x (N, E) sorted edges -> (B, N) int32 bin codes.

    ``searchsorted(side="right")`` per feature column; comparisons in f32
    to match the host encoder and the Pallas kernel bit-for-bit.
    """
    return jax.vmap(
        lambda e, col: jnp.searchsorted(e, col, side="right"),
        in_axes=(0, 1),
        out_axes=1,
    )(edges.astype(jnp.float32), X.astype(jnp.float32)).astype(jnp.int32)


def cor2mi(corr: Array) -> Array:
    """Listing-8 Gaussian MI approximation."""
    return _scores.cor2mi(corr)


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool) -> Array:
    """(B,S,H,D) x (B,T,KV,D) -> (B,S,H,D) GQA softmax attention (f32)."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    qg = q.reshape(b, s, kvh, h // kvh, d).astype(jnp.float32) * (d ** -0.5)
    sc = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32))
    if causal:
        t = k.shape[1]
        mask = jnp.tril(jnp.ones((s, t), jnp.bool_), k=t - s)
        sc = jnp.where(mask[None, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)
