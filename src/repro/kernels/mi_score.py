"""Pallas TPU kernel: batched mutual information from contingency tables.

Reduces stacked (F, V, C) contingency tables to per-feature MI in nats:

    MI_f = sum_{v,c} p log(p / (p_v * p_c)),   p = counts_f / total_f

Memory-bound elementwise-log + reduction; fusing it avoids three extra HBM
round-trips (p, px*py, terms) after the contingency kernel.  Tables are
flattened to (F, V*C) so the reduction runs over clean 2-D lanes; marginals
are rebuilt in VMEM with two small reshapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

_EPS = 1e-12


def _kernel(c_ref, out_ref, *, num_values: int, num_classes: int):
    counts = c_ref[...]  # (TF, V*C)
    tf = counts.shape[0]
    cube = counts.reshape(tf, num_values, num_classes)

    total = jnp.maximum(cube.sum(axis=(1, 2), keepdims=True), 1.0)
    p = cube / total
    px = p.sum(axis=2, keepdims=True)
    py = p.sum(axis=1, keepdims=True)
    ratio = p / jnp.maximum(px * py, _EPS)
    terms = jnp.where(p > 0, p * jnp.log(jnp.maximum(ratio, _EPS)), 0.0)
    out_ref[...] = terms.sum(axis=2).sum(axis=1, keepdims=True)


def mi_scores_pallas(
    counts: Array,
    *,
    tile_f: int = 256,
    interpret: bool = False,
) -> Array:
    """(F, V, C) counts -> (F,) MI in nats (float32)."""
    F, V, C = counts.shape
    tile_f = min(tile_f, F)
    pad_f = (-F) % tile_f
    flat = counts.reshape(F, V * C).astype(jnp.float32)
    flat = jnp.pad(flat, ((0, pad_f), (0, 0)))
    fp = flat.shape[0]

    out = pl.pallas_call(
        functools.partial(_kernel, num_values=V, num_classes=C),
        grid=(fp // tile_f,),
        in_specs=[pl.BlockSpec((tile_f, V * C), lambda f: (f, 0))],
        out_specs=pl.BlockSpec((tile_f, 1), lambda f: (f, 0)),
        out_shape=jax.ShapeDtypeStruct((fp, 1), jnp.float32),
        interpret=interpret,
    )(flat)

    return out[:F, 0]
