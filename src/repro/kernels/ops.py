"""jit'd dispatch wrappers for the Pallas kernels.

``use_pallas``:
  * ``"auto"``  — compiled Pallas on TPU, interpreted Pallas is NOT silently
    used on CPU (interpret mode is a correctness harness, ~100x slower than
    jnp); CPU gets the jnp oracle instead.
  * ``True``    — force Pallas (interpret=True off-TPU; used by tests).
  * ``False``   — force the jnp oracle.

This keeps one call site per op across the library while staying runnable
on both the CPU CI container and a real TPU pod.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.binning import bin_codes_pallas
from repro.kernels.contingency import (
    conditional_tables_pallas,
    contingency_tables_pallas,
)
from repro.kernels.mi_score import mi_scores_pallas
from repro.kernels.pearson import pearson_corr_pallas

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _decide(use_pallas) -> tuple[bool, bool]:
    """-> (run_pallas, interpret)."""
    if use_pallas == "auto":
        return (_on_tpu(), False)
    if use_pallas:
        return (True, not _on_tpu())
    return (False, False)


@functools.partial(
    jax.jit, static_argnames=("num_values", "num_classes", "use_pallas")
)
def contingency_tables(
    X: Array, y: Array, num_values: int, num_classes: int, use_pallas="auto"
) -> Array:
    """(M, F), (M,) -> (F, V, C) contingency tables."""
    run, interp = _decide(use_pallas)
    if run:
        return contingency_tables_pallas(
            X, y, num_values, num_classes, interpret=interp
        )
    return ref.contingency_tables(X, y, num_values, num_classes)


@functools.partial(
    jax.jit, static_argnames=("num_values", "num_classes", "use_pallas")
)
def conditional_tables(
    X: Array, xj: Array, y: Array, num_values: int, num_classes: int,
    use_pallas="auto",
) -> Array:
    """(M, F), (M,), (M,) -> (F, V, V, C) class-conditioned pair tables.

    The JMI/CMIM redundancy statistic: marginal pair counts split per
    class, so one call yields both ``I(x_k; x_j)`` (class-summed) and
    ``I(x_k; x_j | y)`` (class-weighted per-slice MI).
    """
    run, interp = _decide(use_pallas)
    if run:
        return conditional_tables_pallas(
            X, xj, y, num_values, num_classes, interpret=interp
        )
    return ref.conditional_tables(X, xj, y, num_values, num_classes)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def pearson_corr(X: Array, Y: Array, use_pallas="auto") -> Array:
    """(F, M), (T, M) -> (F, T) row correlations."""
    run, interp = _decide(use_pallas)
    if run:
        return pearson_corr_pallas(X, Y, interpret=interp)
    return ref.pearson_corr(X, Y)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def mi_scores(counts: Array, use_pallas="auto") -> Array:
    """(F, V, C) counts -> (F,) MI (nats)."""
    run, interp = _decide(use_pallas)
    if run:
        return mi_scores_pallas(counts, interpret=interp)
    return ref.mi_scores(counts)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def bin_codes(X: Array, edges: Array, use_pallas="auto") -> Array:
    """(B, N) floats x (N, E) sorted edges -> (B, N) int32 bin codes."""
    run, interp = _decide(use_pallas)
    if run:
        return bin_codes_pallas(X, edges, interpret=interp)
    return ref.bin_codes(X, edges)


def mi_tables(
    X: Array, y: Array, num_values: int, num_classes: int, use_pallas="auto"
) -> Array:
    """Fused convenience: per-feature MI against ``y`` from raw columns."""
    counts = contingency_tables(X, y, num_values, num_classes, use_pallas)
    return mi_scores(counts, use_pallas)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_kv", "use_pallas")
)
def flash_attention(
    q: Array, k: Array, v: Array, *, causal: bool = True,
    block_q: int = 512, block_kv: int = 512, use_pallas="auto",
) -> Array:
    """(B,S,H,D), (B,T,KV,D) -> (B,S,H,D) GQA flash attention.

    The TPU path for every attention cell in the §Roofline table (keeps the
    S^2 score/prob intermediates in VMEM); the jnp oracle runs on CPU.
    """
    from repro.kernels.flash_attention import flash_attention_pallas

    run, interp = _decide(use_pallas)
    if run:
        return flash_attention_pallas(
            q, k, v, causal=causal, block_q=block_q, block_kv=block_kv,
            interpret=interp,
        )
    return ref.flash_attention(q, k, v, causal=causal)
