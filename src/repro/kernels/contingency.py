"""Pallas TPU kernel: batched contingency tables as one-hot MXU matmuls.

The paper's conventional-encoding hot loop emits one contingency table per
(observation, candidate-feature) pair and sums them (mapper + combiner).  A
GPU port would scatter-add; TPUs have no fast scatter, so we reformulate the
histogram as a matmul over on-the-fly one-hot tiles:

    out[f*V + v, c] = sum_m  onehot(X[m, f])[v] * onehot(y[m])[c]
                    = (A^T B)[f*V + v, c],
    A = onehot(X_tile) in VMEM, shape (TM, TF*V);  B = onehot(y_tile), (TM, C)

so every (TM, TF) input tile becomes a single (TF·V, TM) x (TM, C) MXU
contraction.  The output block is revisited along the M grid axis
(accumulate-into-output); the one-hot expansion never leaves VMEM.

Tiling defaults: TM=512 rows, TF chosen so TF·V ≈ 256 lanes.  VMEM use is
A (TM·TF·V·4) + B (TM·C·4) + out (TF·V·C·4) ≈ 2.3 MB at defaults — well
inside the ~16 MB v5e VMEM budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(x_ref, y_ref, out_ref, *, num_values: int, num_classes: int):
    """One (TM, TF) tile of X against the matching (TM, 1) tile of y."""
    m_idx = pl.program_id(1)

    x = x_ref[...]  # (TM, TF) int32
    y = y_ref[...]  # (TM, 1) int32
    tm, tf = x.shape

    # One-hot expansion in VMEM. Out-of-range (padding) rows -> all-zero rows.
    iota_v = jax.lax.broadcasted_iota(jnp.int32, (tm, tf, num_values), 2)
    a = (x[:, :, None] == iota_v).astype(jnp.float32)  # (TM, TF, V)
    a = a.reshape(tm, tf * num_values)
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (tm, num_classes), 1)
    b = (y == iota_c).astype(jnp.float32)  # (TM, C)

    part = jax.lax.dot_general(
        a, b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (TF*V, C)

    @pl.when(m_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += part


def contingency_tables_pallas(
    X: Array,
    y: Array,
    num_values: int,
    num_classes: int,
    *,
    tile_m: int = 512,
    tile_f: int | None = None,
    interpret: bool = False,
) -> Array:
    """(M, F) int32, (M,) int32 -> (F, V, C) float32 contingency tables.

    Padding rows may carry out-of-range values; they contribute nothing.
    """
    M, F = X.shape
    if tile_f is None:
        # Aim for TF*V ≈ 256 sublane-friendly rows of the A^T operand.
        tile_f = max(1, min(F, 256 // max(num_values, 1)))
    tile_m = min(tile_m, max(M, 1))

    pad_m = (-M) % tile_m
    pad_f = (-F) % tile_f
    big = jnp.int32(2**31 - 1)  # out of range of any category
    Xp = jnp.pad(X.astype(jnp.int32), ((0, pad_m), (0, pad_f)), constant_values=big)
    yp = jnp.pad(y.astype(jnp.int32), (0, pad_m), constant_values=big)[:, None]

    mp, fp = Xp.shape
    grid = (fp // tile_f, mp // tile_m)

    out = pl.pallas_call(
        functools.partial(_kernel, num_values=num_values, num_classes=num_classes),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, tile_f), lambda f, m: (m, f)),
            pl.BlockSpec((tile_m, 1), lambda f, m: (m, 0)),
        ],
        out_specs=pl.BlockSpec(
            (tile_f * num_values, num_classes), lambda f, m: (f, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((fp * num_values, num_classes), jnp.float32),
        interpret=interpret,
    )(Xp, yp)

    return out.reshape(fp, num_values, num_classes)[:F]


def conditional_tables_pallas(
    X: Array,
    xj: Array,
    y: Array,
    num_values: int,
    num_classes: int,
    *,
    tile_m: int = 512,
    tile_f: int | None = None,
    interpret: bool = False,
) -> Array:
    """(M, F), (M,), (M,) -> (F, V, V, C) class-conditioned pair tables.

    The class axis is fused into the pair target (``xj * C + y``, guarded
    against out-of-range inputs) so the SAME tiled one-hot-matmul kernel
    above produces the 3-way counts — the target one-hot just widens from
    ``V`` to ``V * C`` lanes.  ``counts.sum(-1)`` recovers the marginal
    pair table; each ``[..., c]`` slice is the within-class table.
    """
    from repro.core.contingency import fuse_targets  # shared fuse semantics

    fused = fuse_targets(xj, y, num_values, num_classes)
    out = contingency_tables_pallas(
        X, fused, num_values, num_values * num_classes,
        tile_m=tile_m, tile_f=tile_f, interpret=interpret,
    )
    return out.reshape(out.shape[0], num_values, num_values, num_classes)
