"""Pallas TPU kernel: quantile-bin encoding (batched searchsorted).

Maps a float block ``X (B, N)`` against per-feature sorted edge rows
``edges (N, E)`` to int32 bin codes::

    code[b, n] = #{ k : edges[n, k] <= X[b, n] }

which is exactly ``searchsorted(edges[n], X[:, n], side="right")`` — the
comparison-sum form trades the branchy binary search for ``E`` dense
vectorised compares, the right shape for the VPU (E = bins - 1 is small,
tens not thousands).  Fused ahead of contingency accumulation it keeps
binned streaming on-device: raw float blocks go HBM -> codes -> one-hot
counts without round-tripping int blocks through host memory.

Both operands tile over features on the lane dimension; edge rows are
broadcast across the batch tile.  Padding: batch/feature pads are zeros
(codes for pad lanes are garbage and sliced off), edge pads are +inf so a
padded edge column never increments a real code.  Comparisons are f32 on
both the host (``QuantileBinner.transform``) and device paths, so the two
encodes agree bitwise.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(x_ref, e_ref, out_ref, *, num_edges: int):
    x = x_ref[...]            # (TB, TN) f32
    codes = jnp.zeros(x.shape, jnp.int32)
    # E is small and static: unrolled compare-accumulate, one broadcast
    # edge row per step.
    for k in range(num_edges):
        edge_k = e_ref[:, k][None, :]          # (1, TN)
        codes = codes + (x >= edge_k).astype(jnp.int32)
    out_ref[...] = codes


def bin_codes_pallas(
    X: Array,
    edges: Array,
    *,
    tile_b: int = 256,
    tile_n: int = 128,
    interpret: bool = False,
) -> Array:
    """(B, N) floats x (N, E) sorted edges -> (B, N) int32 codes."""
    B, N = X.shape
    Ne, E = edges.shape
    if Ne != N:
        raise ValueError(f"edges rows {Ne} != features {N}")
    tile_b = min(tile_b, B)
    tile_n = min(tile_n, N)
    pad_b = (-B) % tile_b
    pad_n = (-N) % tile_n

    Xf = jnp.pad(X.astype(jnp.float32), ((0, pad_b), (0, pad_n)))
    ef = jnp.pad(
        edges.astype(jnp.float32),
        ((0, pad_n), (0, 0)),
        constant_values=jnp.inf,
    )
    bp, np_ = Xf.shape

    out = pl.pallas_call(
        functools.partial(_kernel, num_edges=E),
        grid=(bp // tile_b, np_ // tile_n),
        in_specs=[
            pl.BlockSpec((tile_b, tile_n), lambda b, n: (b, n)),
            pl.BlockSpec((tile_n, E), lambda b, n: (n, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b, tile_n), lambda b, n: (b, n)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), jnp.int32),
        interpret=interpret,
    )(Xf, ef)

    return out[:B, :N]
