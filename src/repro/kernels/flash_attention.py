"""Pallas TPU kernel: flash attention (online-softmax, VMEM-resident).

This is the TPU answer to the §Roofline finding that every attention cell
is memory-bound on (B, H, S, S) score/probability traffic: the blockwise
jnp path (models/attention.py) bounds the *footprint* but still moves the
S² intermediates through HBM; this kernel keeps them in VMEM entirely —
HBM sees only Q, K, V and O.

Schedule: grid (B, H, S/bq, S/bkv), KV innermost.  Running max / sum /
accumulator live in VMEM scratch and survive across the KV axis; the
output block is written once on the last KV step.  GQA is handled in the
K/V BlockSpec index maps (query head h reads KV head h // group) — no
repeated-KV materialisation.  Causal masking compares global q/k positions
inside the tile.

VMEM at defaults (bq=bkv=512, D=128, f32 compute): q+k+v tiles ≈ 0.8 MB,
scores ≈ 1 MB, scratch ≈ 0.5 MB — comfortably inside the ~16 MB v5e
budget.  MXU dims (bq×D · D×bkv) are 128-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, scale: float, block_q: int, block_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale  # (bq, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bkv, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, bkv)
    if causal:
        qpos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0
        )
        kpos = ki * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1
        )
        s = jnp.where(qpos >= kpos, s, _NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: Array,  # (B, S, H, D)
    k: Array,  # (B, T, KV, D)
    v: Array,  # (B, T, KV, D)
    *,
    causal: bool = True,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool = False,
) -> Array:
    b, s, h, d = q.shape
    t, kvh = k.shape[1], k.shape[2]
    group = h // kvh
    block_q = min(block_q, s)
    block_kv = min(block_kv, t)
    assert s % block_q == 0 and t % block_kv == 0, (s, t, block_q, block_kv)
    grid = (b, h, s // block_q, t // block_kv)

    kernel = functools.partial(
        _kernel, causal=causal, scale=d ** -0.5,
        block_q=block_q, block_kv=block_kv,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d), lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
            pl.BlockSpec(
                (1, block_kv, 1, d),
                lambda b_, h_, qi, ki, _g=group: (b_, ki, h_ // _g, 0),
            ),
            pl.BlockSpec(
                (1, block_kv, 1, d),
                lambda b_, h_, qi, ki, _g=group: (b_, ki, h_ // _g, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, block_q, 1, d), lambda b_, h_, qi, ki: (b_, qi, h_, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, s, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),   # running max
            pltpu.VMEM((block_q,), jnp.float32),   # running sum
            pltpu.VMEM((block_q, d), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
