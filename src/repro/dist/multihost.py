"""Multi-host map-reduce — each host reads only its shard.

The paper's headline claim is *cluster* scale: millions of observations
or features spread over MapReduce workers, each reading only its
partition, with one reduce merging the per-partition sufficient
statistics.  This module is that layer for the streaming engine, on
``jax.distributed``:

* :func:`init_multihost` — process bootstrap wrapping
  ``jax.distributed.initialize`` (explicit args or ``REPRO_COORDINATOR``
  / ``REPRO_NUM_PROCESSES`` / ``REPRO_PROCESS_ID`` env vars), with the
  CPU collectives implementation pinned to gloo so loopback CI clusters
  work out of the box.
* :class:`HostShardSpec` / :func:`resolve_host_shards` — the paper's
  §III sharding rule applied across *hosts*: tall fits partition the
  observation (block) range, wide fits partition the column range, and
  both-large gets the 2-D (obs × feat) host grid.  Each host's block
  iteration walks ONLY its own ranges
  (:meth:`repro.data.sources.DataSource.iter_shard_blocks`).
* :class:`HostCollectives` — the per-pass reduce as explicit
  ``shard_map``-ped ``psum``\\ s over a global mesh built with one
  representative device per process, so the 2-D grid's collective
  placement is pinned rather than left to GSPMD propagation:

  - ``psum`` merges host-local contingency states over every host
    (tall regime: exact integer count sums, hence bitwise-identical
    finalised scores on every host);
  - ``psum_obs`` merges over the observation-host axis only, keeping
    the per-pair statistics column-sharded (the 2-D grid's reduce);
  - ``assemble`` scatters each column group's finalised score slice
    into the full ``(N,)`` vector and sums the disjoint pieces (the
    wide regime's reduce — float adds against zeros, exact).

  After the reduce every host holds identical full-width vectors, folds
  the criterion identically and commits the identical pick — a genuine
  map-reduce with no designated master.

Module imports stay jax+numpy only (no ``repro.core`` at import time):
``repro.core.selector`` imports ``repro.dist``, and the §III thresholds
are borrowed lazily inside :func:`resolve_host_shards` to keep the two
planners literally rule-identical without an import cycle.
"""

from __future__ import annotations

import dataclasses
import os

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.compat import shard_map
from repro.dist.meshes import factor_mesh, host_mesh

_OBS_AXIS, _FEAT_AXIS = "oh", "fh"  # host-mesh axis names (obs / feature)


# ---------------------------------------------------------------------------
# bootstrap
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MultihostContext:
    """What :func:`init_multihost` resolved: this process's place in the
    cluster (``num_processes == 1`` means single-process, no collectives)."""

    process_id: int
    num_processes: int
    coordinator: str | None


_CONTEXT: MultihostContext | None = None


def _env_int(name: str) -> int | None:
    v = os.environ.get(name)
    return int(v) if v not in (None, "") else None


def init_multihost(
    coordinator: str | None = None,
    num_processes: int | None = None,
    process_id: int | None = None,
    *,
    cpu_collectives: str = "gloo",
) -> MultihostContext:
    """Join (or skip joining) a ``jax.distributed`` cluster — idempotent.

    Args default from the environment — ``REPRO_COORDINATOR`` (e.g.
    ``"10.0.0.1:12355"``), ``REPRO_NUM_PROCESSES``, ``REPRO_PROCESS_ID``
    — so launchers can configure workers without threading flags.  With
    no coordinator (or ``num_processes <= 1``) this is a no-op returning
    a single-process context: the same selection code runs unsharded.

    Must run before any jax computation (backend init locks the device
    set); calling again after a successful init returns the cached
    context.  ``cpu_collectives`` pins the CPU cross-process collectives
    backend (gloo) — required for multi-process CPU psums; harmless on
    accelerator backends.
    """
    global _CONTEXT
    if _CONTEXT is not None:
        return _CONTEXT
    coordinator = coordinator or os.environ.get("REPRO_COORDINATOR") or None
    if num_processes is None:
        num_processes = _env_int("REPRO_NUM_PROCESSES")
    if process_id is None:
        process_id = _env_int("REPRO_PROCESS_ID")
    if coordinator is None or (num_processes or 1) <= 1:
        _CONTEXT = MultihostContext(
            process_id=jax.process_index(),
            num_processes=jax.process_count(),
            coordinator=None,
        )
        return _CONTEXT
    if num_processes is None or process_id is None:
        raise ValueError(
            "multi-host init needs all three of coordinator, num_processes "
            f"and process_id (got coordinator={coordinator!r}, "
            f"num_processes={num_processes!r}, process_id={process_id!r})"
        )
    try:
        # Only affects the CPU backend; must land before backend init.
        jax.config.update("jax_cpu_collectives_implementation", cpu_collectives)
    except AttributeError:  # jax without the knob: single-impl build
        pass
    try:
        jax.distributed.initialize(
            coordinator_address=coordinator,
            num_processes=int(num_processes),
            process_id=int(process_id),
        )
    except RuntimeError as e:
        # Already initialised (a launcher beat us to it): verify instead
        # of failing — idempotence is the contract.
        if "already" not in str(e).lower():
            raise
    if jax.process_count() != int(num_processes):
        raise RuntimeError(
            f"jax.distributed reports {jax.process_count()} processes, "
            f"expected {num_processes}"
        )
    _CONTEXT = MultihostContext(
        process_id=int(jax.process_index()),
        num_processes=int(jax.process_count()),
        coordinator=coordinator,
    )
    return _CONTEXT


# ---------------------------------------------------------------------------
# shard resolution — the §III rule across hosts
# ---------------------------------------------------------------------------

def split_range(total: int, parts: int, index: int) -> tuple[int, int]:
    """Balanced contiguous split of ``range(total)`` into ``parts``:
    the first ``total % parts`` shards get one extra element, so shard
    sizes never differ by more than one."""
    if not 0 <= index < parts:
        raise ValueError(f"index {index} out of range for {parts} parts")
    base, extra = divmod(int(total), int(parts))
    lo = index * base + min(index, extra)
    return lo, lo + base + (1 if index < extra else 0)


@dataclasses.dataclass(frozen=True)
class HostShardSpec:
    """One host's slice of the dataset under the §III host grid.

    ``grid = (obs_hosts, feat_hosts)`` with hosts laid out row-major:
    host ``i`` sits at ``(i // feat_hosts, i % feat_hosts)`` — the same
    order :func:`repro.dist.meshes.host_mesh` lays processes onto the
    collective mesh, so shard ranges and psum axes always agree.
    """

    num_obs: int
    num_features: int
    grid: tuple          # (obs_hosts, feat_hosts)
    host_id: int
    obs_range: tuple     # [lo, hi) rows this host reads
    col_range: tuple     # [lo, hi) columns this host reads

    @property
    def num_hosts(self) -> int:
        return self.grid[0] * self.grid[1]

    @property
    def obs_coord(self) -> int:
        return self.host_id // self.grid[1]

    @property
    def feat_coord(self) -> int:
        return self.host_id % self.grid[1]

    @property
    def local_obs(self) -> int:
        return self.obs_range[1] - self.obs_range[0]

    @property
    def local_cols(self) -> int:
        return self.col_range[1] - self.col_range[0]

    @property
    def partitions_obs(self) -> bool:
        return self.grid[0] > 1

    @property
    def partitions_cols(self) -> bool:
        return self.grid[1] > 1

    @property
    def is_single_host(self) -> bool:
        return self.num_hosts == 1

    @property
    def max_col_width(self) -> int:
        """Widest column group (group 0 under the balanced split) — the
        common padded width for cross-group state collectives."""
        lo, hi = split_range(self.num_features, self.grid[1], 0)
        return hi - lo

    def owns_col(self, c: int) -> bool:
        return self.col_range[0] <= int(c) < self.col_range[1]


def resolve_host_shards(
    num_obs: int,
    num_features: int,
    num_hosts: int,
    host_id: int,
    *,
    grid: tuple | None = None,
) -> HostShardSpec:
    """The §III sharding rule applied to hosts: tall partitions the
    observation range, wide partitions the column range, both-large gets
    the aspect-biased 2-D factorisation (same thresholds as the device
    planner — literally the selector's constants).  ``grid=(oh, fh)``
    overrides the rule.  ``num_hosts == 1`` degenerates to the full
    ranges (today's single-process path)."""
    m, n = int(num_obs), int(num_features)
    H = int(num_hosts)
    if H < 1:
        raise ValueError(f"num_hosts must be >= 1, got {H}")
    if not 0 <= int(host_id) < H:
        raise ValueError(f"host_id {host_id} out of range for {H} hosts")
    if grid is not None:
        oh, fh = int(grid[0]), int(grid[1])
        if oh * fh != H:
            raise ValueError(f"grid {grid} does not factor {H} hosts")
    elif H == 1:
        oh, fh = 1, 1
    else:
        # Borrowed lazily so this module never imports repro.core at
        # import time (selector imports repro.dist) — one rule, two
        # planners, zero drift.
        from repro.core.selector import (
            TALL_RATIO, WIDE_RATIO, _grid_factor,
        )

        aspect = m / max(n, 1)
        if aspect >= TALL_RATIO:
            oh, fh = H, 1
        elif aspect <= WIDE_RATIO:
            oh, fh = 1, H
        else:
            gf = _grid_factor(m, n, H)
            if gf is not None:
                oh, fh = gf
            elif aspect >= 1.0:
                oh, fh = H, 1
            else:
                oh, fh = 1, H
    if oh > max(m, 1) or fh > max(n, 1):
        raise ValueError(
            f"host grid ({oh}, {fh}) over-partitions a {m}x{n} dataset: "
            "some hosts would hold an empty shard; use fewer hosts or an "
            "explicit grid="
        )
    oc, fc = int(host_id) // fh, int(host_id) % fh
    return HostShardSpec(
        num_obs=m,
        num_features=n,
        grid=(oh, fh),
        host_id=int(host_id),
        obs_range=split_range(m, oh, oc),
        col_range=split_range(n, fh, fc),
    )


def factor_host_grid(num_obs: int, num_features: int, num_hosts: int) -> tuple:
    """The (obs_hosts, feat_hosts) factorisation ``resolve_host_shards``
    would pick — exposed for planners and tests."""
    return resolve_host_shards(num_obs, num_features, num_hosts, 0).grid


# ---------------------------------------------------------------------------
# explicit cross-host collectives
# ---------------------------------------------------------------------------

class HostCollectives:
    """The per-pass reduce: explicit psums over the global host mesh.

    Built once per fit from a :class:`HostShardSpec`; every merge is a
    ``shard_map``-ped ``lax.psum`` with pinned in/out specs over a
    ``(obs_hosts, feat_hosts)`` mesh holding ONE representative device
    per process (ordered by process index, so mesh coordinates equal
    shard coordinates).  Single-host specs short-circuit every method to
    the identity — the degenerate path never touches ``jax.distributed``.

    Compiled merge fns are cached per (op × tree signature), so passes
    after the first pay zero trace/compile.
    """

    def __init__(self, spec: HostShardSpec):
        self.spec = spec
        self._fns: dict = {}
        self._mesh: Mesh | None = None
        self._device = None
        if not spec.is_single_host:
            if jax.process_count() != spec.num_hosts:
                raise RuntimeError(
                    f"HostShardSpec wants {spec.num_hosts} hosts but "
                    f"jax.distributed reports {jax.process_count()} "
                    "processes; call init_multihost() first"
                )
            self._mesh = host_mesh(spec.grid, (_OBS_AXIS, _FEAT_AXIS))
            self._device = jax.local_devices()[0]

    # -- plumbing --------------------------------------------------------

    def _global_leaf(self, leaf: np.ndarray):
        """This host's leaf as its (1, 1, *s) shard of the (O, F, *s)
        global array — the make_array construction verified to feed
        cross-process shard_map psums."""
        a = np.ascontiguousarray(leaf)
        gshape = (self.spec.grid[0], self.spec.grid[1]) + a.shape
        sharding = NamedSharding(self._mesh, P(_OBS_AXIS, _FEAT_AXIS))
        local = jax.device_put(a[None, None], self._device)
        return jax.make_array_from_single_device_arrays(
            gshape, sharding, [local]
        )

    def _merged(self, leaves: list, axes: tuple) -> list:
        """psum every leaf over the given mesh axes; returns host numpy
        arrays (the local block, leading host dims dropped)."""
        sig = (
            axes,
            tuple((tuple(l.shape), str(l.dtype)) for l in leaves),
        )
        fn = self._fns.get(sig)
        if fn is None:
            n = len(leaves)
            in_spec = P(_OBS_AXIS, _FEAT_AXIS)
            out_spec = P(
                None if _OBS_AXIS in axes else _OBS_AXIS,
                None if _FEAT_AXIS in axes else _FEAT_AXIS,
            )

            def merge(*xs):
                return tuple(jax.lax.psum(x, axes) for x in xs)

            fn = jax.jit(
                shard_map(
                    merge,
                    mesh=self._mesh,
                    in_specs=(in_spec,) * n,
                    out_specs=(out_spec,) * n,
                )
            )
            self._fns[sig] = fn
        out = fn(*[self._global_leaf(l) for l in leaves])
        return [np.asarray(o.addressable_data(0))[0, 0] for o in out]

    def _tree_merge(self, tree, axes: tuple):
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(l) for l in leaves]
        return jax.tree.unflatten(treedef, self._merged(host, axes))

    # -- the three reduces ----------------------------------------------

    def psum(self, tree):
        """Sum a pytree over EVERY host — the tall regime's state merge.
        Contingency counts are exact integers, so the merged statistics
        (and everything finalised from them) are bitwise-identical to a
        single process having seen every block."""
        if self.spec.is_single_host:
            return tree
        return self._tree_merge(tree, (_OBS_AXIS, _FEAT_AXIS))

    def psum_obs(
        self,
        tree,
        feat_axis: int = 0,
        local_width: int | None = None,
        pad_to: int | None = None,
    ):
        """Sum over the observation-host axis only — the 2-D grid's state
        merge: per-pair statistics stay column-sharded (the wide memory
        wall never re-forms) while row partitions collapse.  Column
        groups may differ in width under a ragged split, so leaves whose
        ``feat_axis`` is exactly ``local_width`` wide (default: this
        host's column count; augmented redundancy states pass their
        target-extended width) are zero-padded to ``pad_to`` (default:
        the widest group) before the psum and sliced back after — zeros
        are the additive identity, so padding never changes a sum.
        Leaves that don't match the width (scalars, counters) ride
        unpadded; the match is decided per-leaf BEFORE the merge so an
        unpadded leaf that happens to come out ``pad_to`` wide is never
        mis-sliced."""
        if self.spec.grid[0] == 1:
            return tree
        mine = self.spec.local_cols if local_width is None else int(local_width)
        w = self.spec.max_col_width if pad_to is None else int(pad_to)
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(l) for l in leaves]
        flags = [
            a.ndim > feat_axis and a.shape[feat_axis] == mine and mine != w
            for a in host
        ]

        def pad(a):
            widths = [(0, 0)] * a.ndim
            widths[feat_axis] = (0, w - a.shape[feat_axis])
            return np.pad(a, widths)

        padded = [pad(a) if f else a for a, f in zip(host, flags)]
        merged = self._merged(padded, (_OBS_AXIS,))

        def unpad(a):
            sl = [slice(None)] * a.ndim
            sl[feat_axis] = slice(0, mine)
            return a[tuple(sl)]

        out = [unpad(a) if f else a for a, f in zip(merged, flags)]
        return jax.tree.unflatten(treedef, out)

    def assemble(self, tree):
        """Scatter each column group's ``(..., local_cols)`` score slice
        into zeros of full width ``(..., N)`` and sum across hosts — the
        wide / 2-D vector reduce.  Only ``obs_coord == 0`` contributes
        (after :meth:`psum_obs` every row in a column group holds the
        identical slice), so each output column receives exactly one
        non-zero addend: float adds against zeros, exact, and every host
        ends with the identical full vector."""
        if not self.spec.partitions_cols:
            return self.psum(tree) if self.spec.grid[0] > 1 else tree
        lo, hi = self.spec.col_range

        def scatter(leaf):
            a = np.asarray(leaf)
            full = np.zeros(a.shape[:-1] + (self.spec.num_features,), a.dtype)
            if self.spec.obs_coord == 0:
                full[..., lo:hi] = a
            return full

        return self._tree_merge(
            jax.tree.map(scatter, tree), (_OBS_AXIS, _FEAT_AXIS)
        )

    # -- ledger exchange -------------------------------------------------

    def allgather_counts(self, values) -> np.ndarray:
        """Every host's integer vector, exactly: ``(num_hosts, k)`` from
        each host's ``(k,)`` counters.  Values ride as two int32 halves
        (x64 is typically disabled, and f32 would round byte counts), so
        counts are exact up to 2**62."""
        v = np.asarray(values, np.int64).reshape(-1)
        if self.spec.is_single_host:
            return v[None, :]
        H, k = self.spec.num_hosts, v.shape[0]
        lo = np.zeros((H, k), np.int32)
        hi = np.zeros((H, k), np.int32)
        lo[self.spec.host_id] = (v & 0x7FFFFFFF).astype(np.int32)
        hi[self.spec.host_id] = (v >> 31).astype(np.int32)
        mlo, mhi = self._merged([lo, hi], (_OBS_AXIS, _FEAT_AXIS))
        return (mhi.astype(np.int64) << 31) | mlo.astype(np.int64)


__all__ = [
    "HostCollectives",
    "HostShardSpec",
    "MultihostContext",
    "factor_host_grid",
    "factor_mesh",
    "init_multihost",
    "resolve_host_shards",
    "split_range",
]
