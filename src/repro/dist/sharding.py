"""Logical-axis sharding rules.

Parameters declare *logical* axis names (``("vocab", "fsdp")``); a
``ShardingRules`` instance maps each logical name to a mesh axis (or to
``None`` = replicate), and ``logical_to_spec`` resolves a def's logical
tuple to a concrete ``PartitionSpec`` — dropping any mapping whose mesh
axis is absent, already used by an earlier dim, or does not divide the dim
size.  That makes one rule set safe across every (arch × shape × mesh)
cell: smoke configs with tiny dims simply come out replicated.
"""

from __future__ import annotations

import dataclasses

from jax.sharding import Mesh, PartitionSpec as P

# mesh axis name, tuple of names (sharded over their product), or None
AxisSel = "str | tuple[str, ...] | None"


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical parameter axis -> mesh axis mapping.

    Defaults are fully replicated (the single-device rules); ``rules_for``
    builds the production mapping from a mesh.
    """

    fsdp: AxisSel = None        # weight shards spread over data parallelism
    ff: AxisSel = None          # MLP hidden (Megatron TP)
    heads: AxisSel = None       # attention query heads
    kv_heads: AxisSel = None    # attention kv heads
    ssm_heads: AxisSel = None   # mamba state heads
    vocab: AxisSel = None       # embed/unembed vocab dim
    experts: AxisSel = None     # MoE expert parallelism
    expert_ff: AxisSel = None   # weight-stationary second EP level
    act_seq: AxisSel = None     # sequence-sharded activations (Megatron-SP)

    def axis_for(self, logical: str) -> AxisSel:
        if logical == "none":
            return None
        return getattr(self, logical, None)


def rules_for(
    mesh: Mesh, *, fsdp: bool = True, seq_shard: bool = False
) -> ShardingRules:
    """Production rules for a mesh: tensor-parallel dims on ``model``,
    FSDP weight shards on ``data`` (when enabled and present)."""
    tp = "model" if "model" in mesh.shape else None
    dp = "data" if (fsdp and "data" in mesh.shape) else None
    return ShardingRules(
        fsdp=dp,
        ff=tp,
        heads=tp,
        kv_heads=tp,
        ssm_heads=tp,
        vocab=tp,
        experts=tp,
        # Expert matrices keep their d_ff storage shards in place (tokens
        # move instead) — mirrors the ff_axis level in moe_apply.
        expert_ff=dp,
        act_seq=(tp if seq_shard else None),
    )


def axes_tuple(axes) -> tuple:
    """Normalise a mesh-axis selection (None | str | sequence) to a tuple."""
    if axes is None:
        return ()
    if isinstance(axes, (list, tuple)):
        return tuple(axes)
    return (axes,)


def mesh_extent(mesh: Mesh | None, axes) -> int:
    """Product of the mesh extents of ``axes`` (1 for no mesh)."""
    if mesh is None:
        return 1
    ext = 1
    for a in axes_tuple(axes):
        ext *= mesh.shape[a]
    return ext


def logical_to_spec(
    logical: tuple, shape: tuple, mesh: Mesh, rules: ShardingRules
) -> P:
    """Resolve a logical axis tuple to a PartitionSpec for ``mesh``.

    Guards applied per dim, in order: mapping exists, all mesh axes present,
    no mesh axis reused by an earlier dim, dim size divisible by the shard
    extent. A dim failing any guard is replicated.
    """
    used: set = set()
    entries = []
    for name, dim in zip(logical, shape):
        sel = rules.axis_for(name)
        axes = (sel,) if isinstance(sel, str) else tuple(sel or ())
        ok = (
            axes
            and all(a in mesh.shape for a in axes)
            and not (set(axes) & used)
            and dim % mesh_extent(mesh, axes) == 0
        )
        if ok:
            used.update(axes)
            entries.append(axes[0] if len(axes) == 1 else axes)
        else:
            entries.append(None)
    return P(*entries)
