"""GPipe pipeline parallelism over a mesh axis.

``pipeline_apply`` runs a stack of S identical stages sharded over a
``stage`` mesh axis: each device holds S/n_stages consecutive stage
params, microbatches stream through the pipeline via ``ppermute``, and the
schedule is bit-equivalent to applying the stages sequentially (tested in
tests/multidevice/md_pipeline.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist import compat

Array = jax.Array


def pipeline_apply(
    stage_fn,
    params,
    x: Array,
    *,
    mesh: Mesh,
    axis: str = "stage",
    microbatches: int = 1,
):
    """Apply ``S`` stacked stages to ``x`` with GPipe over ``mesh[axis]``.

    Args:
      stage_fn: ``(stage_params, h) -> h`` for ONE stage.
      params: pytree whose leaves carry a leading stage axis of size S
        (divisible by the mesh axis extent; each shard applies its
        consecutive block of stages in order).
      x: (B, ...) global batch, replicated; B divisible by ``microbatches``.
      mesh: device mesh containing ``axis``.
      axis: pipeline mesh axis name.
      microbatches: number of in-flight microbatches (GPipe bubbles shrink
        as this grows; 1 = fully sequential).
    Returns:
      (B, ...) output, replicated — identical to folding all S stages.
    """
    n_stages = mesh.shape[axis]
    s_total = jax.tree.leaves(params)[0].shape[0]
    if s_total % n_stages:
        raise ValueError(f"{s_total} stages over {n_stages}-way axis {axis!r}")
    per = s_total // n_stages
    b = x.shape[0]
    if b % microbatches:
        raise ValueError(f"batch {b} not divisible by {microbatches} microbatches")
    mb = b // microbatches
    fwd = [(i, i + 1) for i in range(n_stages - 1)]

    def body(p_loc, x_rep):
        i = lax.axis_index(axis)
        mbs = x_rep.reshape((microbatches, mb) + x_rep.shape[1:])

        def local_apply(h):
            for j in range(per):
                h = stage_fn(jax.tree.map(lambda a: a[j], p_loc), h)
            return h

        carry = compat.pvary(jnp.zeros_like(mbs[0]), (axis,))
        out = compat.pvary(jnp.zeros_like(mbs), (axis,))
        last = n_stages - 1
        for t in range(microbatches + n_stages - 1):
            feed = mbs[min(t, microbatches - 1)]
            h = local_apply(jnp.where(i == 0, feed, carry))
            if t >= last:  # microbatch t-last drains from the last stage
                keep = jnp.where(i == last, h, jnp.zeros_like(h))
                out = out.at[t - last].set(keep)
            if fwd:
                carry = lax.ppermute(h, axis, fwd)
        out = lax.psum(out, axis)
        return out.reshape((b,) + x_rep.shape[1:])

    fn = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(
            jax.tree.map(lambda _: P(axis), params),
            P(),
        ),
        out_specs=P(),
    )
    return fn(params, x)
