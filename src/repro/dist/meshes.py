"""Named device meshes.

``make_mesh`` is the one mesh constructor in the repo: everything from the
2-device CPU debug mesh to the 512-chip dry-run pod goes through it, so
device selection and axis naming cannot drift between launchers, tests and
benchmarks.
"""

from __future__ import annotations

import math
from typing import Sequence

import jax
import numpy as np
from jax.sharding import Mesh


def make_mesh(
    shape: Sequence[int],
    axes: Sequence[str],
    *,
    devices=None,
) -> Mesh:
    """Build a named ``Mesh`` of the given shape.

    Args:
      shape: extent per mesh axis, e.g. ``(16, 16)``.
      axes: axis name per extent, e.g. ``("data", "model")``.
      devices: devices to lay out (default: all local ``jax.devices()``).
        Exactly ``prod(shape)`` leading devices are used.
    """
    shape, axes = tuple(int(s) for s in shape), tuple(axes)
    if len(shape) != len(axes):
        raise ValueError(f"shape {shape} and axes {axes} length mismatch")
    n = math.prod(shape)
    if devices is None:
        devices = jax.devices()
    if len(devices) < n:
        raise ValueError(
            f"mesh {dict(zip(axes, shape))} needs {n} devices, "
            f"have {len(devices)}"
        )
    devices = list(devices)[:n]
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes, devices=devices)
    return Mesh(np.asarray(devices).reshape(shape), axes)


def host_mesh(
    shape: Sequence[int] | None = None,
    axes: Sequence[str] = ("hosts",),
    *,
    devices=None,
) -> Mesh:
    """A global mesh with ONE representative device per process.

    Cross-host collectives over host-local sufficient statistics only
    need one device per host (the statistics already live on a single
    local device); the mesh must place process ``p`` at row-major mesh
    position ``p`` so shard coordinates equal mesh coordinates.
    ``jax.make_mesh`` may reorder devices for transfer performance,
    which would silently break that mapping — hence the raw ``Mesh``
    constructor here.

    Args:
      shape: extent per axis (default ``(num_processes,)``); must
        multiply out to the process count.
      axes: axis name per extent.
      devices: override the representative devices (tests); default is
        the lowest-id device of each process, ordered by process index.
    """
    if devices is None:
        by_proc: dict[int, object] = {}
        for d in jax.devices():
            p = d.process_index
            if p not in by_proc or d.id < by_proc[p].id:
                by_proc[p] = d
        devices = [by_proc[p] for p in sorted(by_proc)]
    devices = list(devices)
    if shape is None:
        shape = (len(devices),)
    shape, axes = tuple(int(s) for s in shape), tuple(axes)
    if math.prod(shape) != len(devices):
        raise ValueError(
            f"host mesh shape {dict(zip(axes, shape))} needs "
            f"{math.prod(shape)} hosts, have {len(devices)}"
        )
    return Mesh(np.asarray(devices, dtype=object).reshape(shape), axes)


def factor_mesh(n_devices: int, *, bias: float = 1.0) -> tuple[int, int]:
    """Split ``n_devices`` into a 2-D grid ``(a, b)``, ``a*b == n_devices``.

    ``bias`` > 1 pushes devices toward the first axis (used by the selection
    planner to give the longer data axis more shards).  Prefers balanced
    factorisations; falls back to ``(n, 1)`` for primes.
    """
    if n_devices <= 0:
        raise ValueError(f"n_devices must be positive, got {n_devices}")
    target = math.sqrt(n_devices * bias)
    best = (n_devices, 1)
    best_err = float("inf")
    for a in range(1, n_devices + 1):
        if n_devices % a:
            continue
        err = abs(math.log(a / target)) if target > 0 else float(a)
        if err < best_err:
            best, best_err = (a, n_devices // a), err
    return best
