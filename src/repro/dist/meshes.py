"""Named device meshes.

``make_mesh`` is the one mesh constructor in the repo: everything from the
2-device CPU debug mesh to the 512-chip dry-run pod goes through it, so
device selection and axis naming cannot drift between launchers, tests and
benchmarks.
"""

from __future__ import annotations

import math
from typing import Sequence

import jax
import numpy as np
from jax.sharding import Mesh


def make_mesh(
    shape: Sequence[int],
    axes: Sequence[str],
    *,
    devices=None,
) -> Mesh:
    """Build a named ``Mesh`` of the given shape.

    Args:
      shape: extent per mesh axis, e.g. ``(16, 16)``.
      axes: axis name per extent, e.g. ``("data", "model")``.
      devices: devices to lay out (default: all local ``jax.devices()``).
        Exactly ``prod(shape)`` leading devices are used.
    """
    shape, axes = tuple(int(s) for s in shape), tuple(axes)
    if len(shape) != len(axes):
        raise ValueError(f"shape {shape} and axes {axes} length mismatch")
    n = math.prod(shape)
    if devices is None:
        devices = jax.devices()
    if len(devices) < n:
        raise ValueError(
            f"mesh {dict(zip(axes, shape))} needs {n} devices, "
            f"have {len(devices)}"
        )
    devices = list(devices)[:n]
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes, devices=devices)
    return Mesh(np.asarray(devices).reshape(shape), axes)


def factor_mesh(n_devices: int, *, bias: float = 1.0) -> tuple[int, int]:
    """Split ``n_devices`` into a 2-D grid ``(a, b)``, ``a*b == n_devices``.

    ``bias`` > 1 pushes devices toward the first axis (used by the selection
    planner to give the longer data axis more shards).  Prefers balanced
    factorisations; falls back to ``(n, 1)`` for primes.
    """
    if n_devices <= 0:
        raise ValueError(f"n_devices must be positive, got {n_devices}")
    target = math.sqrt(n_devices * bias)
    best = (n_devices, 1)
    best_err = float("inf")
    for a in range(1, n_devices + 1):
        if n_devices % a:
            continue
        err = abs(math.log(a / target)) if target > 0 else float(a)
        if err < best_err:
            best, best_err = (a, n_devices // a), err
    return best
