"""Observation-block placement for the streaming engine.

The streaming fit path moves host blocks onto devices one at a time; this
module owns that placement the same way ``repro.core.selector`` owns it
for in-memory fits.  ``BlockPlacer`` pads every incoming block to one
fixed row count (so the engine's accumulate step compiles exactly once)
and, given a mesh, lands the block sharded per the plan's regime:

* **obs-sharded** (tall datasets) — rows split over ``obs_axes``, each
  device accumulating statistics for every feature on its row slice; XLA
  reduces with the same all-reduce the in-memory conventional engine uses.
* **feature-sharded** (wide datasets) — columns split over ``feat_axes``
  and the *statistics state itself* lives sharded over features
  (``place_state`` / ``state_shardings``), so per-device statistics memory
  is ``O(N/shards · d_v · d_c)`` instead of the full per-pair state.
* **2-D grid** — both at once: rows over ``obs_axes``, columns and state
  over ``feat_axes``; XLA partitions the accumulate across the grid and
  all-reduces over the observation axes only.

Padded rows are reported through a ``valid`` mask; what a score does with
it (out-of-range categories, zero-weighted moments) is the score's
business.  Padded feature columns produce junk statistics rows that the
engine slices off after ``finalize``.

``PrefetchPlacer`` is the double-buffered face of the same placement: a
bounded host thread reads and pads block ``i+1`` while the consumer
places (async ``device_put``) and the device accumulates block ``i``, so
streaming throughput approaches the device-bound in-memory rate instead
of serialising source I/O with placement.

``CrossPassReader`` extends the same overlap across *pass boundaries*:
the streaming engine visits the source once per selection, and between
passes the synchronous path stalls — finalize, host argmax, then pass
``l+1`` starts reading from byte zero.  But block *reads* never depend
on the just-picked column (only the pass-target extraction does, and
that is a cheap host slice at consume time), so a reader thread can keep
streaming blocks of pass ``l+1`` while the device finishes pass ``l``.

Batched redundancy passes (``batch_candidates > 1``) reuse all of this
unchanged except for a leading candidate axis: targets become ``(q, B)``
and statistics leaves ``(q, N, ...)`` — ``stage``/``place`` and
``state_shardings`` recognise both layouts.
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.sharding import axes_tuple, mesh_extent

# End-of-stream sentinel for the prefetch queue.
_DONE = object()

# End-of-pass sentinel for the cross-pass read-ahead queue.
_PASS_END = object()


def resolve_prefetch(prefetch, backend: str | None = None) -> int:
    """Resolve the ``prefetch`` knob: an int passes through, ``"auto"``
    applies the measured heuristic.

    Heuristic: the staging thread only pays off when placement blocks the
    consumer — i.e. on backends with *blocking* host-to-device transfers
    (GPU/TPU), where overlapping the numpy stage with the transfer hides
    real latency.  On the CPU backend ``device_put`` and the accumulate
    dispatch are already asynchronous, so the synchronous placer never
    stalls and the extra thread only buys queue handoffs plus GIL/
    threadpool contention with XLA's own workers — measured ~15% *slower*
    (``BENCH_streaming.json``: streaming@16384+pf2 755k rows/s vs pf0's
    881k on the 200k x 256 case).  So ``"auto"`` = 0 on CPU, 2 elsewhere.
    """
    if prefetch != "auto":
        try:
            p = int(prefetch)
        except (TypeError, ValueError):
            raise ValueError(
                f"prefetch must be an int >= 0 or 'auto', got {prefetch!r}"
            ) from None
        if p < 0:
            raise ValueError(f"prefetch must be >= 0 or 'auto', got {p}")
        return p
    if backend is None:
        import jax  # local: keep module importable pre-XLA-init

        backend = jax.default_backend()
    return 0 if backend == "cpu" else 2


def effective_block_obs(block_obs: int, obs_extent: int) -> int:
    """The placer's one block-rounding rule — blocks round UP to a
    multiple of the observation-axes extent so every shard gets equal
    rows.  Shared with ``MRMRSelector._resolve_stream_plan`` so
    ``plan_.block_obs`` always reports exactly what the placer runs."""
    ext = max(int(obs_extent), 1)
    return -(-int(block_obs) // ext) * ext


@dataclasses.dataclass
class BlockPlacer:
    """Pad-and-place for observation blocks.

    Args:
      block_obs: requested rows per block; rounded UP to a multiple of the
        observation-axes extent so every shard gets equal rows.
      mesh: device mesh, or None for single-device placement.
      obs_axes: mesh axes to shard observations over (intersected with the
        mesh's axes).
      feat_axes: mesh axes to shard features — and the statistics state —
        over (intersected with the mesh's axes).
      num_features: global feature count; required for feature sharding,
        where columns are padded up to a multiple of the feature-axes
        extent (``padded_features``) so every shard gets equal columns.
    """

    block_obs: int
    mesh: Mesh | None = None
    obs_axes: tuple = ()
    feat_axes: tuple = ()
    num_features: int | None = None

    def __post_init__(self):
        obs = axes_tuple(self.obs_axes)
        feat = axes_tuple(self.feat_axes)
        if self.mesh is not None:
            obs = tuple(a for a in obs if a in self.mesh.shape)
            feat = tuple(a for a in feat if a in self.mesh.shape)
            if not obs and not feat:
                # A mesh the blocks can't shard over would silently run
                # single-device against the caller's device budget — guard
                # here so the direct engine API fails like the selector.
                raise ValueError(
                    f"mesh axes {tuple(self.mesh.shape)} share no axis "
                    f"with obs_axes {axes_tuple(self.obs_axes)} or "
                    f"feat_axes {axes_tuple(self.feat_axes)}"
                )
            if feat and self.num_features is None:
                # Without the global feature count the placer can neither
                # pad columns to the shard extent nor shard the statistics
                # state — feature sharding would fail late (opaque
                # device_put error) or silently replicate the state it
                # exists to split.
                raise ValueError(
                    "feature sharding requires num_features "
                    f"(feat_axes={feat} on mesh {tuple(self.mesh.shape)})"
                )
        self.obs_axes, self.feat_axes = obs, feat
        oext = mesh_extent(self.mesh, obs)
        fext = mesh_extent(self.mesh, feat)
        self.block_obs = effective_block_obs(self.block_obs, oext)
        self._feat_pad = (
            -(-int(self.num_features) // fext) * fext
            if self.num_features is not None
            else None
        )
        if self.mesh is not None:
            ospec = obs if obs else None
            fspec = feat if feat else None
            self._shard_mat = NamedSharding(self.mesh, P(ospec, fspec))
            self._shard_vec = NamedSharding(self.mesh, P(ospec))
            self._shard_tgt2 = NamedSharding(self.mesh, P(None, ospec))
        else:
            self._shard_mat = self._shard_vec = self._shard_tgt2 = None

    @property
    def padded_features(self) -> int:
        """Feature count after padding to the feature-axes extent."""
        if self._feat_pad is None:
            raise ValueError("BlockPlacer was built without num_features")
        return self._feat_pad

    # -- statistics-state placement -------------------------------------

    def state_shardings(self, state):
        """Shardings for a statistics pytree (None when there is no mesh):
        leaves with a ``padded_features`` dim in position 0 — or position 1
        behind a leading candidate-batch axis (batched redundancy passes
        carry ``(q, N, ...)`` statistics) — shard over ``feat_axes``;
        everything else (scalars, running counts) is replicated.  Used both
        to place the initial state and as the accumulate step's
        ``out_shardings``, pinning the state layout so per-device
        statistics memory scales with ``1/feature-shards``."""
        if self.mesh is None:
            return None

        def sh(leaf):
            leaf = jnp.asarray(leaf)
            if self.feat_axes and self._feat_pad is not None:
                if leaf.ndim >= 1 and leaf.shape[0] == self._feat_pad:
                    spec = P(self.feat_axes, *([None] * (leaf.ndim - 1)))
                    return NamedSharding(self.mesh, spec)
                if leaf.ndim >= 2 and leaf.shape[1] == self._feat_pad:
                    # (q, N, ...) batched statistics: replicate the small
                    # candidate axis, split the feature axis as usual.
                    spec = P(None, self.feat_axes, *([None] * (leaf.ndim - 2)))
                    return NamedSharding(self.mesh, spec)
            return NamedSharding(self.mesh, P())

        return jax.tree.map(sh, state)

    def place_state(self, state):
        """Land a freshly initialised statistics pytree per
        :meth:`state_shardings` (identity without a mesh)."""
        shardings = self.state_shardings(state)
        if shardings is None:
            return jax.tree.map(jnp.asarray, state)
        return jax.tree.map(
            lambda leaf, s: jax.device_put(jnp.asarray(leaf), s),
            state,
            shardings,
        )

    def stage(self, X_block: np.ndarray, target: np.ndarray):
        """Host half: pad a (B, N) block + its target to the fixed
        (block_obs, padded-features) shape and build the valid mask.  The
        target is ``(B,)`` for single-target passes or ``(q, B)`` for
        batched redundancy passes (padded along its observation axis
        either way).  Pure numpy — safe to run on a background thread
        (``PrefetchPlacer`` does)."""
        b, nf = X_block.shape
        if b > self.block_obs:
            raise ValueError(
                f"block of {b} rows exceeds block_obs={self.block_obs}"
            )
        if self.num_features is not None and nf != self.num_features:
            raise ValueError(
                f"block has {nf} features, placer expects {self.num_features}"
            )
        if b < self.block_obs:
            pad = self.block_obs - b
            X_block = np.concatenate(
                [X_block, np.zeros((pad,) + X_block.shape[1:], X_block.dtype)]
            )
            tpad = np.zeros(target.shape[:-1] + (pad,), target.dtype)
            target = np.concatenate([target, tpad], axis=-1)
        if self._feat_pad is not None and nf < self._feat_pad:
            # Zero-filled pad columns: their statistics rows are junk by
            # construction and the engine slices them off after finalize.
            X_block = np.concatenate(
                [
                    X_block,
                    np.zeros(
                        (X_block.shape[0], self._feat_pad - nf), X_block.dtype
                    ),
                ],
                axis=1,
            )
        valid = np.arange(self.block_obs) < b
        return X_block, target, valid

    def place(self, staged):
        """Device half: land a staged (X, target, valid) triple per the
        mesh plan.  ``device_put`` is async — it enqueues and returns.
        A 2-D ``(q, B)`` batched target shards its observation axis like
        the 1-D case, with the candidate axis replicated."""
        X_block, target, valid = staged
        if self._shard_mat is not None:
            tgt_sh = self._shard_vec if target.ndim == 1 else self._shard_tgt2
            return (
                jax.device_put(X_block, self._shard_mat),
                jax.device_put(target, tgt_sh),
                jax.device_put(valid, self._shard_vec),
            )
        return jnp.asarray(X_block), jnp.asarray(target), jnp.asarray(valid)

    def __call__(self, X_block: np.ndarray, target: np.ndarray):
        """(B, N), (B,) host block -> placed (X, target, valid), B' fixed."""
        return self.place(self.stage(X_block, target))


@dataclasses.dataclass
class PrefetchPlacer:
    """Double-buffered placement: a host thread runs the wrapped placer's
    *staging* half (source read + pad — pure numpy) up to ``depth`` blocks
    ahead, while the consumer thread runs the *placement* half
    (``device_put``, async) and the device accumulates the previous block.
    The worker never touches jax, so it cannot contend with the XLA
    runtime's own thread pool.  Exceptions raised while reading or staging
    re-raise in the consumer, and abandoning the iterator stops the
    thread.
    """

    placer: BlockPlacer
    depth: int = 2

    def __post_init__(self):
        if self.depth < 1:
            raise ValueError(f"prefetch depth must be >= 1, got {self.depth}")

    def stream(self, host_blocks):
        """``(X_block, target)`` host iterator -> placed-tuple iterator."""
        q: queue.Queue = queue.Queue(maxsize=self.depth)
        stop = threading.Event()

        def produce():
            # Plain blocking puts: zero handoff latency in steady state.
            # On early consumer exit the finally-block below sets ``stop``
            # and drains the queue until this thread observes it and dies.
            try:
                for X_block, target in host_blocks:
                    if stop.is_set():
                        return
                    q.put((self.placer.stage(X_block, target), None))
                q.put((_DONE, None))
            except BaseException as exc:  # re-raised by the consumer
                q.put((None, exc))

        worker = threading.Thread(
            target=produce, name="block-prefetch", daemon=True
        )
        worker.start()
        try:
            while True:
                staged, exc = q.get()
                if exc is not None:
                    raise exc
                if staged is _DONE:
                    return
                yield self.placer.place(staged)
        finally:
            stop.set()
            while worker.is_alive():
                try:  # unblock a producer waiting on a full queue
                    q.get_nowait()
                except queue.Empty:
                    pass
                worker.join(timeout=0.01)


class CrossPassReader:
    """Read blocks ahead *across pass boundaries* on one reader thread.

    The streaming engine's pass loop has a structural bubble: while the
    device finalizes pass ``l`` and the host folds/argmaxes, nobody is
    reading pass ``l+1`` — yet which blocks a pass reads never depends on
    the pick (only the target-column *extraction* does, and the engine
    extracts at consume time).  This reader keeps one thread iterating
    ``make_pass()`` — a fresh raw ``(X, y)`` host-block iterator per call
    — pass after pass, up to ``depth`` blocks ahead through a bounded
    queue, so the tail of pass ``l`` overlaps the head of pass ``l+1``.

    The consumer pulls whole passes in order via :meth:`next_pass` and
    must call :meth:`close` (or exhaust ``max_passes``) to stop the
    thread.  Read/parse exceptions re-raise in the consumer at the block
    they correspond to.
    """

    def __init__(self, make_pass, depth: int = 2, max_passes: int | None = None):
        if depth < 1:
            raise ValueError(f"read-ahead depth must be >= 1, got {depth}")
        if max_passes is not None and max_passes < 1:
            raise ValueError(f"max_passes must be >= 1, got {max_passes}")
        self._make_pass = make_pass
        self._max_passes = max_passes
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._passes_started = 0
        self._worker = threading.Thread(
            target=self._produce, name="cross-pass-readahead", daemon=True
        )
        self._worker.start()

    def _produce(self):
        try:
            p = 0
            while self._max_passes is None or p < self._max_passes:
                self._passes_started += 1
                for blk in self._make_pass():
                    if self._stop.is_set():
                        return
                    self._q.put((blk, None))
                self._q.put((_PASS_END, None))
                if self._stop.is_set():
                    return
                p += 1
            self._q.put((_DONE, None))
        except BaseException as exc:  # re-raised by the consumer
            self._q.put((None, exc))

    def next_pass(self):
        """Iterator over the next pass's raw ``(X, y)`` host blocks."""
        while True:
            item, exc = self._q.get()
            if exc is not None:
                raise exc
            if item is _PASS_END:
                return
            if item is _DONE:
                raise RuntimeError(
                    "CrossPassReader exhausted: next_pass() called after "
                    f"max_passes={self._max_passes} passes were consumed"
                )
            yield item

    def close(self):
        """Stop the reader thread and drop any read-ahead blocks."""
        self._stop.set()
        while self._worker.is_alive():
            try:  # unblock a producer waiting on a full queue
                self._q.get_nowait()
            except queue.Empty:
                pass
            self._worker.join(timeout=0.01)

    def __enter__(self) -> "CrossPassReader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
