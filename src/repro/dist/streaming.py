"""Observation-block placement for the streaming engine.

The streaming fit path moves host blocks onto devices one at a time; this
module owns that placement the same way ``repro.core.selector`` owns it
for in-memory fits.  ``BlockPlacer`` pads every incoming block to one
fixed row count (so the engine's accumulate step compiles exactly once)
and, given a mesh, lands the block sharded over the observation axes —
each device holds ``block_obs / extent`` rows and XLA partitions the
statistics accumulation data-parallel, reducing with the same all-reduce
the in-memory conventional engine uses.  Padded rows are reported through
a ``valid`` mask; what a score does with it (out-of-range categories,
zero-weighted moments) is the score's business.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.sharding import axes_tuple, mesh_extent


@dataclasses.dataclass
class BlockPlacer:
    """Pad-and-place for observation blocks.

    Args:
      block_obs: requested rows per block; rounded UP to a multiple of the
        observation-axes extent so every shard gets equal rows.
      mesh: device mesh, or None for single-device placement.
      obs_axes: mesh axes to shard observations over (intersected with the
        mesh's axes).
    """

    block_obs: int
    mesh: Mesh | None = None
    obs_axes: tuple = ()

    def __post_init__(self):
        axes = axes_tuple(self.obs_axes)
        if self.mesh is not None:
            axes = tuple(a for a in axes if a in self.mesh.shape)
            if not axes:
                # A mesh the blocks can't shard over would silently run
                # single-device against the caller's device budget — guard
                # here so the direct engine API fails like the selector.
                raise ValueError(
                    f"mesh axes {tuple(self.mesh.shape)} share no axis "
                    f"with obs_axes {axes_tuple(self.obs_axes)}"
                )
        self.obs_axes = axes
        ext = mesh_extent(self.mesh, axes)
        self.block_obs = -(-int(self.block_obs) // ext) * ext
        if self.mesh is not None and axes:
            self._shard_mat = NamedSharding(self.mesh, P(axes, None))
            self._shard_vec = NamedSharding(self.mesh, P(axes))
        else:
            self._shard_mat = self._shard_vec = None

    def __call__(self, X_block: np.ndarray, target: np.ndarray):
        """(B, N), (B,) host block -> placed (X, target, valid), B' fixed."""
        b = X_block.shape[0]
        if b > self.block_obs:
            raise ValueError(
                f"block of {b} rows exceeds block_obs={self.block_obs}"
            )
        if b < self.block_obs:
            pad = self.block_obs - b
            X_block = np.concatenate(
                [X_block, np.zeros((pad,) + X_block.shape[1:], X_block.dtype)]
            )
            target = np.concatenate([target, np.zeros((pad,), target.dtype)])
        valid = np.arange(self.block_obs) < b
        if self._shard_mat is not None:
            return (
                jax.device_put(X_block, self._shard_mat),
                jax.device_put(target, self._shard_vec),
                jax.device_put(valid, self._shard_vec),
            )
        return jnp.asarray(X_block), jnp.asarray(target), jnp.asarray(valid)
