"""Version-portable jax collectives API.

The repo targets the modern spellings (``jax.shard_map``, ``lax.pvary``);
older installs (<= 0.4.x) only ship ``jax.experimental.shard_map`` and have
no ``pvary`` (its VMA bookkeeping does not exist there, so identity is the
correct fallback).  All call sites import from this module so the rest of
the codebase is version-agnostic.
"""

from __future__ import annotations

import jax
from jax import lax

if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)

else:
    from jax.experimental.shard_map import shard_map as _shard_map_exp

    def shard_map(f, *, mesh, in_specs, out_specs):
        # check_rep=False: the legacy replication checker predates several
        # collectives used here (pmax/pmin argmax ladders) and has no pvary
        # escape hatch; the out_specs still pin the contract.
        return _shard_map_exp(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
        )


if hasattr(lax, "pvary"):

    def pvary(x, axes):
        if not axes:
            return x
        return jax.tree.map(lambda v: lax.pvary(v, axes), x)

else:

    def pvary(x, axes):  # pre-VMA jax: values carry no varying-axes type
        return x
