"""repro.dist — the distribution substrate.

Everything that touches device topology lives here, so the rest of the
repo (drivers, models, launchers) never talks to raw jax device state:

* ``repro.dist.compat``   — version-portable ``shard_map`` / ``pvary``
  (jax moved both across releases; call sites import from here).
* ``repro.dist.meshes``   — ``make_mesh``: named device meshes from a
  (shape, axis-names) pair, the single mesh constructor in the repo.
* ``repro.dist.sharding`` — logical-axis sharding: ``ShardingRules`` maps
  logical parameter axes (``fsdp``, ``ff``, ``heads``, ...) to mesh axes,
  ``logical_to_spec`` resolves them to ``PartitionSpec`` with divisibility
  and axis-reuse guards.
* ``repro.dist.pipeline`` — GPipe pipeline parallelism over a mesh axis.
* ``repro.dist.streaming`` — ``BlockPlacer``: pad-and-shard placement of
  streamed observation-blocks (obs-sharded, feature-sharded or 2-D grid)
  for the out-of-core fit path, plus ``PrefetchPlacer``, its
  double-buffered wrapper overlapping host reads with device compute.
* ``repro.dist.multihost`` — cross-process map-reduce: ``init_multihost``
  bootstrap over ``jax.distributed``, ``HostShardSpec`` (the paper's §III
  sharding rule applied to hosts — each host reads only its block/column
  ranges) and ``HostCollectives`` (the per-pass reduce as explicit
  ``shard_map``-ped psums over a one-device-per-process mesh).
"""

from repro.dist.compat import pvary, shard_map  # noqa: F401
from repro.dist.meshes import factor_mesh, host_mesh, make_mesh  # noqa: F401
from repro.dist.multihost import (  # noqa: F401
    HostCollectives,
    HostShardSpec,
    init_multihost,
    resolve_host_shards,
    split_range,
)
from repro.dist.streaming import BlockPlacer, PrefetchPlacer  # noqa: F401
from repro.dist.sharding import (  # noqa: F401
    ShardingRules,
    axes_tuple,
    logical_to_spec,
    mesh_extent,
    rules_for,
)
