"""Sharded checkpointing: atomic, async, elastic-restore.

Layout: ``<dir>/step_<n>/proc_<i>.npz`` + ``manifest.json``.  Each process
saves only its addressable shards (single-process containers save
everything); writes land in ``step_<n>.tmp`` and are ``os.replace``d into
place, so a crash mid-write can never corrupt the latest checkpoint.
Restore takes a *target sharding tree*, so a checkpoint written on one mesh
restores onto any other (elastic re-shard): arrays are assembled host-side
and re-``device_put`` under the new sharding.

Multi-process discipline (checkpoint dirs are usually on a shared
filesystem): every process publishes ONLY its own ``proc_<i>.npz``
(written to a private name, ``os.replace``d into the step's tmp dir), and
process 0 alone — after polling for every shard — writes the manifest,
swaps the tmp dir into place and garbage-collects.  Before this split,
every process raced the same ``rmtree(final); os.replace(tmp, final)``
sequence: the loser's ``rmtree`` could delete the winner's just-published
checkpoint and its ``replace`` then fail on the vanished tmp.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import jax
import numpy as np

SEP = "\x1e"  # record separator: flat pytree key


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = SEP.join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path
        )
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    """Save/restore TrainState pytrees with retention + async writes."""

    def __init__(
        self,
        directory: str,
        *,
        keep: int = 3,
        use_async: bool = True,
        process_index: int | None = None,
        process_count: int | None = None,
        publish_timeout: float = 300.0,
    ):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1) if use_async else None
        self._pending = None
        self._lock = threading.Lock()
        # Injectable cluster coordinates (tests simulate N writers without
        # jax.distributed); None defers to jax at write time.
        self._process_index = process_index
        self._process_count = process_count
        self.publish_timeout = publish_timeout

    def _coords(self) -> tuple[int, int]:
        proc = (
            jax.process_index()
            if self._process_index is None
            else self._process_index
        )
        nproc = (
            jax.process_count()
            if self._process_count is None
            else self._process_count
        )
        return proc, nproc

    # ------------------------------------------------------------------ save
    def save(self, step: int, state) -> None:
        """Snapshot to host memory NOW, write asynchronously."""
        host_tree = jax.tree.map(lambda x: np.asarray(x), state)
        if self._pool is None:
            self._write(step, host_tree)
            return
        self.wait()
        with self._lock:
            self._pending = self._pool.submit(self._write, step, host_tree)

    def wait(self) -> None:
        with self._lock:
            pending, self._pending = self._pending, None
        if pending is not None:
            pending.result()

    def _write(self, step: int, host_tree) -> None:
        flat, _ = _flatten_with_paths(host_tree)
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        proc, nproc = self._coords()
        # Every process lands ONLY its shard file, atomically (private
        # name, then os.replace): the coordinator's poll below can never
        # observe a torn .npz, and no two processes ever write one path.
        part = os.path.join(tmp, f"proc_{proc}.npz.part")
        with open(part, "wb") as f:
            np.savez(f, **flat)
        os.replace(part, os.path.join(tmp, f"proc_{proc}.npz"))
        if proc != 0:
            return  # process 0 alone publishes (manifest, swap, gc)
        expect = [os.path.join(tmp, f"proc_{i}.npz") for i in range(nproc)]
        deadline = time.monotonic() + self.publish_timeout
        while not all(os.path.exists(p) for p in expect):
            if time.monotonic() >= deadline:
                missing = [p for p in expect if not os.path.exists(p)]
                raise TimeoutError(
                    f"step {step}: {len(missing)}/{nproc} shard files never "
                    f"arrived within {self.publish_timeout}s "
                    f"(first missing: {os.path.basename(missing[0])})"
                )
            time.sleep(0.05)
        manifest = {
            "step": step,
            "num_processes": nproc,
            "keys": sorted(flat),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True
            )

    # ---------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(
                    os.path.join(self.directory, name, "manifest.json")
                ):
                    out.append(int(name[len("step_"):]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like, shardings=None):
        """Restore into the structure of ``like`` (pytree of arrays or
        ShapeDtypeStructs). ``shardings``: matching tree of NamedShardings
        for elastic re-shard; None -> default device placement."""
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = {}
        for i in range(manifest["num_processes"]):
            fp = os.path.join(path, f"proc_{i}.npz")
            if os.path.exists(fp):
                with np.load(fp) as z:
                    data.update({k: z[k] for k in z.files})

        flat_like, treedef = _flatten_with_paths(like)
        missing = set(flat_like) - set(data)
        if missing:
            raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]}...")
        # leaves must be fed back in TREEDEF order (flat_like preserves it);
        # sorting here once scrambled params with the (shape-identical) Adam
        # moments — caught by the multi-device bitwise-replay test.
        restored = jax.tree_util.tree_unflatten(
            treedef, [data[k] for k in flat_like]
        )
        if shardings is not None:
            restored = jax.tree.map(
                lambda x, s: jax.device_put(x, s), restored, shardings
            )
        else:
            restored = jax.tree.map(jax.device_put, restored)
        return restored
