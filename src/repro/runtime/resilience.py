"""Fault-tolerance runtime: watchdog, crash-restart driver, elastic re-shard.

On a real multi-pod deployment, failures surface as (a) hung collectives
(node loss -> step never completes), (b) process crashes, (c) degraded
stragglers.  The mitigations here are the host-side halves that are
testable on CPU; the launch scripts (launch/run_*.sh) pair them with the
TPU-side flags (--xla_tpu_enable_flash_... timeouts, preemption signal
handling).

* ``StepWatchdog``  — per-step heartbeat; a step exceeding ``timeout_s``
  triggers ``on_stall`` (default: log loudly).  Catches hung collectives
  and stragglers: the driver can checkpoint-skip or abort for the restart
  wrapper to take over.
* ``run_with_restarts`` — crash-restart loop: on exception, restore the
  latest checkpoint and resume (bounded retries).  Paired with the
  deterministic step-indexed data pipeline, restarts are replay-exact.
* ``retry_with_backoff`` — call-level retry with exponential backoff for
  transient failures (flaky I/O, a preempted worker); the selection
  service wraps each engine run in it so one wobble never fails a job.
* ``elastic_restore`` — restore a checkpoint under a DIFFERENT mesh: the
  checkpoint layout is mesh-agnostic (host-side full arrays), so scaling
  from N to M pods is a restore with new shardings.
"""

from __future__ import annotations

import logging
import threading
import time
from typing import Callable

logger = logging.getLogger("repro.resilience")


class TransientError(RuntimeError):
    """A failure expected to succeed on retry (flaky I/O, preemption).

    Raise it — or pass your own exception types via ``retry_on`` — to mark
    work as retryable; anything else propagates immediately.
    """


def retry_with_backoff(
    fn: Callable[[], object],
    *,
    max_attempts: int = 3,
    base_delay_s: float = 0.1,
    max_delay_s: float = 30.0,
    backoff: float = 2.0,
    retry_on=(TransientError,),
    on_retry: Callable[[int, BaseException, float], None] | None = None,
    sleep: Callable[[float], None] = time.sleep,
):
    """Call ``fn()``; on a retryable exception, back off and re-call.

    Delay before attempt ``k+1`` is ``min(base * backoff**(k-1), max)``.
    Non-retryable exceptions — and the last retryable one once
    ``max_attempts`` calls have failed — propagate to the caller.
    ``on_retry(attempt, exc, delay_s)`` observes each retry (the selection
    service uses it to count attempts per job); ``sleep`` is injectable
    for tests.
    """
    if max_attempts < 1:
        raise ValueError(f"max_attempts must be >= 1, got {max_attempts}")
    attempt = 1
    while True:
        try:
            return fn()
        except retry_on as e:
            if attempt >= max_attempts:
                raise
            delay = min(base_delay_s * backoff ** (attempt - 1), max_delay_s)
            logger.warning(
                "transient failure (attempt %d/%d), retrying in %.2fs: %s",
                attempt, max_attempts, delay, e,
            )
            if on_retry is not None:
                on_retry(attempt, e, delay)
            sleep(delay)
            attempt += 1


class StepWatchdog:
    """Heartbeat monitor: call ``beat(step)`` once per train step."""

    def __init__(
        self,
        timeout_s: float = 300.0,
        on_stall: Callable[[int, float], None] | None = None,
        poll_s: float = 1.0,
    ):
        self.timeout_s = timeout_s
        self.on_stall = on_stall or self._default_stall
        self.poll_s = poll_s
        self._last_beat = time.monotonic()
        self._last_step = -1
        self._stalled_steps: list[int] = []
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def _default_stall(self, step: int, elapsed: float) -> None:
        logger.error(
            "step %d stalled for %.1fs (straggler or hung collective)",
            step, elapsed,
        )

    def beat(self, step: int) -> None:
        self._last_beat = time.monotonic()
        self._last_step = step

    @property
    def stalled_steps(self) -> list[int]:
        return list(self._stalled_steps)

    def _run(self) -> None:
        while not self._stop.wait(self.poll_s):
            elapsed = time.monotonic() - self._last_beat
            if elapsed > self.timeout_s:
                self._stalled_steps.append(self._last_step)
                self.on_stall(self._last_step, elapsed)
                self._last_beat = time.monotonic()  # rate-limit alarms

    def __enter__(self) -> "StepWatchdog":
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def __exit__(self, *exc) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)


def run_with_restarts(
    make_state: Callable[[], object],
    run_from: Callable[[object], object],
    *,
    ckpt,
    state_like_fn: Callable[[], object],
    shardings=None,
    max_restarts: int = 3,
):
    """Crash-restart driver.

    ``make_state()`` builds a fresh state (cold start); ``run_from(state)``
    trains until done (raising on failure); ``ckpt`` is a CheckpointManager.
    On failure, restores the latest checkpoint (or cold-starts when none)
    and re-enters, up to ``max_restarts`` times.
    """
    attempts = 0
    while True:
        try:
            step = ckpt.latest_step()
            if step is None:
                state = make_state()
                logger.info("cold start")
            else:
                state = ckpt.restore(step, state_like_fn(), shardings)
                logger.info("restored checkpoint step %d", step)
            return run_from(state)
        except KeyboardInterrupt:
            raise
        except Exception as e:  # noqa: BLE001 — restart on any failure
            attempts += 1
            logger.exception("run failed (attempt %d): %s", attempts, e)
            if attempts > max_restarts:
                raise
            time.sleep(min(2.0**attempts, 30.0))


def elastic_restore(ckpt, step: int, bundle, opt_cfg, new_mesh):
    """Restore a checkpoint onto a different mesh (elastic scale up/down)."""
    import dataclasses

    from repro.models.model import build_model
    from repro.train.train_step import make_train_state_specs, train_state_shapes
    import jax
    from jax.sharding import NamedSharding

    new_bundle = build_model(bundle.cfg, new_mesh)
    like = train_state_shapes(new_bundle, opt_cfg)
    specs = make_train_state_specs(new_bundle)
    shardings = jax.tree.map(
        lambda s: NamedSharding(new_mesh, s), specs,
        is_leaf=lambda x: hasattr(x, "_parsed_pspec") or x.__class__.__name__ == "PartitionSpec",
    )
    return new_bundle, ckpt.restore(step, like, shardings)
