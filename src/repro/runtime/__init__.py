from repro.runtime.checkpoint import CheckpointManager  # noqa: F401
from repro.runtime.resilience import StepWatchdog, run_with_restarts  # noqa: F401
