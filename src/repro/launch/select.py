"""Feature-selection driver — the paper's job as a production CLI.

    PYTHONPATH=src python -m repro.launch.select --rows 100000 --cols 1000 \
        --select 10 --encoding conventional

    # 2-D grid over 8 simulated devices, explicit mesh shape:
    PYTHONPATH=src REPRO_DEVICES=8 python -m repro.launch.select \
        --encoding grid --mesh-obs 4 --mesh-feat 2

    # Out-of-core: stream a memmapped .npy that never fits in device
    # memory, 65536 observations per block:
    PYTHONPATH=src python -m repro.launch.select \
        --input data.npy --target target.npy --block-obs 65536 --prefetch 2

    # Quotient-form mRMR (MIQ) instead of the paper's difference form;
    # any registered criterion runs on any engine, streamed or in-memory:
    PYTHONPATH=src python -m repro.launch.select --criterion miq

    # Class-conditioned objectives: JMI and CMIM fold I(x_k; x_j | y)
    # against the marginal redundancy — same pass count as mid, the
    # redundancy sweep just carries a class axis:
    PYTHONPATH=src python -m repro.launch.select --criterion jmi
    PYTHONPATH=src python -m repro.launch.select --criterion cmim

    # Parquet input (pyarrow): row batches decode block-by-block from the
    # file's row groups; target = last column, dtypes from the schema:
    PYTHONPATH=src python -m repro.launch.select \
        --input data.parquet --select 10 --block-obs 65536

    # Wide regime: stream with feature-sharded statistics over 2 devices
    # (the per-pair statistics state splits across the model axis):
    PYTHONPATH=src REPRO_DEVICES=2 python -m repro.launch.select \
        --input wide.npy --target target.npy --block-obs 4096 --mesh-feat 2

    # Continuous data with exact discrete MI: one streaming quantile-sketch
    # pass cuts 32 equal-frequency bins per feature, then blocks encode to
    # int codes on the fly (device-side, fused with the contingency sums):
    PYTHONPATH=src python -m repro.launch.select \
        --input floats.csv --bins 32 --block-obs 65536

    # Cut the L-pass I/O tax: speculate 8 redundancy candidates per pass
    # (select=32 drops from 31 redundancy passes toward 4-5) and spill
    # parsed/encoded blocks so passes 2..L replay memmapped chunks —
    # selections stay bitwise-identical to the plain streaming engine:
    PYTHONPATH=src python -m repro.launch.select \
        --input data.csv --select 32 --batch-candidates 8 \
        --spill-dir /tmp/spill --readahead 2

Inputs: ``--input data.npz`` (arrays ``X`` rows=observations, ``y``) loads
in-memory; ``--input data.npy`` (+ ``--target target.npy``) memmaps and
streams block-by-block through the ``streaming`` engine; ``--input
data.csv`` streams a CSV (target = last column); ``--input data.parquet``
streams Parquet row batches (pyarrow; target = last column); default is
the paper's CorrAL-style synthetic generator.  The whole distribution strategy goes
through :class:`repro.MRMRSelector`: encoding ``auto`` applies the paper's
§III aspect-ratio rule (streamed sources always run the streaming engine),
explicit encodings shard over whatever devices jax exposes, and ``grid``
places a 2-D (observation × feature) mesh — shape from
``--mesh-obs``/``--mesh-feat`` or auto-factored.  The same mesh flags
apply to streamed inputs: tall sources shard blocks over the observation
axis, wide sources shard blocks and statistics over the feature axis, and
a mesh with both axes streams on the 2-D grid.  ``REPRO_DEVICES=N``
forces N simulated host devices (set before jax initialises).
"""

from __future__ import annotations

import os

_DEVICES = int(os.environ.get("REPRO_DEVICES", "0"))
if _DEVICES > 1:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_DEVICES} "
        + os.environ.get("XLA_FLAGS", "")
    )

import argparse
import json
import time

import jax
import numpy as np

from repro.core.criteria import available_criteria, resolve_criterion
from repro.core.scores import MIScore, PearsonMIScore
from repro.core.selector import (
    MRMRSelector,
    available_encodings,
    check_num_select,
)
from repro.data.sources import CSVSource, NpySource
from repro.data.synthetic import corral_dataset_np
from repro.dist.meshes import make_mesh


def _load_input(args):
    """-> (X, y, source): arrays for in-memory fits OR a DataSource."""
    path = args.input
    if path is None:
        X, y = corral_dataset_np(args.rows, args.cols, seed=args.seed)
        return X, y, None
    if path.endswith(".npz"):
        data = np.load(path)
        return data["X"], data["y"], None
    if path.endswith(".npy"):
        if not args.target:
            raise SystemExit("--target <y.npy> is required with a .npy input")
        return None, None, NpySource(path, args.target)
    if path.endswith(".csv"):
        # Binned fits read float columns (the sketch pass discretises);
        # plain MI expects pre-discretised integer categories.
        dtype = np.int32 if args.score == "mi" and not args.bins else np.float32
        return None, None, CSVSource(path, dtype=dtype)
    if path.endswith(".parquet"):
        from repro.data.sources import ParquetSource  # soft pyarrow gate

        try:
            # Block dtype comes from the file's schema (all-integral
            # columns -> int32, else float32); target = last column.
            return None, None, ParquetSource(path)
        except ImportError as e:
            raise SystemExit(str(e)) from None
    raise SystemExit(
        f"unsupported --input {path!r} (.npz, .npy, .csv or .parquet)"
    )


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--input", default=None,
                    help=".npz with X,y | .npy matrix (see --target) | .csv")
    ap.add_argument("--target", default=None,
                    help="target-vector .npy for a .npy --input")
    ap.add_argument("--rows", type=int, default=100_000)
    ap.add_argument("--cols", type=int, default=1000)
    ap.add_argument("--select", type=int, default=10)
    ap.add_argument("--encoding", default="auto",
                    choices=("auto",) + available_encodings())
    ap.add_argument("--criterion", default="mid",
                    help="greedy objective: mid (paper's difference form), "
                         "miq (quotient), maxrel (relevance only; streamed "
                         "fits then need a single pass of I/O), jmi / cmim "
                         "(class-conditioned redundancy), or any name added "
                         "via register_criterion")
    ap.add_argument("--mesh-obs", type=int, default=0,
                    help="observation-axis mesh extent (grid; 0 = auto)")
    ap.add_argument("--mesh-feat", type=int, default=0,
                    help="feature-axis mesh extent (grid; 0 = auto)")
    ap.add_argument("--score", default="mi", choices=["mi", "pearson"])
    ap.add_argument("--num-values", type=int, default=2)
    ap.add_argument("--num-classes", type=int, default=2)
    ap.add_argument("--incremental", type=int, default=1)
    ap.add_argument("--block", type=int, default=64)
    ap.add_argument("--block-obs", type=int, default=65536,
                    help="observations per streamed block (DataSource inputs)")
    ap.add_argument("--prefetch", default="auto",
                    help="streamed blocks placed ahead of device "
                         "accumulation (0 = synchronous placer; 'auto' "
                         "= off on CPU, 2 elsewhere)")
    ap.add_argument("--batch-candidates", type=int, default=1,
                    help="redundancy vectors speculated per streamed pass "
                         "(q): cuts select=L from L-1 redundancy passes "
                         "toward ceil((L-1)/q); selections are identical")
    ap.add_argument("--spill-dir", default=None,
                    help="encoded-block spill cache directory: pass 1 "
                         "spills parsed/encoded blocks as .npy chunks, "
                         "passes 2..L replay them memmapped")
    ap.add_argument("--spill-budget-mb", type=int, default=0,
                    help="LRU byte budget for --spill-dir in MiB (0 = "
                         "unbounded)")
    ap.add_argument("--readahead", type=int, default=0,
                    help="raw blocks read ahead across pass boundaries "
                         "(0 = off; supersedes --prefetch)")
    ap.add_argument("--bins", type=int, default=0,
                    help="quantile-discretise continuous features into this "
                         "many equal-frequency bins (one streaming sketch "
                         "pass) and select with exact discrete MI; 0 = off")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--output", default=None,
                    help="write the full MRMRResult (selected, gains, "
                         "relevance, provenance) as JSON to this path")
    args = ap.parse_args(argv)

    # Validate the criterion name here — free-form (any registered name,
    # including user plugins imported via sitecustomize) beats a frozen
    # argparse choices list, but an unknown name should exit with the
    # registry, not escape as a traceback out of fit().
    try:
        resolve_criterion(args.criterion)
    except ValueError:
        raise SystemExit(
            f"--criterion {args.criterion!r} is not registered; "
            f"available: {', '.join(available_criteria())} "
            "(register_criterion adds more)"
        ) from None

    X, y, source = _load_input(args)

    # Fail the bounds check here, before any engine work: the selector
    # raises the same ValueError, but a CLI user should see a one-line
    # message, not a traceback out of fit().
    n_features = source.num_features if source is not None else X.shape[1]
    try:
        check_num_select(args.select, n_features)
    except ValueError as e:
        raise SystemExit(f"--select invalid: {e}") from None

    if args.bins:
        # Auto-resolve: the selector wraps continuous inputs in a
        # BinnedSource and sizes the MI score from the bin config.
        score = None
        if X is not None:
            X = X.astype(np.float32)
    elif args.score == "mi":
        score = MIScore(num_values=args.num_values,
                        num_classes=args.num_classes)
    else:
        score = PearsonMIScore()
        if X is not None:
            X = X.astype(np.float32)

    mesh = None
    if args.mesh_obs or args.mesh_feat:
        n_dev = len(jax.devices())
        obs = args.mesh_obs or max(n_dev // max(args.mesh_feat, 1), 1)
        feat = args.mesh_feat or max(n_dev // obs, 1)
        mesh = make_mesh((obs, feat), ("data", "model"))

    prefetch = args.prefetch if args.prefetch == "auto" else int(args.prefetch)
    t0 = time.time()
    sel = MRMRSelector(
        num_select=args.select, score=score, criterion=args.criterion,
        encoding=args.encoding, mesh=mesh,
        incremental=bool(args.incremental), block=args.block,
        block_obs=args.block_obs, prefetch=prefetch,
        bins=args.bins or None,
        batch_candidates=args.batch_candidates,
        spill_dir=args.spill_dir,
        spill_budget_bytes=args.spill_budget_mb * 2**20 or None,
        readahead=args.readahead,
    )
    sel = sel.fit(source) if source is not None else sel.fit(X, y)
    plan = sel.plan_
    out = {
        "encoding": plan.encoding,
        "criterion": sel.result_.criterion,
        "mesh": dict(zip(plan.mesh_axes, plan.mesh_shape)),
        "devices": len(jax.devices()),
        "incremental": plan.incremental,
        "selected": sel.selected_.tolist(),
        "gains": [round(float(g), 5) for g in sel.gains_],
        "seconds": round(time.time() - t0, 3),
    }
    if plan.encoding == "streaming":
        out["block_obs"] = plan.block_obs  # effective (rounded) size
        out["prefetch"] = plan.prefetch   # resolved ("auto" -> int)
        if plan.batch_candidates > 1:
            out["batch_candidates"] = plan.batch_candidates
        if plan.spill_dir is not None:
            out["spill_dir"] = plan.spill_dir
        if plan.readahead:
            out["readahead"] = plan.readahead
        if sel.result_.io is not None:
            out["io"] = sel.result_.io
    if plan.bins is not None:
        out["bins"] = plan.bins
    if args.output:
        # The same MRMRResult.to_json payload the service's result cache
        # persists — MRMRResult.from_json round-trips it.
        with open(args.output, "w") as f:
            f.write(sel.result_.to_json())
        out["output"] = args.output
    print(json.dumps(out))
    return out


if __name__ == "__main__":
    main()
