"""Feature-selection driver — the paper's job as a production CLI.

    PYTHONPATH=src python -m repro.launch.select --rows 100000 --cols 1000 \
        --select 10 --encoding conventional

Input: ``--input data.npz`` with arrays ``X`` (rows=observations) and ``y``,
or the paper's CorrAL-style synthetic generator by default.  The device
mesh is whatever jax exposes (all local devices): observations sharded for
the conventional encoding, features for the alternative encoding — the same
axes the LM workloads use for DP and TP.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.mrmr import make_alternative_fn, make_conventional_fn
from repro.core.scores import MIScore, PearsonMIScore
from repro.data.synthetic import corral_dataset_np
from repro.dist.meshes import make_mesh


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--input", default=None, help="npz with X (M,N), y (M,)")
    ap.add_argument("--rows", type=int, default=100_000)
    ap.add_argument("--cols", type=int, default=1000)
    ap.add_argument("--select", type=int, default=10)
    ap.add_argument("--encoding", default="auto",
                    choices=["auto", "conventional", "alternative"])
    ap.add_argument("--score", default="mi", choices=["mi", "pearson"])
    ap.add_argument("--num-values", type=int, default=2)
    ap.add_argument("--num-classes", type=int, default=2)
    ap.add_argument("--incremental", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.input:
        data = np.load(args.input)
        X, y = data["X"], data["y"]
    else:
        X, y = corral_dataset_np(args.rows, args.cols, seed=args.seed)
    m, n = X.shape
    enc = args.encoding
    if enc == "auto":  # paper §III: layout follows the aspect ratio
        enc = "conventional" if m >= n else "alternative"

    n_dev = len(jax.devices())
    t0 = time.time()
    if enc == "conventional":
        mesh = make_mesh((n_dev,), ("data",)) if n_dev > 1 else None
        pad = (-m) % max(n_dev, 1)
        if pad:
            X = np.concatenate([X, np.full((pad, n), args.num_values, X.dtype)])
            y = np.concatenate([y, np.full((pad,), args.num_classes, y.dtype)])
        score = MIScore(num_values=args.num_values, num_classes=args.num_classes)
        fn = make_conventional_fn(
            args.select, score, mesh=mesh, incremental=bool(args.incremental)
        )
        if mesh is not None:
            X = jax.device_put(X, NamedSharding(mesh, P("data", None)))
            y = jax.device_put(y, NamedSharding(mesh, P("data")))
        sel, gains = fn(X, y)
    else:
        Xr = np.ascontiguousarray(X.T)
        mesh = make_mesh((n_dev,), ("model",)) if n_dev > 1 else None
        pad = (-n) % max(n_dev, 1)
        if pad:
            Xr = np.concatenate([Xr, np.zeros((pad, m), Xr.dtype)])
        if args.score == "mi":
            score = MIScore(
                num_values=args.num_values, num_classes=args.num_classes
            )
        else:
            score = PearsonMIScore()
            Xr = Xr.astype(np.float32)
            y = y.astype(np.float32)
        fn = make_alternative_fn(
            args.select, score, n, mesh=mesh,
            incremental=bool(args.incremental),
        )
        if mesh is not None:
            Xr = jax.device_put(Xr, NamedSharding(mesh, P("model", None)))
            y = jax.device_put(y, NamedSharding(mesh, P()))
        sel, gains = fn(Xr, y)
    out = {
        "encoding": enc,
        "devices": n_dev,
        "selected": np.asarray(sel).tolist(),
        "gains": [round(float(g), 5) for g in np.asarray(gains)],
        "seconds": round(time.time() - t0, 3),
    }
    print(json.dumps(out))
    return out


if __name__ == "__main__":
    main()
