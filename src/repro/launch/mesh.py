"""Production mesh definitions.

``make_production_mesh`` is a *function* (never a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``--xla_force_host_platform_device_count=512`` before first jax init and
everything else must see the single real CPU device.

Mesh topology (TPU v5e-class):

* single pod: ``(data=16, model=16)`` — 256 chips, 2-D ICI torus.
* multi pod:  ``(pod=2, data=16, model=16)`` — 512 chips; the leading
  ``pod`` axis crosses the DCN boundary and composes with ``data`` for
  data parallelism (gradient all-reduce spans ``('pod','data')``).
"""

from __future__ import annotations

from repro.dist.meshes import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU multi-device tests (8 forced host devices)."""
    return make_mesh((n_data, n_model), ("data", "model"))
