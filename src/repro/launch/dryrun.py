import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# NOTE (§Perf iteration 4): the CPU backend float-normalizes bf16 compute to
# f32 and no XLA flag disables it (--xla_allow_excess_precision=false was
# tried: zero effect — the normalization pass, not excess precision, is
# responsible).  The TPU-width correction therefore lives in
# repro.analysis.hlo_analysis.analyze_hlo(bf16_model=True).

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: for each cell we AOT-lower ``train_step`` / ``prefill`` /
``serve_step`` against ShapeDtypeStruct inputs (no allocation), compile for
the production mesh, and record

* ``memory_analysis()``  — fits-in-HBM evidence,
* ``cost_analysis()``    — per-device FLOPs / bytes for §Roofline,
* collective operand/wire bytes parsed from the partitioned HLO
  (``repro.analysis.hlo_analysis``), scan trip counts unrolled.

Results are cached as JSON under ``results/dryrun/<mesh>/<arch>__<shape>.json``
so the matrix re-runs incrementally; EXPERIMENTS.md tables are generated
from these files by ``benchmarks/report.py``.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo_analysis import analyze_hlo
from repro.analysis.roofline import model_flops, param_counts, roofline_terms
from repro.configs import REGISTRY, SHAPES, get_config, get_shape, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import (
    make_train_state_specs,
    make_train_step,
    train_state_shapes,
)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _apply_overrides(cfg, overrides: dict):
    if not overrides:
        return cfg
    import dataclasses

    typed = {}
    for k, v in overrides.items():
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            typed[k] = v in ("1", "true", "True")
        elif isinstance(cur, int):
            typed[k] = int(v)
        elif isinstance(cur, float):
            typed[k] = float(v)
        else:
            typed[k] = v
    return dataclasses.replace(cfg, **typed)


def build_cell(arch: str, shape_name: str, mesh, overrides: dict | None = None):
    """-> (fn, example_args, in_shardings, donate_argnums, step_kind)."""
    cfg = _apply_overrides(get_config(arch), overrides or {})
    shape = get_shape(shape_name)
    bundle = build_model(cfg, mesh)
    batch_sds = bundle.input_specs(shape)
    batch_shardings = _named(mesh, bundle.input_shardings(shape))

    if shape.kind == "train":
        opt_cfg = AdamWConfig(moment_dtype=cfg.optimizer_moment_dtype)
        step = make_train_step(bundle, opt_cfg)
        state_sds = train_state_shapes(bundle, opt_cfg)
        state_shardings = _named(mesh, make_train_state_specs(bundle))
        return (
            step,
            (state_sds, batch_sds),
            (state_shardings, batch_shardings),
            (0,),
            "train_step",
            bundle,
        )
    # Serve cells lower with f32 params on purpose: the CPU backend computes
    # in f32 either way, and the analyzer's bf16 width correction counts the
    # f32 weight reads at 2 bytes — i.e. the dry-run models bf16-stored
    # serving weights (cfg.serve_params_dtype, used by the real engine)
    # without the spurious convert temps a bf16 SDS causes on CPU (§Perf B1).
    params_sds = bundle.shapes()
    params_shardings = bundle.shardings()
    if shape.kind == "prefill":
        return (
            bundle.prefill,
            (params_sds, batch_sds),
            (params_shardings, batch_shardings),
            (),
            "prefill",
            bundle,
        )
    return (
        bundle.serve_step,
        (params_sds, batch_sds),
        (params_shardings, batch_shardings),
        (1,),  # donate the cache-carrying batch
        "serve_step",
        bundle,
    )


def _memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        out["total_hbm_bytes"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
        )
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             force: bool = False, keep_hlo: bool = False,
             overrides: dict | None = None, tag: str = "") -> dict:
    name = f"{arch}__{shape_name}" + (f"__{tag}" if tag else "")
    path = os.path.join(out_dir, mesh_kind, f"{name}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, reason = shape_applicable(cfg, shape)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "status": "skipped" if not ok else "pending",
    }
    if not ok:
        rec["skip_reason"] = reason
        _save(path, rec)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.size
    if overrides:
        rec["overrides"] = dict(overrides)
    try:
        fn, args, in_sh, donate, step_kind, bundle = build_cell(
            arch, shape_name, mesh, overrides
        )
        jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
        t0 = time.time()
        lowered = jitted.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        cost = dict(compiled.cost_analysis() or {})
        hlo = compiled.as_text()
        bf16 = jnp.dtype(bundle.cfg.dtype) == jnp.bfloat16
        hc = analyze_hlo(hlo, bf16_model=bf16)  # trip-aware, TPU-width
        hc_raw = analyze_hlo(hlo, bf16_model=False) if bf16 else hc
        coll = hc["collectives"]
        mem = _memory_dict(compiled)
        n_total, n_active = param_counts(bundle.cfg)
        mf = model_flops(bundle.cfg, shape)
        roof = roofline_terms(
            flops_per_device=float(hc["flops"]),
            bytes_per_device=float(hc["bytes"]),
            collective_operand_bytes=float(coll["operand_bytes"]),
            n_devices=n_dev,
            model_flops_global=mf,
        )
        rec.update(
            status="ok",
            step_kind=step_kind,
            n_devices=n_dev,
            mesh_shape={k: int(v) for k, v in mesh.shape.items()},
            params_total=float(bundle.num_params()),
            params_matmul_total=float(n_total),
            params_matmul_active=float(n_active),
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            cost_xla={
                k: float(v)
                for k, v in cost.items()
                if isinstance(v, (int, float)) and k in ("flops", "bytes accessed")
            },
            cost={"flops": float(hc["flops"]), "bytes": float(hc["bytes"])},
            cost_raw_f32={
                "bytes": float(hc_raw["bytes"]),
                "collective_operand_bytes": float(
                    hc_raw["collectives"]["operand_bytes"]
                ),
            },
            memory=mem,
            collectives=coll,
            roofline=roof,
            hlo_bytes=len(hlo),
        )
        if keep_hlo:
            hp = path[:-5] + ".hlo.txt"
            os.makedirs(os.path.dirname(hp), exist_ok=True)
            with open(hp, "w") as f:
                f.write(hlo)
    except Exception as e:  # a failing cell is a bug; record it loudly
        rec.update(status="error", error=repr(e), trace=traceback.format_exc())
    _save(path, rec)
    return rec


def _save(path: str, rec: dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def _summary_line(rec: dict) -> str:
    tag = f"{rec['arch']:<24s} {rec['shape']:<12s} {rec['mesh']:<6s}"
    if rec["status"] == "skipped":
        return f"{tag} SKIP  ({rec['skip_reason'][:60]}...)"
    if rec["status"] == "error":
        return f"{tag} ERROR {rec['error'][:90]}"
    r = rec["roofline"]
    mem = rec.get("memory", {}).get("total_hbm_bytes")
    memgb = f"{mem/2**30:7.2f}GiB" if mem else "      n/a"
    return (
        f"{tag} ok    comp={r['compute_s']:9.3e}s mem={r['memory_s']:9.3e}s "
        f"coll={r['collective_s']:9.3e}s dom={r['dominant'][:-2]:<10s} "
        f"hbm/dev={memgb} useful={r['useful_flops_ratio']:5.2f} "
        f"compile={rec['compile_s']:.0f}s"
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="arch id or 'all'")
    ap.add_argument("--shape", default=None, help="shape id or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="full matrix")
    ap.add_argument("--force", action="store_true", help="ignore cache")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    ap.add_argument("--set", action="append", default=[], dest="overrides",
                    help="config override key=value (repeatable; §Perf)")
    ap.add_argument("--tag", default="", help="suffix for the result file")
    args = ap.parse_args()
    overrides = dict(kv.split("=", 1) for kv in args.overrides)

    archs = sorted(REGISTRY) if (args.all or args.arch in (None, "all")) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or args.shape in (None, "all")) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_bad = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape_name in shapes:
                rec = run_cell(
                    arch, shape_name, mesh_kind, args.out,
                    force=args.force, keep_hlo=args.keep_hlo,
                    overrides=overrides, tag=args.tag,
                )
                print(_summary_line(rec), flush=True)
                n_bad += rec["status"] == "error"
    if n_bad:
        raise SystemExit(f"{n_bad} cells failed")


if __name__ == "__main__":
    main()
