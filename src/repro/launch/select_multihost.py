"""Multi-host feature selection — one process per shard, loopback or real.

Spawn mode (the default) stands up an N-process ``jax.distributed``
cluster on this machine — a free loopback coordinator port, N child
copies of this script, gloo CPU collectives — runs the SAME selection in
every process with ``MRMRSelector(hosts=N)``, asserts every host
committed the identical picks/gains, and prints one merged JSON report:

    # 2-process map-reduce over a streamed .npy (each host reads only
    # its shard of the file):
    PYTHONPATH=src python -m repro.launch.select_multihost \\
        --num-processes 2 --input data.npy --target y.npy --select 10

    # Synthetic CorrAL-style data, wide regime, spill + batching:
    PYTHONPATH=src python -m repro.launch.select_multihost \\
        --num-processes 2 --rows 200 --cols 2048 --select 8 \\
        --batch-candidates 4 --spill-dir /tmp/spill

Worker mode (``--process-id`` set, as spawn mode sets it for its
children) joins the coordinator, fits, and prints this host's result —
which is how a REAL cluster runs it: one invocation per machine with
``--coordinator host0:port --num-processes N --process-id i`` (or the
``REPRO_COORDINATOR`` / ``REPRO_NUM_PROCESSES`` / ``REPRO_PROCESS_ID``
environment variables).

Every host returns the identical selection — the per-pass reduce is a
collective psum of exact integer statistics, so there is no designated
master to gather from; spawn mode's cross-host assertion is checking a
guarantee, not electing a winner.
"""

from __future__ import annotations

import argparse
import json
import os
import socket
import subprocess
import sys
import time

_MARK = "MHRESULT:"


def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--coordinator", default=None,
                    help="host:port of process 0's coordinator (spawn mode "
                         "picks a free loopback port when omitted)")
    ap.add_argument("--num-processes", type=int, default=2)
    ap.add_argument("--process-id", type=int, default=None,
                    help="this process's id 0..N-1; omitting it runs spawn "
                         "mode, which launches all N workers locally")
    ap.add_argument("--input", default=None,
                    help=".npy matrix (see --target), .csv or .parquet; "
                         "default = synthetic CorrAL-style data")
    ap.add_argument("--target", default=None,
                    help="target-vector .npy for a .npy --input")
    ap.add_argument("--rows", type=int, default=6000)
    ap.add_argument("--cols", type=int, default=24)
    ap.add_argument("--select", type=int, default=4)
    ap.add_argument("--criterion", default="mid")
    ap.add_argument("--score", default="mi", choices=["mi", "pearson"])
    ap.add_argument("--num-values", type=int, default=2)
    ap.add_argument("--num-classes", type=int, default=2)
    ap.add_argument("--block-obs", type=int, default=65536)
    ap.add_argument("--batch-candidates", type=int, default=1)
    ap.add_argument("--spill-dir", default=None)
    ap.add_argument("--readahead", type=int, default=0)
    ap.add_argument("--bins", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    return ap


def _load_source(args):
    """The worker's DataSource — every host builds the IDENTICAL source
    (same paths, same synthetic seed); the HostShardSpec decides which
    rows/columns of it this host actually reads."""
    import numpy as np

    from repro.data.sources import ArraySource, CSVSource, NpySource

    if args.input is None:
        from repro.data.synthetic import corral_dataset_np

        X, y = corral_dataset_np(args.rows, args.cols, seed=args.seed)
        if args.score == "pearson" or args.bins:
            X = X.astype(np.float32)
        return ArraySource(X, y)
    if args.input.endswith(".npy"):
        if not args.target:
            raise SystemExit("--target <y.npy> is required with a .npy input")
        return NpySource(args.input, args.target)
    if args.input.endswith(".csv"):
        dtype = np.int32 if args.score == "mi" and not args.bins else np.float32
        return CSVSource(args.input, dtype=dtype)
    if args.input.endswith(".parquet"):
        from repro.data.sources import ParquetSource

        return ParquetSource(args.input)
    raise SystemExit(f"unsupported --input {args.input!r}")


def _run_worker(args) -> dict:
    # Join the cluster BEFORE any jax computation: backend init locks the
    # device set, and the gloo knob must land first.
    from repro.dist.multihost import init_multihost

    ctx = init_multihost(
        coordinator=args.coordinator,
        num_processes=args.num_processes,
        process_id=args.process_id,
    )

    from repro.core.scores import MIScore, PearsonMIScore
    from repro.core.selector import MRMRSelector

    if args.bins:
        score = None
    elif args.score == "mi":
        score = MIScore(
            num_values=args.num_values, num_classes=args.num_classes
        )
    else:
        score = PearsonMIScore()
    source = _load_source(args)
    t0 = time.time()
    sel = MRMRSelector(
        num_select=args.select,
        score=score,
        criterion=args.criterion,
        block_obs=args.block_obs,
        batch_candidates=args.batch_candidates,
        spill_dir=args.spill_dir,
        readahead=args.readahead,
        bins=args.bins or None,
        hosts="auto",
    ).fit(source)
    return dict(
        process_id=ctx.process_id,
        num_processes=ctx.num_processes,
        selected=sel.selected_.tolist(),
        gains=[float(g) for g in sel.gains_],
        criterion=sel.result_.criterion,
        io=sel.result_.io,
        seconds=round(time.time() - t0, 3),
    )


def _spawn(args, argv) -> dict:
    coordinator = args.coordinator or f"127.0.0.1:{_free_port()}"
    procs = []
    for pid in range(args.num_processes):
        env = dict(os.environ)
        # Children resolve their place from argv, not env — scrub any
        # inherited multihost env so a nested launch can't cross wires.
        for k in ("REPRO_COORDINATOR", "REPRO_NUM_PROCESSES",
                  "REPRO_PROCESS_ID"):
            env.pop(k, None)
        procs.append(subprocess.Popen(
            [sys.executable, "-m", "repro.launch.select_multihost",
             *argv, "--coordinator", coordinator, "--process-id", str(pid)],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
            text=True,
        ))
    results = {}
    failed = []
    for pid, p in enumerate(procs):
        out, err = p.communicate(timeout=1800)
        payload = next(
            (l[len(_MARK):] for l in out.splitlines() if l.startswith(_MARK)),
            None,
        )
        if p.returncode != 0 or payload is None:
            failed.append(
                f"--- worker {pid} (rc={p.returncode}) ---\n"
                f"{out[-2000:]}\n{err[-2000:]}"
            )
            continue
        results[pid] = json.loads(payload)
    if failed:
        raise SystemExit("\n".join(failed))
    first = results[0]
    for pid, r in results.items():
        if r["selected"] != first["selected"] or r["gains"] != first["gains"]:
            raise SystemExit(
                f"host {pid} disagrees with host 0:\n"
                f"  host 0: {first['selected']} {first['gains']}\n"
                f"  host {pid}: {r['selected']} {r['gains']}"
            )
    merged = dict(
        num_processes=args.num_processes,
        coordinator=coordinator,
        selected=first["selected"],
        gains=first["gains"],
        criterion=first["criterion"],
        hosts=first["io"].get("hosts"),
        per_host_io={
            pid: {k: r["io"][k] for k in ("passes", "blocks_read",
                                          "bytes_read", "state_bytes")}
            for pid, r in sorted(results.items())
        },
        seconds=max(r["seconds"] for r in results.values()),
    )
    print(json.dumps(merged))
    return merged


def main(argv=None) -> dict:
    argv = list(sys.argv[1:] if argv is None else argv)
    args = _parser().parse_args(argv)
    if args.num_processes < 1:
        raise SystemExit("--num-processes must be >= 1")
    if args.process_id is None and os.environ.get("REPRO_PROCESS_ID"):
        # Real-cluster launchers configure workers purely via env vars.
        args.process_id = int(os.environ["REPRO_PROCESS_ID"])
        args.coordinator = args.coordinator or os.environ.get(
            "REPRO_COORDINATOR"
        )
        args.num_processes = int(os.environ.get(
            "REPRO_NUM_PROCESSES", args.num_processes
        ))
    if args.process_id is not None:
        out = _run_worker(args)
        print(_MARK + json.dumps(out))
        return out
    return _spawn(args, argv)


if __name__ == "__main__":
    main()
