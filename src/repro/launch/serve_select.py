"""Selection-service driver — submit / poll / stats as one JSON report.

    # Demo on the paper's synthetic generator: first fit runs the engine,
    # the identical resubmission is a content-addressed cache hit, the
    # distinct fit runs again:
    PYTHONPATH=src python -m repro.launch.serve_select \
        --source corral:20000x64 --select 5 --repeat 2 --distinct-select 3

    # Real files (memmapped .npy pair or CSV), persistent result cache:
    PYTHONPATH=src python -m repro.launch.serve_select \
        --source X.npy::y.npy --select 10 --cache-dir /tmp/selcache

Each ``--repeat`` beyond the first resubmits the *identical* request
after the first completes — a cache hit with zero engine or I/O passes;
``--distinct-select K`` adds one request with a different ``num_select``
(a genuine second engine run).  The report is a single JSON object:
``jobs`` (lifecycle snapshot + selected ids per submission) and
``stats`` (queue depth/capacity/rejections, coalescing and cache
hit/miss/eviction counters) — the same dict ``SelectionService.stats()``
serves in-process.  ``REPRO_DEVICES=N`` forces N simulated host devices.
"""

from __future__ import annotations

import os

_DEVICES = int(os.environ.get("REPRO_DEVICES", "0"))
if _DEVICES > 1:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_DEVICES} "
        + os.environ.get("XLA_FLAGS", "")
    )

import argparse
import json

from repro.core.criteria import available_criteria
from repro.serve.selection import SelectionService


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--source", default="corral:20000x64",
                    help="'X.npy::y.npy' | 'data.csv' | 'corral:ROWSxCOLS"
                         "[:SEED]'")
    ap.add_argument("--select", type=int, default=5)
    ap.add_argument("--criterion", default="mid",
                    choices=available_criteria())
    ap.add_argument("--repeat", type=int, default=2,
                    help="total identical submissions (>=1); each after "
                         "the first should be a cache hit")
    ap.add_argument("--distinct-select", type=int, default=0,
                    help="also submit one fit with this num_select "
                         "(0 = off); a distinct job, never a cache hit")
    ap.add_argument("--block-obs", type=int, default=65536)
    ap.add_argument("--prefetch", type=int, default=2)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--queue-cap", type=int, default=32)
    ap.add_argument("--cache-cap", type=int, default=128)
    ap.add_argument("--cache-dir", default=None,
                    help="persist cached results as JSON in this directory")
    args = ap.parse_args(argv)
    if args.repeat < 1:
        raise SystemExit(f"--repeat must be >= 1, got {args.repeat}")

    knobs = dict(
        criterion=args.criterion, block_obs=args.block_obs,
        prefetch=args.prefetch,
    )
    job_ids = []
    with SelectionService(
        workers=args.workers, queue_capacity=args.queue_cap,
        cache_capacity=args.cache_cap, cache_dir=args.cache_dir,
    ) as svc:
        first = svc.submit(args.source, num_select=args.select, **knobs)
        job_ids.append(first)
        svc.result(first)  # wait, so the resubmissions exercise the cache
        for _ in range(args.repeat - 1):
            job_ids.append(
                svc.submit(args.source, num_select=args.select, **knobs)
            )
        if args.distinct_select:
            job_ids.append(
                svc.submit(
                    args.source, num_select=args.distinct_select, **knobs
                )
            )
        jobs = []
        for jid in job_ids:
            result = svc.result(jid)
            info = svc.poll(jid).to_dict()
            info["selected"] = [int(v) for v in result.selected]
            jobs.append(info)
        out = dict(jobs=jobs, stats=svc.stats())
    print(json.dumps(out))
    return out


if __name__ == "__main__":
    main()
