"""Production-style training driver.

Wires the full substrate: mesh + sharded TrainState, scan/remat model,
AdamW, deterministic resumable data pipeline, async sharded checkpoints,
step watchdog (straggler alarm) and the crash-restart loop.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --preset smoke --steps 50 --global-batch 8 --seq-len 256 \
        --ckpt-dir /tmp/ckpt --resume auto

``--fail-at-step N`` injects a crash (fault-tolerance demo: the restart
driver restores the latest checkpoint and the run completes bit-identically
to an uninterrupted one — tested in tests/test_resilience.py).
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, smoke_config
from repro.data.pipeline import ShardedDataPipeline
from repro.dist.meshes import make_mesh
from repro.models.model import build_model
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.resilience import StepWatchdog, run_with_restarts
from repro.train.optimizer import AdamWConfig, warmup_cosine
from repro.train.train_step import (
    TrainState,
    make_train_state_specs,
    make_train_step,
    train_state_shapes,
)

logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
log = logging.getLogger("repro.train")


def build_local_mesh(model_parallel: int = 1):
    n = len(jax.devices())
    assert n % model_parallel == 0, (n, model_parallel)
    return make_mesh((n // model_parallel, model_parallel), ("data", "model"))


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--watchdog-s", type=float, default=600.0)
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="inject a crash once at this step (FT demo)")
    ap.add_argument("--seed", type=int, default=0)
    # quick model-surgery overrides (e.g. the ~100M example config)
    for k in ("num-layers", "d-model", "num-heads", "num-kv-heads", "d-ff",
              "vocab-size"):
        ap.add_argument(f"--{k}", type=int, default=None)
    return ap.parse_args(argv)


def resolve_config(args):
    cfg = get_config(args.arch) if args.preset == "full" else smoke_config(args.arch)
    upd = {}
    for k in ("num_layers", "d_model", "num_heads", "num_kv_heads", "d_ff",
              "vocab_size"):
        v = getattr(args, k)
        if v is not None:
            upd[k] = v
    if args.microbatches > 1:
        upd["microbatches"] = args.microbatches
    if upd:
        cfg = dataclasses.replace(cfg, **upd)
    return cfg


def main(argv=None) -> dict:
    args = parse_args(argv)
    cfg = resolve_config(args)
    mesh = build_local_mesh(args.model_parallel)
    bundle = build_model(cfg, mesh)
    log.info("arch=%s params=%.2fM mesh=%s", cfg.name,
             bundle.num_params() / 1e6, dict(mesh.shape))

    opt_cfg = AdamWConfig(
        learning_rate=warmup_cosine(args.lr, args.warmup, args.steps),
        moment_dtype=cfg.optimizer_moment_dtype,
    )
    step_fn = jax.jit(make_train_step(bundle, opt_cfg), donate_argnums=0)
    pipe = ShardedDataPipeline(
        mesh=mesh, global_batch=args.global_batch, seq_len=args.seq_len,
        vocab=cfg.vocab_size, seed=args.seed,
    )
    ckpt = CheckpointManager(args.ckpt_dir, keep=3)
    specs = make_train_state_specs(bundle)
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    if args.resume == "none":
        for s in ckpt.all_steps():
            pass  # keep old checkpoints; cold-start regardless
    failed_once = {"done": False}
    metrics_out: dict = {}

    def make_state():
        key = jax.random.PRNGKey(args.seed)
        params = jax.jit(
            bundle.init, out_shardings=shardings.params
        )(key)
        return TrainState.create(params, opt_cfg)

    def state_like():
        return train_state_shapes(bundle, opt_cfg)

    def run_from(state: TrainState):
        start = int(state.step)
        t_tok = args.global_batch * args.seq_len
        with StepWatchdog(timeout_s=args.watchdog_s) as dog:
            t0 = time.time()
            for step in range(start, args.steps):
                if step == args.fail_at_step and not failed_once["done"]:
                    failed_once["done"] = True
                    raise RuntimeError(f"injected failure at step {step}")
                state, metrics = step_fn(state, pipe.batch_at(step))
                dog.beat(step)
                if (step + 1) % args.log_every == 0 or step + 1 == args.steps:
                    loss = float(metrics["loss"])
                    dt = (time.time() - t0) / max(step + 1 - start, 1)
                    log.info("step %d loss %.4f  %.2fs/step  %.0f tok/s",
                             step + 1, loss, dt, t_tok / dt)
                    metrics_out.update(step=step + 1, loss=loss)
                if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
                    ckpt.save(step + 1, state)
        ckpt.wait()
        return state

    state = run_with_restarts(
        make_state, run_from, ckpt=ckpt, state_like_fn=state_like,
        shardings=shardings, max_restarts=args.max_restarts,
    )
    log.info("done: step=%d loss=%.4f", metrics_out.get("step", 0),
             metrics_out.get("loss", float("nan")))
    return metrics_out


if __name__ == "__main__":
    main()
