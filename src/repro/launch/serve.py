"""Serving driver: batched greedy/temperature generation.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --preset smoke --requests 8 --prompt-len 32 --max-new-tokens 16

Random-init weights by default (no pretrained weights ship with the repo);
``--ckpt-dir`` restores params from a launch/train.py checkpoint.
"""

from __future__ import annotations

import argparse
import logging
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.launch.train import build_local_mesh
from repro.models.model import build_model
from repro.serve.engine import Request, ServeEngine

logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
log = logging.getLogger("repro.serve")


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.preset == "full" else smoke_config(args.arch)
    mesh = build_local_mesh(args.model_parallel)
    bundle = build_model(cfg, mesh)
    params = jax.jit(bundle.init)(jax.random.PRNGKey(args.seed))
    if args.ckpt_dir:
        from repro.runtime.checkpoint import CheckpointManager
        from repro.train.optimizer import AdamWConfig
        from repro.train.train_step import train_state_shapes

        ckpt = CheckpointManager(args.ckpt_dir)
        step = ckpt.latest_step()
        if step is not None:
            like = train_state_shapes(bundle, AdamWConfig())
            params = ckpt.restore(step, like).params
            log.info("restored params from step %d", step)

    engine = ServeEngine(
        bundle, params, temperature=args.temperature, seed=args.seed
    )
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(
            prompt=rng.integers(
                0, cfg.vocab_size, size=args.prompt_len
            ).tolist(),
            max_new_tokens=args.max_new_tokens,
        )
        for _ in range(args.requests)
    ]
    t0 = time.time()
    outs = engine.serve(reqs)
    dt = time.time() - t0
    total_new = sum(len(o) for o in outs)
    for i, o in enumerate(outs[: min(4, len(outs))]):
        log.info("req %d -> %s%s", i, o[:12], "..." if len(o) > 12 else "")
    log.info(
        "%d requests, %d tokens in %.2fs (%.1f tok/s incl. prefill+compile)",
        len(reqs), total_new, dt, total_new / dt,
    )
    return {"requests": len(reqs), "new_tokens": total_new, "seconds": dt}


if __name__ == "__main__":
    main()
