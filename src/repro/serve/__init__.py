from repro.serve.engine import ServeEngine, Request  # noqa: F401
from repro.serve.selection import (  # noqa: F401
    Backpressure,
    JobCancelled,
    JobFailed,
    JobInfo,
    ResultCache,
    SelectionRequest,
    SelectionService,
    UnknownJob,
    parse_source_ref,
)
