"""Batched serving engine: prefill -> cache grow -> jitted decode loop.

Wave batching: requests are grouped into fixed-size waves (padded with
replicas of the last prompt); each wave shares a prompt length (shorter
prompts are left-padded by the caller or bucketed by ``ServeEngine.serve``).
Decode runs one jitted ``serve_step`` per token with the cache donated, so
steady-state decode allocates nothing.

Per-row cursors (continuous batching) are roadmap: they need per-row cache
scatter; the wave design keeps serve_step identical to the dry-run cell.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 32
    eos_id: Optional[int] = None


def _pad_caches(caches, new_len: int):
    """Grow attention K/V caches (ng, B, S, KV, D) along S; SSM states pass."""

    def grow(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if any(n in ("k", "v") for n in names) and not any(
            n in ("xk", "xv", "ssm") for n in names
        ):
            pad = new_len - leaf.shape[2]
            if pad > 0:
                cfgpad = [(0, 0)] * leaf.ndim
                cfgpad[2] = (0, pad)
                return jnp.pad(leaf, cfgpad)
        return leaf

    return jax.tree_util.tree_map_with_path(grow, caches)


class ServeEngine:
    """Greedy/temperature decoding over a ModelBundle (decoder-only)."""

    def __init__(self, bundle, params, *, temperature: float = 0.0, seed: int = 0):
        if bundle.cfg.is_encdec:
            raise NotImplementedError(
                "ServeEngine drives decoder-only families; whisper-style "
                "enc-dec serving goes through examples/whisper_stub.py"
            )
        self.bundle = bundle
        self.params = params
        self.temperature = temperature
        self._key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(bundle.prefill)
        self._step = jax.jit(bundle.serve_step, donate_argnums=(1,))

    # ------------------------------------------------------------------ wave
    def _sample(self, logits: Array) -> Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._key, sub = jax.random.split(self._key)
        return jax.random.categorical(
            sub, logits / self.temperature, axis=-1
        ).astype(jnp.int32)

    def generate_wave(
        self,
        prompts: np.ndarray,  # (B, S) int32, equal-length wave
        max_new_tokens: int,
        eos_id: Optional[int] = None,
    ) -> np.ndarray:
        b, s = prompts.shape
        tokens = jnp.asarray(prompts, jnp.int32)
        last_logits, caches = self._prefill(self.params, {"tokens": tokens})
        caches = _pad_caches(caches, s + max_new_tokens)
        out = np.zeros((b, max_new_tokens), np.int32)
        next_tok = self._sample(last_logits)
        done = np.zeros((b,), bool)
        for i in range(max_new_tokens):
            out[:, i] = np.where(done, eos_id or 0, np.asarray(next_tok))
            if eos_id is not None:
                done |= out[:, i] == eos_id
                if done.all():
                    break
            batch = {
                "tokens": next_tok[:, None],
                "pos": jnp.int32(s + i),
                "caches": caches,
            }
            logits, caches = self._step(self.params, batch)
            next_tok = self._sample(logits[:, 0])
        return out

    # ------------------------------------------------------------------ API
    def serve(self, requests: List[Request]) -> List[List[int]]:
        """Bucket by prompt length, run waves, return new tokens per req."""
        order = sorted(range(len(requests)), key=lambda i: len(requests[i].prompt))
        results: dict[int, List[int]] = {}
        i = 0
        while i < len(order):
            j = i
            plen = len(requests[order[i]].prompt)
            while j < len(order) and len(requests[order[j]].prompt) == plen:
                j += 1
            wave_ids = order[i:j]
            wave = np.stack(
                [np.asarray(requests[k].prompt, np.int32) for k in wave_ids]
            )
            mnt = max(requests[k].max_new_tokens for k in wave_ids)
            eos = requests[wave_ids[0]].eos_id
            toks = self.generate_wave(wave, mnt, eos)
            for row, k in enumerate(wave_ids):
                t = toks[row, : requests[k].max_new_tokens].tolist()
                if requests[k].eos_id is not None and requests[k].eos_id in t:
                    t = t[: t.index(requests[k].eos_id)]
                results[k] = t
            i = j
        return [results[k] for k in range(len(requests))]
