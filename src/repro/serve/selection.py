"""Selection-as-a-service: job manager, result cache, coalescing queue.

The paper pitches feature selection as shared cluster infrastructure —
many analysts, one dataset fleet — and at that scale *recomputation
count*, not FLOPs, dominates cost: most traffic is the same few fits
asked for again and again.  :class:`SelectionService` is the long-lived
front end that exploits that:

* **Job manager** — ``submit(source, num_select=...) -> job_id`` with the
  lifecycle ``QUEUED -> RUNNING -> DONE | FAILED | CANCELLED``;
  ``poll``/``result``/``cancel``/``stats`` observe and steer it.
* **Queue-based load leveling** — a bounded work queue drained by a
  worker pool.  A full queue *rejects* with :class:`Backpressure`
  (carrying ``retry_after_s``) instead of blocking or crashing, so load
  spikes shed gracefully and callers know when to come back.
* **Content-addressed result cache** — cache-aside over
  ``sha256(source.fingerprint() × score × criterion × num_select ×
  encoding)`` with an LRU bound: a repeat submission is DONE at submit
  time with zero engine or I/O passes.  ``block_obs``/``prefetch``/
  ``batch_candidates``/``spill_dir``/``readahead`` are deliberately NOT
  part of the address — selections are block-size independent and
  batched/spilled runs are bitwise-identical (tested repo invariants),
  so every execution geometry of the same fit shares one cache line.  An optional ``cache_dir`` spills
  entries as JSON (``MRMRResult.to_json``) and reads them back
  (read-through), surviving restarts.
* **Request coalescing / idempotency keys** — a stampede of identical
  submissions while one is queued or running attaches to the in-flight
  primary job: the engine runs exactly once and every submitter gets the
  same result (and their own job id).
* **Retry with backoff** — each engine run goes through
  :func:`repro.runtime.resilience.retry_with_backoff`; transient worker
  failures (:class:`~repro.runtime.resilience.TransientError` by
  default) re-run with exponential backoff before the job FAILs.

Downstream, repeat traffic also skips compilation: the engines' jitted
callables are memoised in warm jit caches keyed by engine × criterion ×
score × block shape (``repro.core.selector.cached_engine_fn``,
``repro.core.streaming``'s accumulate cache), so a cache *miss* on a
previously-seen job shape pays I/O but never XLA compile.

    >>> from repro.serve import SelectionService
    >>> svc = SelectionService(workers=2, queue_capacity=32)
    >>> job = svc.submit("X.npy::y.npy", num_select=10)
    >>> svc.result(job).selected        # blocks until DONE
    >>> svc.submit("X.npy::y.npy", num_select=10)   # cache hit: DONE now
    >>> svc.stats()                     # queue / cache / coalescing counters

CLI: ``python -m repro.launch.serve_select`` submits, polls and prints
the same stats as JSON.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import os
import queue
import threading
import time
from collections import OrderedDict

import numpy as np

from repro.core.criteria import Criterion, resolve_criterion
from repro.core.mrmr import MRMRResult
from repro.core.scores import MIScore, PearsonMIScore, ScoreFn
from repro.core.selector import check_num_select
from repro.data.binning import BinnedSource
from repro.data.sources import (
    CSVSource,
    CorralSource,
    DataSource,
    NpySource,
    as_source,
)
from repro.runtime.resilience import TransientError, retry_with_backoff

# Job lifecycle states.
QUEUED = "QUEUED"
RUNNING = "RUNNING"
DONE = "DONE"
FAILED = "FAILED"
CANCELLED = "CANCELLED"

_SHUTDOWN = object()  # worker-loop poison pill


# ---------------------------------------------------------------------------
# errors
# ---------------------------------------------------------------------------

class Backpressure(RuntimeError):
    """Work queue full — resubmit after ``retry_after_s`` seconds.

    The reject-with-retry-after half of queue-based load leveling: a full
    queue sheds load at the door instead of letting latency (or memory)
    grow without bound.  ``retry_after_s`` estimates the backlog drain
    time from a running average of job durations.
    """

    def __init__(self, retry_after_s: float, depth: int, capacity: int):
        super().__init__(
            f"selection queue full ({depth}/{capacity} jobs); "
            f"retry after ~{retry_after_s:.2f}s"
        )
        self.retry_after_s = retry_after_s
        self.depth = depth
        self.capacity = capacity


class UnknownJob(KeyError):
    """No job with that id."""


class JobFailed(RuntimeError):
    """The job's engine run raised (after exhausting retries)."""

    def __init__(self, job_id: str, error: str):
        super().__init__(f"{job_id} failed: {error}")
        self.job_id = job_id
        self.error = error


class JobCancelled(RuntimeError):
    """The job was cancelled before producing a result."""


# ---------------------------------------------------------------------------
# requests and jobs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SelectionRequest:
    """One fit ask: the source plus every plan knob the service honours.

    ``score`` is already resolved (never None) by the time a request is
    built — the idempotency key needs a concrete score identity.
    """

    source: DataSource
    num_select: int
    score: ScoreFn
    criterion: Criterion
    encoding: str = "auto"
    block_obs: int = 65536
    prefetch: int | str = "auto"
    batch_candidates: int = 1
    spill_dir: str | None = None
    readahead: int = 0

    def cache_key(self) -> str:
        """The content address: what the *result* depends on, nothing more.

        ``block_obs`` / ``prefetch`` / ``batch_candidates`` / ``spill_dir``
        / ``readahead`` only change how the fit executes, not what it
        selects (block-size independence and batched/spilled bitwise
        equivalence are tested invariants), so they are excluded — every
        execution geometry of the same fit coalesces onto one cache line.
        """
        payload = "|".join(
            (
                self.source.fingerprint(),
                repr(self.score),
                self.criterion.name or repr(self.criterion),
                str(int(self.num_select)),
                self.encoding,
            )
        )
        return hashlib.sha256(payload.encode()).hexdigest()


@dataclasses.dataclass
class _Job:
    """Internal mutable job record (one per submission, coalesced or not)."""

    job_id: str
    key: str
    request: SelectionRequest
    state: str = QUEUED
    submitted_at: float = 0.0
    started_at: float | None = None
    finished_at: float | None = None
    error: str | None = None
    result: MRMRResult | None = None
    cache_hit: bool = False
    coalesced_into: str | None = None
    attempts: int = 0
    cancel_requested: bool = False
    followers: list = dataclasses.field(default_factory=list)
    done: threading.Event = dataclasses.field(default_factory=threading.Event)


@dataclasses.dataclass(frozen=True)
class JobInfo:
    """Immutable ``poll`` snapshot of a job."""

    job_id: str
    state: str
    cache_hit: bool
    coalesced_into: str | None
    error: str | None
    attempts: int
    submitted_at: float
    started_at: float | None
    finished_at: float | None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _snapshot(job: _Job) -> JobInfo:
    return JobInfo(
        job_id=job.job_id, state=job.state, cache_hit=job.cache_hit,
        coalesced_into=job.coalesced_into, error=job.error,
        attempts=job.attempts, submitted_at=job.submitted_at,
        started_at=job.started_at, finished_at=job.finished_at,
    )


# ---------------------------------------------------------------------------
# result cache
# ---------------------------------------------------------------------------

class ResultCache:
    """Content-addressed LRU cache of :class:`MRMRResult`s (cache-aside).

    The service reads before enqueueing and writes after each engine run;
    the cache itself never computes.  ``persist_dir`` spills every entry
    as ``<key>.json`` (write-through) and ``get`` falls back to disk
    (read-through), so a restarted service — or another process pointed at
    the same directory — reuses results across the LRU bound and across
    process lifetimes.
    """

    def __init__(self, capacity: int = 128, persist_dir: str | None = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.persist_dir = persist_dir
        self._entries: OrderedDict = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.disk_hits = 0
        if persist_dir is not None:
            os.makedirs(persist_dir, exist_ok=True)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._entries

    def _path(self, key: str) -> str:
        return os.path.join(self.persist_dir, f"{key}.json")

    def get(self, key: str) -> MRMRResult | None:
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                self.hits += 1
                return self._entries[key]
        if self.persist_dir is not None and os.path.exists(self._path(key)):
            with open(self._path(key)) as f:
                result = MRMRResult.from_json(f.read())
            with self._lock:
                self.disk_hits += 1
            self._insert(key, result)
            return result
        with self._lock:
            self.misses += 1
        return None

    def _insert(self, key: str, result: MRMRResult) -> None:
        with self._lock:
            self._entries[key] = result
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.evictions += 1

    def put(self, key: str, result: MRMRResult) -> None:
        self._insert(key, result)
        if self.persist_dir is not None:
            # Atomic spill: a concurrent reader sees the old file or the
            # new one, never a torn write.
            tmp = self._path(key) + ".tmp"
            with open(tmp, "w") as f:
                f.write(result.to_json())
            os.replace(tmp, self._path(key))

    def stats(self) -> dict:
        with self._lock:
            return dict(
                size=len(self._entries), capacity=self.capacity,
                hits=self.hits, misses=self.misses,
                evictions=self.evictions, disk_hits=self.disk_hits,
            )

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.hits = self.misses = self.evictions = self.disk_hits = 0


# ---------------------------------------------------------------------------
# source refs
# ---------------------------------------------------------------------------

def parse_source_ref(ref: str) -> DataSource:
    """Build a :class:`DataSource` from a string reference.

    Accepted forms (the CLI's ``--source`` and ``submit``'s string face):

    * ``"X.npy::y.npy"``       — memmapped feature matrix + target vector
    * ``"data.csv"``           — streaming CSV, target = last column
    * ``"corral:ROWSxCOLS"``   — the paper's synthetic generator
      (``corral:20000x64:7`` pins ``seed=7``; default seed 0)
    """
    if ref.startswith("corral:"):
        parts = ref.split(":")
        try:
            rows, cols = (int(v) for v in parts[1].split("x"))
            seed = int(parts[2]) if len(parts) > 2 else 0
        except (ValueError, IndexError):
            raise ValueError(
                f"bad corral ref {ref!r}; want 'corral:ROWSxCOLS[:SEED]'"
            ) from None
        return CorralSource(rows, cols, seed=seed)
    if "::" in ref:
        x_path, y_path = ref.split("::", 1)
        return NpySource(x_path, y_path)
    if ref.endswith(".csv"):
        return CSVSource(ref, dtype=np.int32)
    raise ValueError(
        f"unrecognised source ref {ref!r}; want 'X.npy::y.npy', "
        "'data.csv' or 'corral:ROWSxCOLS[:SEED]'"
    )


# ---------------------------------------------------------------------------
# the service
# ---------------------------------------------------------------------------

class SelectionService:
    """Long-lived selection front end: queue, workers, cache, coalescing.

    Args:
      workers: worker threads draining the queue (each runs one engine fit
        at a time; streamed fits bound their own device memory, so worker
        count × ``block_obs`` is the service's peak-memory envelope).
      queue_capacity: bound on QUEUED jobs; beyond it ``submit`` raises
        :class:`Backpressure` (coalesced and cache-hit submissions never
        occupy a slot).
      cache_capacity / cache_dir: LRU bound and optional JSON spill
        directory of the :class:`ResultCache`.
      max_attempts / retry_base_delay_s / retry_on: the per-job
        :func:`retry_with_backoff` envelope for transient engine failures.
      fit_fn: ``SelectionRequest -> MRMRResult`` override (tests inject
        counting/flaky fits); default runs :class:`repro.MRMRSelector`.

    Thread-safe; use as a context manager or call :meth:`close`.
    """

    def __init__(
        self,
        *,
        workers: int = 2,
        queue_capacity: int = 32,
        cache_capacity: int = 128,
        cache_dir: str | None = None,
        max_attempts: int = 3,
        retry_base_delay_s: float = 0.05,
        retry_on=(TransientError,),
        fit_fn=None,
        retry_sleep=time.sleep,
    ):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self.cache = ResultCache(cache_capacity, persist_dir=cache_dir)
        self._queue: queue.Queue = queue.Queue(maxsize=queue_capacity)
        self._lock = threading.Lock()
        self._jobs: dict[str, _Job] = {}
        self._inflight: dict[str, _Job] = {}  # cache key -> primary job
        self._ids = itertools.count()
        self._rejected = 0
        self._coalesced = 0
        self._avg_run_s: float | None = None
        self._closed = False
        self._max_attempts = max_attempts
        self._retry_base_delay_s = retry_base_delay_s
        self._retry_on = retry_on
        self._retry_sleep = retry_sleep
        self._fit_fn = fit_fn if fit_fn is not None else _default_fit
        self._workers = [
            threading.Thread(
                target=self._worker_loop,
                name=f"selection-worker-{i}",
                daemon=True,
            )
            for i in range(workers)
        ]
        for w in self._workers:
            w.start()

    # ------------------------------------------------------------- submit

    def submit(
        self,
        source,
        *,
        num_select: int,
        score: ScoreFn | None = None,
        criterion: Criterion | str = "mid",
        encoding: str = "auto",
        block_obs: int = 65536,
        prefetch: int | str = "auto",
        batch_candidates: int = 1,
        spill_dir: str | None = None,
        readahead: int = 0,
        bins: int | None = None,
    ) -> str:
        """Enqueue a fit; returns a job id immediately.

        ``source`` is a :class:`DataSource`, a string reference (see
        :func:`parse_source_ref`) or an ``(X, y)`` array pair.  A result
        already in the cache completes the job at submit time
        (``cache_hit``); an identical request queued or running coalesces
        onto it; otherwise the job takes a queue slot — or, when the queue
        is full, ``submit`` raises :class:`Backpressure`.

        ``bins`` quantile-discretises a continuous source on the fly
        (:class:`~repro.data.binning.BinnedSource`); the binned
        fingerprint folds the bin config into the cache key, so bins=16
        and bins=64 runs of the same file never collide, and wrapping is
        I/O-free at submit (the sketch pass runs inside the worker's fit,
        memoised per fingerprint).
        """
        if self._closed:
            raise RuntimeError("SelectionService is closed")
        if isinstance(source, str):
            source = parse_source_ref(source)
        elif isinstance(source, tuple):
            source = as_source(*source)
        else:
            source = as_source(source)
        check_num_select(num_select, source.num_features)
        if (
            bins is not None
            and not isinstance(source, BinnedSource)
            and (score is None or isinstance(score, MIScore))
            and (
                np.issubdtype(source.feature_dtype, np.floating)
                if source.feature_dtype is not None
                else not source.stats(block_obs).discrete
            )
        ):
            source = BinnedSource(source, int(bins), fit_block_obs=block_obs)
        if isinstance(source, BinnedSource) and score is None:
            # Sized from config + the sketch pass (memoised: repeat
            # submissions of the same binned content never re-sketch).
            score = MIScore(
                num_values=source.bins,
                num_classes=source.stats(block_obs).num_classes,
            )
        if score is None:
            # stats() is memoised per source fingerprint, so repeat
            # submissions on the same file resolve without an I/O pass.
            st = source.stats(block_obs)
            score = (
                MIScore(num_values=st.num_values, num_classes=st.num_classes)
                if st.discrete
                else PearsonMIScore()
            )
        request = SelectionRequest(
            source=source, num_select=int(num_select), score=score,
            criterion=resolve_criterion(criterion), encoding=encoding,
            block_obs=int(block_obs),
            prefetch=prefetch if prefetch == "auto" else int(prefetch),
            batch_candidates=int(batch_candidates), spill_dir=spill_dir,
            readahead=int(readahead),
        )
        key = request.cache_key()
        cached = self.cache.get(key)
        with self._lock:
            job_id = f"job-{next(self._ids):04d}"
            now = time.time()
            job = _Job(
                job_id=job_id, key=key, request=request, submitted_at=now
            )
            if cached is not None:
                # Cache-aside read path: DONE before it ever queues.
                job.state = DONE
                job.result = cached
                job.cache_hit = True
                job.started_at = job.finished_at = now
                job.done.set()
                self._jobs[job_id] = job
                return job_id
            primary = self._inflight.get(key)
            if primary is not None:
                # Idempotent coalescing: ride the in-flight run.  (The
                # primary may itself be CANCELLED-but-queued; this new
                # submitter's interest is what keeps the run alive.)
                job.coalesced_into = primary.job_id
                job.state = RUNNING if primary.state == RUNNING else QUEUED
                job.started_at = primary.started_at
                primary.followers.append(job)
                self._coalesced += 1
                self._jobs[job_id] = job
                return job_id
            try:
                self._queue.put_nowait(job)
            except queue.Full:
                self._rejected += 1
                raise Backpressure(
                    self._retry_after(), self._queue.qsize(),
                    self._queue.maxsize,
                ) from None
            self._inflight[key] = job
            self._jobs[job_id] = job
            return job_id

    def _retry_after(self) -> float:
        per_job = self._avg_run_s if self._avg_run_s is not None else 1.0
        # Full queue + what the workers hold, drained by the pool.
        backlog = self._queue.maxsize + len(self._workers)
        return max(per_job * backlog / max(len(self._workers), 1), 0.05)

    # -------------------------------------------------------------- query

    def _get(self, job_id: str) -> _Job:
        try:
            return self._jobs[job_id]
        except KeyError:
            raise UnknownJob(job_id) from None

    def poll(self, job_id: str) -> JobInfo:
        """Current lifecycle snapshot of a job."""
        with self._lock:
            return _snapshot(self._get(job_id))

    def result(self, job_id: str, timeout: float | None = None) -> MRMRResult:
        """Block until the job finishes and return its result.

        Raises :class:`JobFailed` / :class:`JobCancelled` for those
        terminal states and ``TimeoutError`` if ``timeout`` elapses.
        """
        with self._lock:
            job = self._get(job_id)
        if not job.done.wait(timeout):
            raise TimeoutError(f"{job_id} still {job.state} after {timeout}s")
        if job.state == FAILED:
            raise JobFailed(job_id, job.error or "unknown error")
        if job.state == CANCELLED:
            raise JobCancelled(f"{job_id} was cancelled")
        return job.result

    def cancel(self, job_id: str) -> bool:
        """Withdraw a submission; True if it will never run for this caller.

        A QUEUED primary job is cancelled in place (the worker skips it
        unless coalesced followers still want the result — then the run
        proceeds for them and this job stays CANCELLED).  Coalesced
        followers can cancel any time before completion.  A RUNNING
        primary cannot be stopped mid-engine: returns False.
        """
        with self._lock:
            job = self._get(job_id)
            if job.state in (DONE, FAILED, CANCELLED):
                return job.state == CANCELLED
            if job.coalesced_into is None and job.state != QUEUED:
                return False  # primary already running
            job.cancel_requested = True
            job.state = CANCELLED
            job.finished_at = time.time()
            job.done.set()
            return True

    def stats(self) -> dict:
        """Queue, job, coalescing and cache counters (one JSON-able dict)."""
        with self._lock:
            by_state: dict[str, int] = {}
            for j in self._jobs.values():
                by_state[j.state] = by_state.get(j.state, 0) + 1
            return dict(
                queue=dict(
                    depth=self._queue.qsize(),
                    capacity=self._queue.maxsize,
                    rejected=self._rejected,
                    inflight=len(self._inflight),
                ),
                workers=len(self._workers),
                jobs=by_state,
                coalesced=self._coalesced,
                avg_run_s=self._avg_run_s,
                cache=self.cache.stats(),
            )

    # ------------------------------------------------------------ workers

    def _worker_loop(self) -> None:
        while True:
            job = self._queue.get()
            if job is _SHUTDOWN:
                return
            with self._lock:
                interested = [
                    j
                    for j in (job, *job.followers)
                    if not j.cancel_requested
                ]
                if not interested:
                    # Everyone cancelled while queued; states are already
                    # CANCELLED — just release the idempotency key.
                    self._inflight.pop(job.key, None)
                    continue
                started = time.time()
                for j in interested:
                    j.state = RUNNING
                    j.started_at = started

            def run():
                job.attempts += 1
                return self._fit_fn(job.request)

            try:
                result = retry_with_backoff(
                    run,
                    max_attempts=self._max_attempts,
                    base_delay_s=self._retry_base_delay_s,
                    retry_on=self._retry_on,
                    sleep=self._retry_sleep,
                )
            except Exception as e:  # noqa: BLE001 — job-level fault barrier
                self._finish(job, FAILED, error=f"{type(e).__name__}: {e}")
                continue
            # Cache-aside write path: populate before releasing the key so
            # the next identical submit hits the cache, not a fresh run.
            self.cache.put(job.key, result)
            elapsed = time.time() - started
            self._avg_run_s = (
                elapsed
                if self._avg_run_s is None
                else 0.8 * self._avg_run_s + 0.2 * elapsed
            )
            self._finish(job, DONE, result=result)

    def _finish(self, job: _Job, state: str, *, result=None, error=None):
        """Fan a terminal state out to the primary and every follower —
        including followers that coalesced on while the engine ran."""
        now = time.time()
        with self._lock:
            for j in (job, *job.followers):
                if j.cancel_requested:
                    continue  # already CANCELLED with done set
                j.state = state
                j.result = result
                j.error = error
                j.attempts = job.attempts
                j.finished_at = now
                j.done.set()
            self._inflight.pop(job.key, None)

    # ------------------------------------------------------------ closing

    def close(self, timeout: float = 10.0) -> None:
        """Stop accepting work and join the workers (running jobs finish)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        for _ in self._workers:
            self._queue.put(_SHUTDOWN)
        for w in self._workers:
            w.join(timeout=timeout)

    def __enter__(self) -> "SelectionService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _default_fit(request: SelectionRequest) -> MRMRResult:
    """Run the request through the front-door selector (streaming engine
    for every DataSource under ``encoding="auto"``)."""
    from repro.core.selector import MRMRSelector  # local: breaks no cycles

    sel = MRMRSelector(
        num_select=request.num_select,
        score=request.score,
        criterion=request.criterion,
        encoding=request.encoding,
        block_obs=request.block_obs,
        prefetch=request.prefetch,
        batch_candidates=request.batch_candidates,
        spill_dir=request.spill_dir,
        readahead=request.readahead,
    )
    sel.fit(request.source)
    return sel.result_


__all__ = [
    "Backpressure",
    "CANCELLED",
    "DONE",
    "FAILED",
    "JobCancelled",
    "JobFailed",
    "JobInfo",
    "QUEUED",
    "RUNNING",
    "ResultCache",
    "SelectionRequest",
    "SelectionService",
    "UnknownJob",
    "parse_source_ref",
]
