"""scikit-learn face of the mRMR engines — ``MRMRTransformer``.

A :class:`~sklearn.feature_selection.SelectorMixin` estimator wrapping
:class:`repro.MRMRSelector`, so the paper's selection drops into the
standard composition machinery unchanged::

    from sklearn.pipeline import make_pipeline
    from sklearn.linear_model import LogisticRegression
    from repro.interop.sklearn import MRMRTransformer

    pipe = make_pipeline(
        MRMRTransformer(num_select=10, criterion="jmi", bins=32),
        LogisticRegression(),
    )
    pipe.fit(X_train, y_train)                  # select-then-train
    GridSearchCV(pipe, {"mrmrtransformer__num_select": [5, 10, 20]})

Constructor params are stored verbatim (the sklearn ``clone`` contract:
``get_params`` must round-trip unmodified), and every selection knob —
``criterion`` (``mid``/``miq``/``maxrel``/``jmi``/``cmim`` or a
``Criterion`` instance), ``bins`` for on-the-fly quantile
discretisation of continuous data, ``encoding``/``devices`` for the
distribution plan — passes straight through to the selector at ``fit``
time.  ``transform`` keeps sklearn's convention (selected columns in
ascending index order, via the mixin's support mask); the greedy pick
order lives in ``selected_`` and the objective trajectory in ``gains_``.

scikit-learn is a soft dependency: importing this module without it
raises an actionable ``ImportError`` rather than leaving ``repro``
depending on sklearn.
"""

from __future__ import annotations

import numpy as np

try:
    from sklearn.base import BaseEstimator
    from sklearn.feature_selection import SelectorMixin
    from sklearn.utils.validation import check_is_fitted, check_X_y
except ImportError:  # pragma: no cover - exercised only without sklearn
    raise ImportError(
        "repro.interop.sklearn requires scikit-learn; install it "
        "(pip install scikit-learn) or use repro.MRMRSelector directly"
    ) from None

from repro.core.selector import MRMRSelector


class MRMRTransformer(SelectorMixin, BaseEstimator):
    """mRMR feature selection as a scikit-learn transformer.

    Args:
      num_select: number of features to select (L).
      criterion: greedy objective — a registered name (``"mid"``,
        ``"miq"``, ``"maxrel"``, ``"jmi"``, ``"cmim"``) or a
        :class:`~repro.core.criteria.Criterion` instance.
      score: an explicit :class:`~repro.core.scores.ScoreFn`; None
        resolves from the data (discrete -> exact MI, continuous ->
        Pearson-MI, or binned MI when ``bins`` is set).
      bins: quantile-discretise continuous features into this many
        equal-frequency bins and select with exact discrete MI (the
        route to ``jmi``/``cmim`` on float data); None = off.
      encoding: distribution plan (``"auto"`` applies the paper's §III
        rule) — see :class:`~repro.core.selector.MRMRSelector`.
      devices: device budget for auto-planning.
      block_obs: observations per streamed block (DataSource fits).

    Fitted attributes follow sklearn conventions: ``n_features_in_``,
    ``selected_`` (pick order), ``gains_``, ``scores_`` (per-feature
    relevance), ``ranking_``; ``get_support()``/``transform`` come from
    ``SelectorMixin``.  The fitted :class:`~repro.core.selector.
    MRMRSelector` is exposed as ``selector_`` for the full report
    (``selector_.result_``, ``selector_.plan_``).
    """

    def __init__(
        self,
        num_select: int = 10,
        *,
        criterion="mid",
        score=None,
        bins=None,
        encoding: str = "auto",
        devices=None,
        block_obs: int = 65536,
    ):
        self.num_select = num_select
        self.criterion = criterion
        self.score = score
        self.bins = bins
        self.encoding = encoding
        self.devices = devices
        self.block_obs = block_obs

    def fit(self, X, y=None):
        """Run the greedy selection; ``y`` is required (supervised)."""
        if y is None:
            raise ValueError(
                "MRMRTransformer is a supervised selector: fit(X, y)"
            )
        # dtype=None keeps integer matrices integral — the discrete-MI
        # route; sklearn's default float coercion would silently send
        # categorical data down the Pearson path.
        X, y = check_X_y(X, y, dtype=None)
        self.n_features_in_ = X.shape[1]
        self.selector_ = MRMRSelector(
            num_select=self.num_select,
            score=self.score,
            criterion=self.criterion,
            encoding=self.encoding,
            devices=self.devices,
            block_obs=self.block_obs,
            bins=self.bins,
        ).fit(X, y)
        self.selected_ = np.asarray(self.selector_.selected_)
        self.gains_ = np.asarray(self.selector_.gains_)
        self.scores_ = (
            None
            if self.selector_.scores_ is None
            else np.asarray(self.selector_.scores_)
        )
        self.ranking_ = np.asarray(self.selector_.ranking_)
        return self

    def _get_support_mask(self) -> np.ndarray:
        check_is_fitted(self, "selector_")
        return self.selector_.get_support()

    def _more_tags(self):  # sklearn < 1.6 tag API
        return {"allow_nan": False, "requires_y": True}

    def __sklearn_tags__(self):  # sklearn >= 1.6 tag API
        tags = super().__sklearn_tags__()
        tags.target_tags.required = True
        return tags


__all__ = ["MRMRTransformer"]
