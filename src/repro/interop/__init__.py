"""Ecosystem adapters — this repo's engines behind other libraries' APIs.

Submodules soft-gate their third-party imports so ``repro`` itself never
grows a hard dependency: ``repro.interop.sklearn`` needs scikit-learn
(:class:`~repro.interop.sklearn.MRMRTransformer`, a ``SelectorMixin``
estimator that drops into ``Pipeline``/``GridSearchCV``), and the
columnar sources it pairs with (``ParquetSource``/``ArrowSource`` in
:mod:`repro.data.sources`) need pyarrow.  Importing a submodule without
its dependency raises an actionable ``ImportError`` naming the package.
"""

from __future__ import annotations

__all__ = ["sklearn"]
