"""Streaming mRMR — the paper's MapReduce fit over out-of-core data.

This is the data regime the paper actually targets: a dataset too large to
hold in device memory, visited as observation-blocks.  Each scoring pass
is one MapReduce job in the paper's conventional encoding — ``map`` =
per-block sufficient statistics (contingency tables for MI, running
moments for Pearson), ``combine`` = the block-level batched einsum,
``reduce`` = the state-carrying sum across blocks (plus the mesh
all-reduce when blocks are sharded).  The greedy loop is host-driven:

    pass 0:        relevance statistics vs the class   -> rel (N,)
    pick l, then:  statistics of ALL features vs the just-selected column
                   (read from the same blocks, no column cache), folded
                   into the criterion's running state

Total I/O is ``L`` passes over the source (1 relevance + L-1 redundancy,
the running-fold formulation — selections identical to the paper's
recompute, as with the in-memory engines) while peak device memory is
``O(block_obs × N)`` for the block plus the statistics state,
independent of ``num_obs``.  The greedy objective is pluggable
(``criterion=`` — ``mid``/``miq``/``maxrel`` or anything registered via
:func:`repro.core.criteria.register_criterion`); a criterion that
declares ``needs_redundancy = False`` (``maxrel``) collapses the whole
fit to ONE relevance pass of I/O.

Both of the paper's §III regimes stream:

* **tall** — blocks shard over ``obs_axes`` (the paper's conventional
  partitioning); statistics reduce with one all-reduce per block.
* **wide** — blocks *and the statistics state* shard over ``feat_axes``
  (the alternative/vertical partitioning), so the ``O(N · d_v · d_c)``
  per-pair state that would blow one device spreads across the mesh:
  per-device statistics memory is ``O(N/shards · d_v · d_c)``.
* **both-large** — a 2-D (obs × feat) grid combines the two; XLA
  partitions the accumulate across the grid from the input/state
  shardings alone.

``prefetch`` double-buffers placement (:class:`~repro.dist.streaming.
PrefetchPlacer`): the host reads/pads/``device_put``s block ``i+1`` while
the device accumulates block ``i``; ``0`` restores the synchronous path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.criteria import Criterion, resolve_criterion
from repro.core.mrmr import MRMRResult, WarmJitCache
from repro.core.scores import MIScore, ScoreFn
from repro.core.selector import check_num_select, register_engine
from repro.data.binning import BinnedSource, _as_class_labels
from repro.data.sources import DataSource, as_source
from repro.dist.streaming import BlockPlacer, PrefetchPlacer

_NEG_INF = float("-inf")

# Warm accumulate cache: one jitted accumulate per (score × mesh layout ×
# block shape).  A fresh ``jax.jit(score.accumulate)`` every fit would
# recompile the whole per-block step each time; keeping the wrapper keyed
# by the placed geometry means repeat streamed fits (the selection
# service's steady state) pay zero compile after the first.
_ACC_FN_CACHE = WarmJitCache(capacity=32)


def _cached_acc_fn(
    score: ScoreFn,
    placer: BlockPlacer,
    mesh: Mesh | None,
    num_edges: int | None = None,
):
    key = (
        "acc_fn", score, mesh, placer.block_obs, placer.padded_features,
        placer.obs_axes, placer.feat_axes, num_edges,
    )

    def build():
        # Pin the state layout (feature-sharded in the wide regime) through
        # the compiled accumulate, so XLA never gathers the per-pair
        # statistics.
        shardings = placer.state_shardings(
            score.init_state(placer.padded_features, "class")
        )
        if num_edges is None:
            return jax.jit(score.accumulate, out_shardings=shardings)

        from repro.kernels import ops  # lazy: avoids core<->kernels cycle

        use_pallas = getattr(score, "use_pallas", "auto")

        # Fused binned accumulate: the raw float block encodes to bin codes
        # on device (Pallas/jnp searchsorted) feeding straight into the
        # one-hot contingency sum — no int block round-trips through host
        # memory.  Edges ride as a traced argument, so this compiles once
        # per geometry, not per fitted-edge content.
        def fused(state, X_block, target, valid, edges):
            codes = ops.bin_codes(X_block, edges, use_pallas=use_pallas)
            return score.accumulate(state, codes, target, valid)

        return jax.jit(fused, out_shardings=shardings)

    return _ACC_FN_CACHE.get_or_build(key, build)


def _placed_edges(edges: np.ndarray, placer: BlockPlacer):
    """Land fitted bin edges (N, E) padded to the placer's feature extent
    and sharded to match the block columns.  Pad rows are +inf so a padded
    feature's codes stay 0 (its statistics rows are sliced off anyway)."""
    e = np.asarray(edges, np.float32)
    pad = placer.padded_features - e.shape[0]
    if pad:
        e = np.concatenate(
            [e, np.full((pad, e.shape[1]), np.inf, np.float32)]
        )
    if placer.mesh is not None:
        spec = P(placer.feat_axes if placer.feat_axes else None, None)
        return jax.device_put(e, NamedSharding(placer.mesh, spec))
    return jnp.asarray(e)


def acc_fn_cache_stats() -> dict:
    """Hit/miss/eviction counters of the warm accumulate cache."""
    return _ACC_FN_CACHE.stats()


def clear_acc_fn_cache() -> None:
    """Drop every warmed accumulate fn (tests; frees executables)."""
    _ACC_FN_CACHE.clear()


def _placed_blocks(
    source: DataSource,
    placer: BlockPlacer,
    target_col: int | None,
    prefetch: int,
    binned: "BinnedSource | None" = None,
):
    """Iterate the source's blocks as placed (X, target, valid) tuples,
    extracting the pass's target column on the host; ``prefetch > 0`` runs
    read+pad+place up to that many blocks ahead on a host thread.

    With ``binned`` set the *base* source streams raw float32 blocks (the
    device encodes them — the fused accumulate) and only the pass target
    is encoded on the host: one column per redundancy pass, through the
    same f32 ``searchsorted`` the kernel runs, so host and device codes
    agree bitwise."""

    def host_blocks():
        if binned is not None:
            binner = binned.binner
            for X_blk, y_blk in binned.base.iter_blocks(placer.block_obs):
                X32 = np.asarray(X_blk, np.float32)
                if target_col is None:
                    tgt = _as_class_labels(y_blk)
                else:
                    tgt = binner.encode_column(target_col, X32[:, target_col])
                yield X32, tgt
            return
        for X_blk, y_blk in source.iter_blocks(placer.block_obs):
            tgt = y_blk if target_col is None else X_blk[:, target_col]
            yield X_blk, tgt

    if prefetch > 0:
        return PrefetchPlacer(placer, depth=prefetch).stream(host_blocks())
    return (placer(X_blk, tgt) for X_blk, tgt in host_blocks())


def _score_pass(
    source: DataSource,
    score: ScoreFn,
    acc_fn,
    placer: BlockPlacer,
    target_col: int | None,
    prefetch: int,
    binned: "BinnedSource | None" = None,
) -> np.ndarray:
    """One full map-reduce pass: (N,) scores of every feature against the
    class (``target_col=None``) or against feature column ``target_col``."""
    kind = "class" if target_col is None else "feature"
    state = placer.place_state(score.init_state(placer.padded_features, kind))
    for placed in _placed_blocks(source, placer, target_col, prefetch, binned):
        state = acc_fn(state, *placed)
    scores = np.asarray(score.finalize(state), np.float32)
    return scores[: source.num_features]  # drop feature-padding columns


def mrmr_streaming(
    source,
    num_select: int,
    score: ScoreFn,
    *,
    block_obs: int = 65536,
    mesh: Mesh | None = None,
    obs_axes=("data",),
    feat_axes=(),
    prefetch: int = 2,
    criterion: Criterion | str = "mid",
) -> MRMRResult:
    """Greedy mRMR over a :class:`~repro.data.sources.DataSource`.

    Args:
      source: a ``DataSource`` (or an ``(X, y)`` pair to wrap).
      num_select: L, number of features to pick.
      score: a streaming-capable ``ScoreFn`` (``supports_streaming``).
      block_obs: observations per device block — the peak-memory knob
        (rounded up to the mesh's observation extent).
      mesh / obs_axes / feat_axes: shard each block over the observation
        axes, the feature axes, or both (the 2-D grid).  Feature sharding
        also shards the statistics state, the wide-regime memory wall;
        observation sharding reduces statistics with one all-reduce per
        block, the paper's reducer on the ICI ring.
      prefetch: host blocks to read/pad/place ahead of device
        accumulation (0 = synchronous placement).
      criterion: greedy objective — a name (``"mid"``/``"miq"``/
        ``"maxrel"``) or :class:`~repro.core.criteria.Criterion`.  The
        fold runs on the same (N,)-sized vectors the in-memory engines
        fold, so selections agree engine-for-engine per criterion.
    """
    crit = resolve_criterion(criterion)
    source = as_source(*source) if isinstance(source, tuple) else as_source(source)
    if not score.supports_streaming:
        raise ValueError(
            f"{type(score).__name__} cannot stream: it has no "
            "sufficient-statistics decomposition (init_state/accumulate/"
            "finalize). Materialise the data and use an in-memory engine."
        )
    n = source.num_features
    check_num_select(num_select, n)
    if prefetch < 0:
        raise ValueError(f"prefetch must be >= 0, got {prefetch}")

    placer = BlockPlacer(block_obs, mesh, obs_axes, feat_axes, num_features=n)

    # A BinnedSource scoring discrete MI streams FUSED: raw float blocks
    # go to the device and are encoded there (Pallas searchsorted on TPU,
    # jnp elsewhere) directly ahead of the contingency sum.  The sketch
    # pass (memoised by fingerprint) happens here, before the first
    # scoring pass.  Any other score falls back to host-side encoding
    # through the wrapper's normal iter_blocks.
    binned = (
        source
        if isinstance(source, BinnedSource) and isinstance(score, MIScore)
        else None
    )
    if binned is not None:
        edges = binned.binner.edges_
        base_fn = _cached_acc_fn(score, placer, mesh, num_edges=edges.shape[1])
        edges_dev = _placed_edges(edges, placer)

        def acc_fn(state, X_block, target, valid):
            return base_fn(state, X_block, target, valid, edges_dev)

    else:
        acc_fn = _cached_acc_fn(score, placer, mesh)

    rel = _score_pass(source, score, acc_fn, placer, None, prefetch, binned)
    rel_j = jnp.asarray(rel)
    cstate = crit.init_state(n)
    mask = np.zeros((n,), bool)
    selected = np.full((num_select,), -1, np.int32)
    gains = np.zeros((num_select,), np.float32)
    for l in range(num_select):
        # The criterion fold is the same pure-f32 jnp math the device
        # drivers trace, so argmax ties resolve identically to the
        # in-memory engines (toward the lowest id).
        g = np.array(crit.objective(rel_j, cstate, l), np.float32)
        g[mask] = _NEG_INF
        k = int(np.argmax(g))
        selected[l], gains[l] = k, g[k]
        mask[k] = True
        if l + 1 < num_select and crit.needs_redundancy:
            # One redundancy pass of I/O vs the just-picked column; maxrel
            # (needs_redundancy=False) never re-reads the source.
            red = _score_pass(source, score, acc_fn, placer, k, prefetch, binned)
            cstate = crit.update(cstate, jnp.asarray(red), l)
    return MRMRResult(
        selected=jnp.asarray(selected),
        gains=jnp.asarray(gains),
        relevance=jnp.asarray(rel),
        criterion=crit.name,
        engine="streaming",
    )


@register_engine("streaming")
def _fit_streaming(source, y, *, num_select, plan, mesh) -> MRMRResult:
    del y  # targets come from the source's blocks
    return mrmr_streaming(
        source,
        num_select,
        plan.score,
        block_obs=plan.block_obs,
        mesh=mesh,
        obs_axes=plan.obs_axes,
        feat_axes=plan.feat_axes,
        prefetch=plan.prefetch,
        criterion=plan.criterion,
    )
