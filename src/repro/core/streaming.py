"""Streaming mRMR — the paper's MapReduce fit over out-of-core data.

This is the data regime the paper actually targets: a dataset too large to
hold in device memory, visited as observation-blocks.  Each scoring pass
is one MapReduce job in the paper's conventional encoding — ``map`` =
per-block sufficient statistics (contingency tables for MI, running
moments for Pearson), ``combine`` = the block-level batched einsum,
``reduce`` = the state-carrying sum across blocks (plus the mesh
all-reduce when blocks are sharded).  The greedy loop is host-driven:

    pass 0:        relevance statistics vs the class   -> rel (N,)
    pick l, then:  statistics of ALL features vs the just-selected column
                   (read from the same blocks, no column cache), folded
                   into the criterion's running state

Total I/O is ``L`` passes over the source (1 relevance + L-1 redundancy,
the running-fold formulation — selections identical to the paper's
recompute, as with the in-memory engines) while peak device memory is
``O(block_obs × N)`` for the block plus the statistics state,
independent of ``num_obs``.  The greedy objective is pluggable
(``criterion=`` — ``mid``/``miq``/``maxrel``/``jmi``/``cmim`` or
anything registered via
:func:`repro.core.criteria.register_criterion`); a criterion that
declares ``needs_redundancy = False`` (``maxrel``) collapses the whole
fit to ONE relevance pass of I/O, while one that declares
``needs_conditional_redundancy = True`` (``jmi``/``cmim``) widens each
redundancy pass's target one-hot by the class axis (host-fused codes,
``"feature_cond"`` statistics state) so the SAME sweep yields both
``I(x_k; x_j)`` and ``I(x_k; x_j | y)`` — no extra pass, and zero extra
state bytes for criteria that never ask (asserted via ``io["state_bytes"]``).

At production scale that ``L``-pass tax is the wall-clock story, so the
engine carries three composable knobs that attack pass count and
per-pass cost — selections stay bitwise-identical to the plain engine
under every combination:

* ``batch_candidates=q`` — **batched redundancy.**  When a redundancy
  pass is unavoidable, score the pass's target column *and* the top
  ``q-1`` remaining candidates by the current objective in the same
  sweep (the statistics state grows a ``q``-sized leading axis; targets
  ride as ``(q, B)`` slabs).  The greedy loop then commits picks with
  exact per-pick :class:`~repro.core.criteria.Criterion` folds, drawing
  each needed redundancy vector from the batch when speculation hit and
  paying a fresh pass only on a miss — redundancy vectors are pairwise
  properties of the data, so a speculated vector is never invalidated by
  later picks and stays usable for the rest of the fit.  ``num_select=L``
  drops from ``L-1`` redundancy passes toward ``⌈(L-1)/q⌉``.
* ``spill_dir=`` — **encoded-block spill cache** (:class:`repro.data.
  block_cache.BlockCacheSource`).  Pass 1 writes each block — post CSV
  parse, post quantile-bin encode — to compact ``.npy`` chunks; passes
  2..L replay memmapped chunks, so parse/encode cost is paid once per
  dataset instead of once per pass.  A binned source spills its *int
  codes* (the device-side fused encode is skipped in favour of encoding
  exactly once on the host).
* ``readahead=`` — **cross-pass read-ahead** (:class:`~repro.dist.
  streaming.CrossPassReader`).  Block reads never depend on the
  just-picked column (only the pass-target extraction does, a host
  slice at consume time), so a reader thread streams the head of pass
  ``l+1`` while the device drains the tail of pass ``l``, removing the
  per-pass cold-start bubble.  ``readahead > 0`` supersedes the in-pass
  ``prefetch`` thread: the reader is the producer and staging runs at
  consume time.

Both of the paper's §III regimes stream:

* **tall** — blocks shard over ``obs_axes`` (the paper's conventional
  partitioning); statistics reduce with one all-reduce per block.
* **wide** — blocks *and the statistics state* shard over ``feat_axes``
  (the alternative/vertical partitioning), so the ``O(N · d_v · d_c)``
  per-pair state that would blow one device spreads across the mesh:
  per-device statistics memory is ``O(N/shards · d_v · d_c)`` (times
  ``q`` under batching).
* **both-large** — a 2-D (obs × feat) grid combines the two; XLA
  partitions the accumulate across the grid from the input/state
  shardings alone.

``prefetch`` double-buffers placement (:class:`~repro.dist.streaming.
PrefetchPlacer`): the host reads/pads/``device_put``s block ``i+1`` while
the device accumulates block ``i``; ``0`` restores the synchronous path
and ``"auto"`` applies :func:`~repro.dist.streaming.resolve_prefetch`
(off on CPU, where the staging thread measurably loses to async sync
dispatch; on elsewhere).

Every fit reports its I/O on the result: ``MRMRResult.io`` carries
``passes`` / ``blocks_read`` / ``bytes_read`` counters (plus the spill
cache's parse-vs-replay split when ``spill_dir`` is set), so the pass
math above is asserted by tests and benchmarks, not eyeballed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.criteria import Criterion, resolve_criterion
from repro.core.mrmr import MRMRResult, WarmJitCache, check_conditional_support
from repro.core.scores import MIScore, ScoreFn
from repro.core.selector import check_num_select, register_engine
from repro.data.binning import BinnedSource, _as_class_labels
from repro.data.block_cache import BlockCacheSource
from repro.data.sources import DataSource, ShardSource, as_source
from repro.dist.multihost import HostCollectives, HostShardSpec
from repro.dist.streaming import (
    BlockPlacer,
    CrossPassReader,
    PrefetchPlacer,
    resolve_prefetch,
)

_NEG_INF = float("-inf")

# Warm accumulate cache: one jitted accumulate per (score × mesh layout ×
# block shape × candidate-batch width).  A fresh ``jax.jit`` every fit
# would recompile the whole per-block step each time; keeping the wrapper
# keyed by the placed geometry means repeat streamed fits (the selection
# service's steady state) pay zero compile after the first.
_ACC_FN_CACHE = WarmJitCache(capacity=32)


def _cached_acc_fn(
    score: ScoreFn,
    placer: BlockPlacer,
    mesh: Mesh | None,
    num_edges: int | None = None,
    batch: int | None = None,
):
    """The jitted per-block accumulate.

    ``batch=None`` is the classic single-target step.  ``batch=q`` vmaps
    the *same* accumulate over a leading candidate axis — state leaves
    ``(q, N, ...)``, targets ``(q, B)``, the block shared — so each slice
    runs the identical per-target arithmetic as the unbatched step
    (contingency counts are exact integers; selections stay bitwise).
    """
    key = (
        "acc_fn", score, mesh, placer.block_obs, placer.padded_features,
        placer.obs_axes, placer.feat_axes, num_edges, batch,
    )

    def build():
        # Pin the state layout (feature-sharded in the wide regime) through
        # the compiled accumulate, so XLA never gathers the per-pair
        # statistics.
        state0 = score.init_state(
            placer.padded_features, "class" if batch is None else "feature"
        )
        if batch is not None:
            state0 = jax.tree.map(
                lambda leaf: jnp.zeros(
                    (batch,) + jnp.asarray(leaf).shape, jnp.asarray(leaf).dtype
                ),
                state0,
            )
        shardings = placer.state_shardings(state0)
        step = (
            score.accumulate
            if batch is None
            else jax.vmap(score.accumulate, in_axes=(0, None, 0, None))
        )
        if num_edges is None:
            return jax.jit(step, out_shardings=shardings)

        from repro.kernels import ops  # lazy: avoids core<->kernels cycle

        use_pallas = getattr(score, "use_pallas", "auto")

        # Fused binned accumulate: the raw float block encodes to bin codes
        # on device (Pallas/jnp searchsorted) feeding straight into the
        # one-hot contingency sum — no int block round-trips through host
        # memory.  Edges ride as a traced argument, so this compiles once
        # per geometry, not per fitted-edge content.
        def fused(state, X_block, target, valid, edges):
            codes = ops.bin_codes(X_block, edges, use_pallas=use_pallas)
            return step(state, codes, target, valid)

        return jax.jit(fused, out_shardings=shardings)

    return _ACC_FN_CACHE.get_or_build(key, build)


def _placed_edges(edges: np.ndarray, placer: BlockPlacer):
    """Land fitted bin edges (N, E) padded to the placer's feature extent
    and sharded to match the block columns.  Pad rows are +inf so a padded
    feature's codes stay 0 (its statistics rows are sliced off anyway)."""
    e = np.asarray(edges, np.float32)
    pad = placer.padded_features - e.shape[0]
    if pad:
        e = np.concatenate(
            [e, np.full((pad, e.shape[1]), np.inf, np.float32)]
        )
    if placer.mesh is not None:
        spec = P(placer.feat_axes if placer.feat_axes else None, None)
        return jax.device_put(e, NamedSharding(placer.mesh, spec))
    return jnp.asarray(e)


def acc_fn_cache_stats() -> dict:
    """Hit/miss/eviction counters of the warm accumulate cache."""
    return _ACC_FN_CACHE.stats()


def clear_acc_fn_cache() -> None:
    """Drop every warmed accumulate fn (tests; frees executables)."""
    _ACC_FN_CACHE.clear()


def _extract_target(
    X_blk: np.ndarray,
    y_blk: np.ndarray,
    target_cols,
    binner,
    cond_classes: int | None = None,
):
    """The pass target from one raw host block: the class (``None``), one
    feature column (int -> ``(B,)``) or a batch of candidate columns
    (sequence -> ``(q, B)``).  With a ``binner`` the block is raw float32
    and each target column encodes through the same f32 ``searchsorted``
    the device kernel runs, so host and device codes agree bitwise.

    ``cond_classes`` marks a class-conditioned redundancy pass (JMI/CMIM):
    each extracted column fuses with the class labels into one code
    ``col * cond_classes + label`` — the host-side twin of
    :func:`repro.core.contingency.fuse_targets`, feeding the same
    accumulate with a ``num_values * cond_classes``-wide one-hot."""
    if target_cols is None:
        return _as_class_labels(y_blk) if binner is not None else y_blk
    labels = None
    if cond_classes is not None:
        labels = (
            _as_class_labels(y_blk) if binner is not None else y_blk
        ).astype(np.int64)

    def column(c):
        c = int(c)
        col = (
            binner.encode_column(c, X_blk[:, c])
            if binner is not None
            else X_blk[:, c]
        )
        if labels is None:
            return col
        return (col.astype(np.int64) * cond_classes + labels).astype(np.int32)

    if np.ndim(target_cols) == 0:
        return column(target_cols)
    cols = [column(c) for c in target_cols]
    return np.ascontiguousarray(np.stack(cols))


class _PassIO:
    """Per-fit I/O ledger: every pass/block/byte the engine consumes,
    plus the peak statistics-state footprint (``state_bytes`` — how the
    conditional-criterion memory tax is asserted, not eyeballed)."""

    def __init__(self):
        self.passes = 0
        self.blocks_read = 0
        self.bytes_read = 0
        self.state_bytes = 0

    def count(self, raw_blocks):
        for X_blk, y_blk in raw_blocks:
            self.blocks_read += 1
            self.bytes_read += X_blk.nbytes + y_blk.nbytes
            yield X_blk, y_blk

    def note_state(self, state):
        size = sum(leaf.nbytes for leaf in jax.tree.leaves(state))
        self.state_bytes = max(self.state_bytes, size)

    def as_dict(self) -> dict:
        return dict(
            passes=self.passes,
            blocks_read=self.blocks_read,
            bytes_read=self.bytes_read,
            state_bytes=self.state_bytes,
        )


def _score_pass(
    raw_pass,
    source: DataSource,
    score: ScoreFn,
    acc_fn,
    placer: BlockPlacer,
    target_cols,
    prefetch: int,
    io: _PassIO,
    binned: "BinnedSource | None" = None,
    batch: int | None = None,
    conditional: bool = False,
    merge_state=None,
    keep: int | None = None,
):
    """One full map-reduce pass over ``raw_pass`` (an ``(X, y)`` raw host
    block iterator): ``(N,)`` scores of every feature against the class
    (``target_cols=None``) / one column (int), or ``(q, N)`` scores
    against a batch of candidate columns (sequence of length ``q``).

    ``conditional=True`` (JMI/CMIM redundancy passes) fuses the class into
    the target codes and returns ``dict(marginal=..., conditional=...)``
    arrays instead — both terms from the ONE counting sweep.

    ``merge_state`` is the multi-host reduce hook: applied to the fully
    accumulated state *before* finalize (a cross-process psum of exact
    integer counts), so finalisation runs on the merged statistics
    exactly as if one process had counted every block.  ``keep``
    overrides how many leading feature rows survive the padding slice
    (default: the source's full width; a column-sharded host keeps only
    its own columns, dropping appended target columns too)."""
    io.passes += 1
    binner = binned.binner if binned is not None else None
    cond = conditional and target_cols is not None
    kind = (
        "class"
        if target_cols is None
        else ("feature_cond" if cond else "feature")
    )
    if batch is None:
        state = score.init_state(placer.padded_features, kind)
    else:
        state = jax.tree.map(
            lambda leaf: jnp.zeros(
                (batch,) + jnp.asarray(leaf).shape, jnp.asarray(leaf).dtype
            ),
            score.init_state(placer.padded_features, kind),
        )
    state = placer.place_state(state)
    io.note_state(state)
    cond_classes = score.num_classes if cond else None

    def host_blocks():
        for X_blk, y_blk in io.count(raw_pass):
            if binner is not None:
                X_blk = np.asarray(X_blk, np.float32)
            yield X_blk, _extract_target(
                X_blk, y_blk, target_cols, binner, cond_classes
            )

    if prefetch > 0:
        placed = PrefetchPlacer(placer, depth=prefetch).stream(host_blocks())
    else:
        placed = (placer(X_blk, tgt) for X_blk, tgt in host_blocks())
    for triple in placed:
        state = acc_fn(state, *triple)
    if merge_state is not None:
        state = merge_state(state)
    # Drop feature-padding columns on every read.
    n = source.num_features if keep is None else int(keep)
    if cond:
        fin = (
            score.finalize_conditional
            if batch is None
            else jax.vmap(score.finalize_conditional)
        )
        terms = {k: np.asarray(v, np.float32) for k, v in fin(state).items()}
        if batch is None:
            return {k: v[:n] for k, v in terms.items()}
        return {k: v[:, :n] for k, v in terms.items()}
    if batch is None:
        scores = np.asarray(score.finalize(state), np.float32)
        return scores[:n]
    scores = np.asarray(jax.vmap(score.finalize)(state), np.float32)
    return scores[:, :n]


def _greedy_select(run_pass, crit: Criterion, n: int, num_select: int, q: int):
    """The host-driven greedy loop shared by the single- and multi-host
    fits: one relevance pass, then exact per-pick criterion folds with
    ``q``-wide redundancy speculation.  ``run_pass(target_cols, batch=)``
    hides where blocks come from and how per-host statistics merge — by
    the time a vector reaches this loop every participating host holds
    the identical full-width copy, so every host commits the identical
    pick with no designated master."""
    rel = run_pass(None)
    rel_j = jnp.asarray(rel)
    cstate = crit.init_state(n)
    mask = np.zeros((n,), bool)
    selected = np.full((num_select,), -1, np.int32)
    gains = np.zeros((num_select,), np.float32)
    # Speculated redundancy vectors by feature id: a vector is a pure
    # pairwise property of the data, so once computed it stays valid
    # for the whole fit (an in-batch pick never invalidates it).
    pending: dict = {}
    for l in range(num_select):
        # The criterion fold is the same pure-f32 jnp math the device
        # drivers trace, so argmax ties resolve identically to the
        # in-memory engines (toward the lowest id).
        g = np.array(crit.objective(rel_j, cstate, l), np.float32)
        g[mask] = _NEG_INF
        k = int(np.argmax(g))
        selected[l], gains[l] = k, g[k]
        mask[k] = True
        if l + 1 >= num_select or not crit.needs_redundancy:
            continue
        if k in pending:
            red = pending.pop(k)  # speculation hit: zero I/O
        else:
            if q == 1:
                red = run_pass(k)
            else:
                # One sweep scores the needed column plus the top
                # q-1 remaining candidates by the CURRENT objective —
                # the same lazy-greedy bet that objectives shift
                # slowly between folds.  Short batches pad by
                # repeating the last column so the accumulate keeps
                # one compiled shape per q.
                cols = [k]
                for j in np.argsort(-g, kind="stable"):
                    if len(cols) == q:
                        break
                    j = int(j)
                    if mask[j] or j in pending or g[j] == _NEG_INF:
                        continue
                    cols.append(j)
                padded = cols + [cols[-1]] * (q - len(cols))
                reds = run_pass(padded, batch=q)
                for i, c in enumerate(cols):
                    pending[c] = (
                        {k2: v[i] for k2, v in reds.items()}
                        if isinstance(reds, dict)
                        else reds[i]
                    )
                red = pending.pop(k)
        terms = (
            {k2: jnp.asarray(v) for k2, v in red.items()}
            if isinstance(red, dict)
            else jnp.asarray(red)
        )
        cstate = crit.update(cstate, terms, l)
    return rel, selected, gains


def mrmr_streaming(
    source,
    num_select: int,
    score: ScoreFn,
    *,
    block_obs: int = 65536,
    mesh: Mesh | None = None,
    obs_axes=("data",),
    feat_axes=(),
    prefetch="auto",
    criterion: Criterion | str = "mid",
    batch_candidates: int = 1,
    spill_dir: str | None = None,
    spill_budget_bytes: int | None = None,
    readahead: int = 0,
    shards: "HostShardSpec | None" = None,
    collectives: "HostCollectives | None" = None,
) -> MRMRResult:
    """Greedy mRMR over a :class:`~repro.data.sources.DataSource`.

    Args:
      source: a ``DataSource`` (or an ``(X, y)`` pair to wrap).
      num_select: L, number of features to pick.
      score: a streaming-capable ``ScoreFn`` (``supports_streaming``).
      block_obs: observations per device block — the peak-memory knob
        (rounded up to the mesh's observation extent).
      mesh / obs_axes / feat_axes: shard each block over the observation
        axes, the feature axes, or both (the 2-D grid).  Feature sharding
        also shards the statistics state, the wide-regime memory wall;
        observation sharding reduces statistics with one all-reduce per
        block, the paper's reducer on the ICI ring.
      prefetch: host blocks to read/pad/place ahead of device
        accumulation (0 = synchronous placement; ``"auto"`` resolves per
        backend, see :func:`~repro.dist.streaming.resolve_prefetch`).
      criterion: greedy objective — a name (``"mid"``/``"miq"``/
        ``"maxrel"``/``"jmi"``/``"cmim"``) or
        :class:`~repro.core.criteria.Criterion`.  The fold runs on the
        same (N,)-sized vectors the in-memory engines fold, so
        selections agree engine-for-engine per criterion.  Conditional
        criteria (``jmi``/``cmim``) require an :class:`~repro.core.
        scores.MIScore` (or any score with a conditional decomposition).
      batch_candidates: redundancy vectors speculated per pass (``q``).
        1 reproduces the classic one-pass-per-pick loop; ``q > 1`` cuts
        redundancy passes toward ``⌈(L-1)/q⌉`` at ``q×`` the statistics
        memory and identical selections.
      spill_dir: directory for the encoded-block spill cache — pass 1
        writes parsed/encoded blocks, passes 2..L replay them memmapped
        (zero parse, zero re-encode).  ``spill_budget_bytes`` bounds the
        directory LRU-wise.
      readahead: raw blocks the cross-pass reader streams ahead of the
        consumer, across pass boundaries (0 = off).  Supersedes
        ``prefetch`` when positive.
      shards: a :class:`~repro.dist.multihost.HostShardSpec` placing this
        process on the cross-host grid — the fit then reads ONLY this
        host's block/column ranges and merges per-pass statistics with
        explicit collectives (see :func:`_mrmr_streaming_multihost`).
        ``None`` or a single-host spec runs today's one-process path.
      collectives: a pre-built :class:`~repro.dist.multihost.
        HostCollectives` for ``shards`` (built on demand when omitted).
    """
    crit = resolve_criterion(criterion)
    source = as_source(*source) if isinstance(source, tuple) else as_source(source)
    if not score.supports_streaming:
        raise ValueError(
            f"{type(score).__name__} cannot stream: it has no "
            "sufficient-statistics decomposition (init_state/accumulate/"
            "finalize). Materialise the data and use an in-memory engine."
        )
    # JMI/CMIM need class-conditioned pair statistics; fail before any
    # I/O if the score can't produce them.  Non-conditional criteria keep
    # the exact pre-refactor pass shapes and state bytes.
    check_conditional_support(score, crit)
    needs_cond = crit.needs_redundancy and crit.needs_conditional_redundancy
    n = source.num_features
    check_num_select(num_select, n)
    prefetch = resolve_prefetch(prefetch)
    q = int(batch_candidates)
    if q < 1:
        raise ValueError(f"batch_candidates must be >= 1, got {q}")
    if readahead < 0:
        raise ValueError(f"readahead must be >= 0, got {readahead}")

    if shards is not None and not shards.is_single_host:
        return _mrmr_streaming_multihost(
            source,
            num_select,
            score,
            spec=shards,
            coll=collectives,
            block_obs=block_obs,
            mesh=mesh,
            obs_axes=obs_axes,
            feat_axes=feat_axes,
            prefetch=prefetch,
            crit=crit,
            q=q,
            spill_dir=spill_dir,
            spill_budget_bytes=spill_budget_bytes,
            readahead=readahead,
        )

    # A caller-wrapped BlockCacheSource reports its counters on the result
    # the same as an engine-built one.
    spill: BlockCacheSource | None = (
        source if isinstance(source, BlockCacheSource) else None
    )
    if spill_dir is not None:
        # The cache sits post parse/encode: wrapping a BinnedSource spills
        # its int codes, so replay passes skip the bin encode too (the
        # device-side fused encode is deliberately bypassed — encoding
        # happens exactly once, on the staging pass).
        spill = BlockCacheSource(
            source, spill_dir, budget_bytes=spill_budget_bytes
        )
        source = spill

    placer = BlockPlacer(block_obs, mesh, obs_axes, feat_axes, num_features=n)

    # A BinnedSource scoring discrete MI streams FUSED: raw float blocks
    # go to the device and are encoded there (Pallas searchsorted on TPU,
    # jnp elsewhere) directly ahead of the contingency sum.  The sketch
    # pass (memoised by fingerprint) happens here, before the first
    # scoring pass.  Any other score falls back to host-side encoding
    # through the wrapper's normal iter_blocks.
    binned = (
        source
        if isinstance(source, BinnedSource) and isinstance(score, MIScore)
        else None
    )
    num_edges = None
    if binned is not None:
        edges = binned.binner.edges_
        num_edges = edges.shape[1]
        edges_dev = _placed_edges(edges, placer)

        def _wrap(base_fn):
            return lambda state, X_block, target, valid: base_fn(
                state, X_block, target, valid, edges_dev
            )

        acc_fn = _wrap(_cached_acc_fn(score, placer, mesh, num_edges=num_edges))
        acc_fn_q = (
            _wrap(
                _cached_acc_fn(
                    score, placer, mesh, num_edges=num_edges, batch=q
                )
            )
            if q > 1
            else None
        )
    else:
        acc_fn = _cached_acc_fn(score, placer, mesh)
        acc_fn_q = _cached_acc_fn(score, placer, mesh, batch=q) if q > 1 else None

    # Raw block production: the fused binned path streams the *base*
    # source's float blocks (the device encodes them); everything else —
    # including a spill-cached binned source, whose cache already holds
    # the codes — streams the source itself.
    block_src = binned.base if binned is not None else source
    io = _PassIO()
    reader: CrossPassReader | None = None
    if readahead > 0:
        # Upper bound on passes; batching/speculation only lowers it, and
        # close() stops the reader thread wherever the fit actually ends.
        max_passes = num_select if crit.needs_redundancy else 1
        reader = CrossPassReader(
            lambda: block_src.iter_blocks(placer.block_obs),
            depth=readahead,
            max_passes=max_passes,
        )
        next_raw = reader.next_pass
        prefetch = 0  # the reader thread is the producer; stage at consume
    else:
        next_raw = lambda: block_src.iter_blocks(placer.block_obs)

    def run_pass(target_cols, batch=None):
        return _score_pass(
            next_raw(), source, score, acc_fn if batch is None else acc_fn_q,
            placer, target_cols, prefetch, io, binned, batch,
            conditional=needs_cond and target_cols is not None,
        )

    try:
        rel, selected, gains = _greedy_select(run_pass, crit, n, num_select, q)
    finally:
        if reader is not None:
            reader.close()
    io_report = io.as_dict()
    if spill is not None:
        io_report["cache"] = dict(spill.counters)
    return MRMRResult(
        selected=jnp.asarray(selected),
        gains=jnp.asarray(gains),
        relevance=jnp.asarray(rel),
        criterion=crit.name,
        engine="streaming",
        io=io_report,
    )


def _mrmr_streaming_multihost(
    source,
    num_select: int,
    score: ScoreFn,
    *,
    spec: HostShardSpec,
    coll: "HostCollectives | None",
    block_obs: int,
    mesh: Mesh | None,
    obs_axes,
    feat_axes,
    prefetch: int,
    crit: Criterion,
    q: int,
    spill_dir: str | None,
    spill_budget_bytes: int | None,
    readahead: int,
) -> MRMRResult:
    """The cross-host fit: this process reads ONLY its shard, the per-pass
    reduce is an explicit collective, and every host runs the identical
    greedy loop on identical merged vectors.

    The paper's two partitionings map onto the host grid exactly as they
    map onto the device mesh:

    * **tall** (``grid=(H, 1)``): each host streams its row window at
      full width and accumulates a full-width statistics state; one
      ``psum`` of the exact integer counts reconstructs the global state
      bitwise on every host before finalize — scores (hence picks) are
      identical to one process having read everything.
    * **wide** (``grid=(1, H)``): each host streams every row of its own
      column group; states never merge (each host already saw all rows).
      Finalised per-column scores scatter-``assemble`` into the full
      ``(N,)`` vector (one non-zero addend per column — float adds
      against zeros, exact).  Redundancy targets a host doesn't own ride
      as *appended columns*: a synchronous single-column shard stream
      aligned block-for-block with the main stream, so the augmented
      state is ``local_cols + t`` wide and targets always live at local
      indices ``local_cols..local_cols+t-1``.
    * **2-D grid**: both — ``psum_obs`` collapses the row partitions
      (column groups padded to the widest, zeros are the additive
      identity), then the ``obs_coord == 0`` row of hosts assembles.

    Per-host device placement still applies *within* each process
    (``mesh``/``obs_axes`` shard the local block over local devices), but
    column-partitioned regimes force ``feat_axes=()`` per host: with no
    device feature-sharding the placer's padded width equals the exact
    shard width, which is what makes cross-host state shapes align
    deterministically regardless of local device count.
    """
    n = source.num_features
    if (spec.num_obs, spec.num_features) != (source.num_obs, n):
        raise ValueError(
            f"HostShardSpec geometry {(spec.num_obs, spec.num_features)} "
            f"does not match the source {(source.num_obs, n)}"
        )
    if spec.partitions_obs and not score.supports_state_merge:
        raise ValueError(
            f"{type(score).__name__} statistics cannot merge across row "
            "partitions (supports_state_merge=False): its state is not a "
            "plain sum over blocks.  Use an MI score, or a column-only "
            "host grid (grid=(1, H)) where no state merge is needed."
        )
    if spec.partitions_cols and feat_axes:
        raise ValueError(
            "column-partitioned multi-host fits require feat_axes=() per "
            "host: device feature-sharding would pad the statistics width "
            "past the exact shard width and break cross-host alignment"
        )
    if isinstance(source, BlockCacheSource):
        raise ValueError(
            "pass spill_dir= instead of a pre-wrapped BlockCacheSource: "
            "multi-host fits spill per-host shard streams under a "
            "process-namespaced entry"
        )
    if coll is None:
        coll = HostCollectives(spec)
    needs_cond = crit.needs_redundancy and crit.needs_conditional_redundancy
    (clo, _chi) = spec.col_range
    n_local = spec.local_cols

    # Each host's block stream: ONLY its row/column windows.  Spill (when
    # asked) caches the shard stream under a per-process namespace, so
    # hosts sharing one filesystem can never race each other's chunks.
    shard_src = ShardSource(source, spec.obs_range, spec.col_range)
    stream_src: DataSource = shard_src
    spill: BlockCacheSource | None = None
    if spill_dir is not None:
        spill = BlockCacheSource(
            shard_src,
            spill_dir,
            budget_bytes=spill_budget_bytes,
            namespace=f"h{spec.host_id}",
        )
        stream_src = spill

    # Tall hosts hold every column; column-partitioned hosts size their
    # placer (and state) to the exact shard width (feat_axes=() makes
    # padded_features == num_features, asserted by the placer contract).
    width_rel = n_local if spec.partitions_cols else n
    placer_rel = BlockPlacer(
        block_obs, mesh, obs_axes, feat_axes, num_features=width_rel
    )
    eff_bo = placer_rel.block_obs
    _red_placers: dict = {}

    def red_placer(aug: int) -> BlockPlacer:
        p = _red_placers.get(aug)
        if p is None:
            p = BlockPlacer(
                block_obs, mesh, obs_axes, (), num_features=n_local + aug
            )
            _red_placers[aug] = p
        return p

    def aug_blocks(raw, cols):
        """Append each target column's codes for this host's row window
        to every raw block: owned columns slice out of the block itself,
        non-owned ones ride a synchronous single-column shard stream off
        the base source (same ``eff_bo``, same row window — aligned
        block-for-block by construction, and checked)."""
        plans, streams = [], []
        try:
            for c in cols:
                c = int(c)
                if spec.owns_col(c):
                    plans.append(("own", c - clo))
                else:
                    it = source.iter_shard_blocks(
                        eff_bo, spec.obs_range, (c, c + 1)
                    )
                    plans.append(("stream", it))
                    streams.append(it)
            for X_blk, y_blk in raw:
                X_blk = np.asarray(X_blk)
                extra = []
                for kind, v in plans:
                    if kind == "own":
                        extra.append(X_blk[:, v : v + 1])
                    else:
                        Xc, _ = next(v)
                        if Xc.shape[0] != X_blk.shape[0]:
                            raise RuntimeError(
                                "target-column stream misaligned with the "
                                f"shard stream ({Xc.shape[0]} vs "
                                f"{X_blk.shape[0]} rows)"
                            )
                        extra.append(np.asarray(Xc))
                yield np.concatenate([X_blk] + extra, axis=1), y_blk
        finally:
            for it in streams:
                close = getattr(it, "close", None)
                if close is not None:
                    close()

    io = _PassIO()
    reader: CrossPassReader | None = None
    if readahead > 0:
        max_passes = num_select if crit.needs_redundancy else 1
        reader = CrossPassReader(
            lambda: stream_src.iter_blocks(eff_bo),
            depth=readahead,
            max_passes=max_passes,
        )
        next_raw = reader.next_pass
        prefetch = 0
    else:
        next_raw = lambda: stream_src.iter_blocks(eff_bo)

    def run_pass(target_cols, batch=None):
        cond = needs_cond and target_cols is not None
        if target_cols is None or not spec.partitions_cols:
            # Relevance everywhere, and tall-regime redundancy: every
            # column is local, so global target ids index the block.
            placer, raw, local_targets, aug = (
                placer_rel, next_raw(), target_cols, 0
            )
        else:
            cols = (
                [int(target_cols)]
                if batch is None
                else [int(c) for c in target_cols]
            )
            aug = len(cols)
            placer = red_placer(aug)
            local_targets = (
                n_local
                if batch is None
                else list(range(n_local, n_local + aug))
            )
            raw = aug_blocks(next_raw(), cols)
        merge = None
        if spec.partitions_obs:
            if not spec.partitions_cols:
                merge = coll.psum
            else:
                fa = 0 if batch is None else 1
                lw, pt = n_local + aug, spec.max_col_width + aug
                merge = lambda st: coll.psum_obs(
                    st, feat_axis=fa, local_width=lw, pad_to=pt
                )
        acc = _cached_acc_fn(score, placer, mesh, batch=batch)
        res = _score_pass(
            raw, stream_src, score, acc, placer, local_targets, prefetch,
            io, None, batch, conditional=cond, merge_state=merge,
            keep=n_local if spec.partitions_cols else n,
        )
        return coll.assemble(res) if spec.partitions_cols else res

    try:
        rel, selected, gains = _greedy_select(run_pass, crit, n, num_select, q)
    finally:
        if reader is not None:
            reader.close()
    io_report = io.as_dict()
    if spill is not None:
        io_report["cache"] = dict(spill.counters)
    io_report["host"] = dict(
        id=spec.host_id,
        grid=list(spec.grid),
        obs_range=list(spec.obs_range),
        col_range=list(spec.col_range),
    )
    # Exact cross-host ledger exchange (int64 as two int32 halves — byte
    # counts must not round): per-host rows plus the cluster aggregate.
    per = coll.allgather_counts(
        [io.passes, io.blocks_read, io.bytes_read, io.state_bytes]
    )
    names = ("passes", "blocks_read", "bytes_read", "state_bytes")
    io_report["hosts"] = dict(
        grid=list(spec.grid),
        per_host=[
            {k: int(v) for k, v in zip(names, row)} for row in per
        ],
        aggregate=dict(
            # Passes run in lockstep (max == every host); the rest sum.
            passes=int(per[:, 0].max()),
            blocks_read=int(per[:, 1].sum()),
            bytes_read=int(per[:, 2].sum()),
            state_bytes=int(per[:, 3].sum()),
        ),
    )
    return MRMRResult(
        selected=jnp.asarray(selected),
        gains=jnp.asarray(gains),
        relevance=jnp.asarray(rel),
        criterion=crit.name,
        engine="streaming",
        io=io_report,
    )


@register_engine("streaming")
def _fit_streaming(source, y, *, num_select, plan, mesh) -> MRMRResult:
    del y  # targets come from the source's blocks
    shards = None
    if getattr(plan, "hosts", 1) > 1:
        from repro.dist.multihost import resolve_host_shards

        shards = resolve_host_shards(
            source.num_obs, source.num_features, plan.hosts,
            jax.process_index(),
        )
    return mrmr_streaming(
        source,
        num_select,
        plan.score,
        block_obs=plan.block_obs,
        mesh=mesh,
        obs_axes=plan.obs_axes,
        feat_axes=plan.feat_axes,
        prefetch=plan.prefetch,
        criterion=plan.criterion,
        batch_candidates=plan.batch_candidates,
        spill_dir=plan.spill_dir,
        spill_budget_bytes=plan.spill_budget_bytes,
        readahead=plan.readahead,
        shards=shards,
    )
