"""Composable greedy selection criteria — the fold the engines share.

The paper implements exactly one greedy objective, the mRMR *difference*
form (Eq. 1, §II): relevance minus mean pairwise redundancy.  But the
whole family of greedy information-theoretic criteria (mRMR/MID, MIQ,
max-relevance, JMI, CMIM, ...) shares the same distributed
relevance/redundancy primitive — the engines already compute every
sufficient statistic; the criteria differ only in how the per-candidate
redundancy terms fold into an objective (Ramírez-Gallego et al., *An
Information Theoretic Feature Selection Framework for Big Data*; Vivek &
Sai Prasad ship quotient forms on the same vertical-partitioned
machinery).  A :class:`Criterion` captures that fold as three jit-safe
pure-jnp hooks:

  * ``init_state(n)`` — zeroed per-candidate fold state for ``n``
    candidates: a pytree of ``(n,)``-leading arrays (or empty), carried
    through ``lax.fori_loop`` by the compiled engines and across passes
    by the streaming engine.
  * ``update(state, terms, l)`` — fold the redundancy terms of the
    ``l``-th selected feature (0-based) into the state.  ``terms`` is the
    generic redundancy form ``{"marginal": (n,), "conditional": (n,) |
    None}`` (what :meth:`repro.core.scores.ScoreFn.redundancy_terms`
    returns): the pairwise statistic ``f(x_k; x_j)`` of every candidate
    against the selection, and — for criteria that declare
    ``needs_conditional_redundancy`` — the same statistic conditioned on
    the class, ``f(x_k; x_j | y)``.  Use :func:`marginal_terms` /
    :func:`conditional_terms` to unpack (both also accept a bare array
    for hand-rolled folds and older custom criteria).
  * ``objective(rel, state, l)`` — ``(n,)`` per-candidate objective given
    the relevance vector and a state holding ``l`` folded selections.
    The engines mask and argmax this; the distributed argmax/psum
    structure never changes with the criterion.

Engines call the hooks from inside their compiled loops (in-memory) or
from the host-driven pass loop (streaming), so a criterion written once
runs on every engine × regime combination.  ``needs_redundancy = False``
(max-relevance) lets engines skip redundancy scoring entirely — the
streaming engine then runs ONE pass of I/O over the source instead of
``num_select`` passes.  ``needs_conditional_redundancy = True`` (JMI,
CMIM) makes every engine compute class-conditioned pair statistics —
3-way ``(candidate value, pair value, class)`` counts — alongside the
marginal ones; criteria that leave it ``False`` pay nothing: no class
axis is materialised and the streaming statistics state keeps its
marginal shape and bytes.

Register your own with :func:`register_criterion`::

    @register_criterion
    @dataclasses.dataclass(frozen=True)
    class PenalisedMID(Criterion):
        name = "mid2x"
        def init_state(self, n):
            return dict(red_sum=jnp.zeros((n,), jnp.float32))
        def update(self, state, terms, l):
            return dict(red_sum=state["red_sum"] + marginal_terms(terms))
        def objective(self, rel, state, l):
            denom = jnp.maximum(l, 1).astype(jnp.float32)
            return rel - 2.0 * state["red_sum"] / denom

    MRMRSelector(num_select=10, criterion="mid2x").fit(X, y)

Hooks must stay pure jnp (no host callbacks, no Python-level data
dependence on traced values): the in-memory engines trace them once into
``lax.fori_loop`` bodies under ``shard_map``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array

# Quotient-form floor, in nats: mean redundancy below this is treated as
# "no redundancy" and the candidate is ranked by pure relevance (rel/eps).
# This does two jobs.  (1) First pick: the empty state has mean redundancy
# 0, so iteration 0 is a relevance argmax without a divide-by-zero.
# (2) Numerical robustness: an f32 MI value carries ~1e-7 of rounding
# noise that differs between compiled-loop and host evaluation orders;
# dividing by a redundancy at that scale would rank candidates by noise
# (the classic MIQ pathology on near-independent features) and break the
# engine-for-engine selection-identity contract.  1e-4 nats is far below
# any meaningful dependence (a binary pair carries up to ln 2 ~ 0.69) and
# far above the noise floor.  Plain float, not a jnp constant (import-time
# jnp values initialise the XLA backend and lock the device count).
_QUOTIENT_EPS = 1e-4


def marginal_terms(terms) -> Array:
    """The ``(n,)`` marginal redundancy vector from a terms dict.

    Also accepts a bare array (hand-rolled folds, pre-terms custom
    criteria), so ``update`` implementations written either way work.
    """
    if isinstance(terms, dict):
        return terms["marginal"]
    return terms


def conditional_terms(terms) -> Array:
    """The ``(n,)`` class-conditioned redundancy vector from a terms dict.

    Only present when the criterion declares
    ``needs_conditional_redundancy = True`` (the engines then compute
    3-way counts); anything else fails loudly instead of folding garbage.
    """
    if isinstance(terms, dict) and terms.get("conditional") is not None:
        return terms["conditional"]
    raise ValueError(
        "redundancy terms carry no conditional component; a criterion "
        "reading conditional_terms(...) must declare "
        "needs_conditional_redundancy = True so the engines compute "
        "class-conditioned pair statistics"
    )


class Criterion:
    """A greedy selection objective as a jit-safe pure-jnp fold.

    Subclasses set ``name`` (the registry key, reported in
    ``MRMRResult.criterion``) and implement the three hooks below.
    ``needs_redundancy = False`` declares that ``objective`` never reads
    the fold state; engines then skip redundancy scoring entirely
    (streaming: one I/O pass instead of ``num_select``).
    ``needs_conditional_redundancy = True`` makes the engines deliver
    class-conditioned pair statistics in ``terms["conditional"]`` (the
    score must support them — :class:`~repro.core.scores.MIScore` does);
    leaving it ``False`` keeps the marginal-only fast path: no class
    axis, no extra statistics memory or I/O.
    """

    name: str = ""
    needs_redundancy: bool = True
    needs_conditional_redundancy: bool = False

    def init_state(self, n: int):
        """Zeroed fold state for ``n`` candidate features (a pytree)."""
        raise NotImplementedError

    def update(self, state, terms, l):
        """Fold selection ``l``'s redundancy ``terms`` (0-based; see
        :func:`marginal_terms` / :func:`conditional_terms`)."""
        raise NotImplementedError

    def objective(self, rel: Array, state, l) -> Array:
        """``(n,)`` objective after ``l`` selections have been folded."""
        raise NotImplementedError


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_CRITERIA: dict = {}


def register_criterion(criterion, name: str | None = None):
    """Register a :class:`Criterion` under its ``name`` (or ``name=``).

    Accepts an instance or a zero-arg class (usable as a class decorator);
    returns its argument unchanged.  Later registrations of the same name
    win, mirroring :func:`repro.core.selector.register_engine`.
    """
    crit = criterion() if isinstance(criterion, type) else criterion
    key = name or crit.name
    if not key:
        raise ValueError("criterion has no name; set .name or pass name=")
    if crit.name != key:
        # Keep provenance (MRMRResult.criterion) in sync with the registry
        # key; object.__setattr__ also reaches frozen-dataclass instances.
        object.__setattr__(crit, "name", key)
    _CRITERIA[key] = crit
    return criterion


def resolve_criterion(criterion) -> Criterion:
    """Name or instance -> Criterion instance (None -> the paper's mid)."""
    if criterion is None:
        return _CRITERIA["mid"]
    if isinstance(criterion, Criterion):
        return criterion
    try:
        return _CRITERIA[criterion]
    except (KeyError, TypeError):
        raise ValueError(
            f"unknown criterion {criterion!r}; registered: "
            f"{sorted(_CRITERIA)} (register_criterion adds more)"
        ) from None


def available_criteria() -> tuple:
    return tuple(sorted(_CRITERIA))


# ---------------------------------------------------------------------------
# built-ins
# ---------------------------------------------------------------------------

@register_criterion
@dataclasses.dataclass(frozen=True)
class MIDCriterion(Criterion):
    """Mutual-information difference — the paper's mRMR objective (Eq. 1).

    ``g_k = rel_k - red_sum_k / max(l, 1)``: relevance minus mean pairwise
    redundancy against the selected set.  This reproduces the pre-criterion
    engines bit for bit: the fold is the exact ``red_sum`` running sum and
    the objective the exact expression the engine bodies used to inline.
    """

    name = "mid"

    def init_state(self, n: int):
        return dict(red_sum=jnp.zeros((n,), jnp.float32))

    def update(self, state, terms, l):
        return dict(red_sum=state["red_sum"] + marginal_terms(terms))

    def objective(self, rel: Array, state, l) -> Array:
        denom = jnp.maximum(l, 1).astype(jnp.float32)
        return rel - state["red_sum"] / denom


@register_criterion
@dataclasses.dataclass(frozen=True)
class MIQCriterion(Criterion):
    """Mutual-information quotient: ``g_k = rel_k / max(mean_red_k, eps)``.

    The quotient form of mRMR (Ding & Peng's MIQ; the criterion family's
    second classic).  Mean redundancy is floored at ``1e-4`` nats (see
    ``_QUOTIENT_EPS``): below that a candidate counts as unredundant and
    ranks by pure relevance — in particular the first pick (empty state,
    mean redundancy 0) is a relevance argmax and its reported gain is
    ``rel / 1e-4``.
    """

    name = "miq"

    def init_state(self, n: int):
        return dict(red_sum=jnp.zeros((n,), jnp.float32))

    def update(self, state, terms, l):
        return dict(red_sum=state["red_sum"] + marginal_terms(terms))

    def objective(self, rel: Array, state, l) -> Array:
        denom = jnp.maximum(l, 1).astype(jnp.float32)
        red_mean = state["red_sum"] / denom
        return rel / jnp.maximum(red_mean, jnp.float32(_QUOTIENT_EPS))


@register_criterion
@dataclasses.dataclass(frozen=True)
class MaxRelCriterion(Criterion):
    """Max-relevance baseline: ``g_k = rel_k``, no redundancy at all.

    Selects the top-``L`` features by relevance (ties toward the smaller
    feature id, like every engine's argmax).  ``needs_redundancy = False``
    lets engines drop pair scoring: the streaming engine runs a single
    relevance pass of I/O instead of ``num_select`` passes.
    """

    name = "maxrel"
    needs_redundancy = False

    def init_state(self, n: int):
        return {}

    def update(self, state, terms, l):
        return state

    def objective(self, rel: Array, state, l) -> Array:
        return rel


@register_criterion
@dataclasses.dataclass(frozen=True)
class JMICriterion(Criterion):
    """Joint mutual information (Yang & Moody; Brown et al.'s unified form).

    ``g_k = rel_k + mean_j [I(x_k; x_j | y) - I(x_k; x_j)]``: the
    class-conditioned pair term REWARDS candidates whose dependence on the
    selected set is informative about the class (complementarity), while
    the marginal term penalises plain redundancy — mRMR's penalty with the
    sign-corrected conditional completing the ITFS generic form.  The fold
    is a running sum of the per-selection gap, so streaming folds it
    incrementally exactly like ``mid`` folds ``red_sum``.
    """

    name = "jmi"
    needs_conditional_redundancy = True

    def init_state(self, n: int):
        return dict(gap_sum=jnp.zeros((n,), jnp.float32))

    def update(self, state, terms, l):
        gap = conditional_terms(terms) - marginal_terms(terms)
        return dict(gap_sum=state["gap_sum"] + gap)

    def objective(self, rel: Array, state, l) -> Array:
        denom = jnp.maximum(l, 1).astype(jnp.float32)
        return rel + state["gap_sum"] / denom


@register_criterion
@dataclasses.dataclass(frozen=True)
class CMIMCriterion(Criterion):
    """Conditional mutual information maximisation (Fleuret 2004).

    ``g_k = min_j I(x_k; y | x_j)`` over the selected set — pick the
    candidate whose WORST-case usefulness given any single already-selected
    feature is best (max of min).  By the chain rule ``I(x_k; y | x_j) =
    rel_k + I(x_k; x_j | y) - I(x_k; x_j)``, so the fold is a running
    *min* of the per-selection gap (the registry's min-fold, exercised by
    no other built-in): state starts at ``+inf``, and with an empty
    selected set the objective is pure relevance.  Ties argmax toward the
    smallest feature id like every engine.
    """

    name = "cmim"
    needs_conditional_redundancy = True

    def init_state(self, n: int):
        # +inf identity of the min-fold; objective guards l == 0, so the
        # infinity never reaches a reported gain.
        return dict(worst_gap=jnp.full((n,), jnp.inf, jnp.float32))

    def update(self, state, terms, l):
        gap = conditional_terms(terms) - marginal_terms(terms)
        return dict(worst_gap=jnp.minimum(state["worst_gap"], gap))

    def objective(self, rel: Array, state, l) -> Array:
        # rel + inf stays inf (never NaN: rel is finite MI), so the where
        # cleanly selects pure relevance for the first pick.
        return jnp.where(jnp.asarray(l) == 0, rel, rel + state["worst_gap"])


@register_criterion
@dataclasses.dataclass(frozen=True)
class MIFSCriterion(Criterion):
    """Mutual information feature selection (Battiti 1994, ``β = 1``).

    ``g_k = rel_k - Σ_j I(x_k; x_j)``: relevance minus the *summed* (not
    mean) pairwise redundancy — the original ITFS penalty that mRMR later
    normalised by the selection size.  The un-normalised sum makes the
    penalty grow with every pick, so MIFS turns conservative late in a
    fit where ``mid`` keeps trading; both share the exact ``red_sum``
    fold, so MIFS costs nothing the engines don't already compute.
    """

    name = "mifs"

    def init_state(self, n: int):
        return dict(red_sum=jnp.zeros((n,), jnp.float32))

    def update(self, state, terms, l):
        return dict(red_sum=state["red_sum"] + marginal_terms(terms))

    def objective(self, rel: Array, state, l) -> Array:
        return rel - state["red_sum"]


@register_criterion
@dataclasses.dataclass(frozen=True)
class CIFECriterion(Criterion):
    """Conditional infomax feature extraction (Lin & Tang 2006).

    ``g_k = rel_k + Σ_j [I(x_k; x_j | y) - I(x_k; x_j)]`` — JMI's
    complementarity gap, but *summed* rather than averaged (in Brown et
    al.'s unified form: ``β = γ = 1``).  Rewards candidates whose
    dependence on the selected set is class-informative at full weight,
    so redundancy penalties and synergy bonuses both scale with the
    selection size.  Same running ``gap_sum`` fold as JMI; only the
    normalisation differs.
    """

    name = "cife"
    needs_conditional_redundancy = True

    def init_state(self, n: int):
        return dict(gap_sum=jnp.zeros((n,), jnp.float32))

    def update(self, state, terms, l):
        gap = conditional_terms(terms) - marginal_terms(terms)
        return dict(gap_sum=state["gap_sum"] + gap)

    def objective(self, rel: Array, state, l) -> Array:
        return rel + state["gap_sum"]


@register_criterion
@dataclasses.dataclass(frozen=True)
class ICAPCriterion(Criterion):
    """Interaction capping (Jakulin 2005).

    ``g_k = rel_k - Σ_j max(0, I(x_k; x_j) - I(x_k; x_j | y))``: penalise
    only the part of each pairwise dependence the class does NOT explain,
    and never reward synergy — the interaction term is capped at zero, so
    ICAP sits between mRMR (which penalises all dependence) and CIFE
    (which lets synergy offset redundancy without bound).  The fold is a
    running sum of the clipped per-selection term.
    """

    name = "icap"
    needs_conditional_redundancy = True

    def init_state(self, n: int):
        return dict(cap_sum=jnp.zeros((n,), jnp.float32))

    def update(self, state, terms, l):
        capped = jnp.maximum(
            marginal_terms(terms) - conditional_terms(terms), 0.0
        )
        return dict(cap_sum=state["cap_sum"] + capped)

    def objective(self, rel: Array, state, l) -> Array:
        return rel - state["cap_sum"]


__all__ = [
    "CIFECriterion",
    "CMIMCriterion",
    "Criterion",
    "ICAPCriterion",
    "JMICriterion",
    "MIDCriterion",
    "MIFSCriterion",
    "MIQCriterion",
    "MaxRelCriterion",
    "available_criteria",
    "conditional_terms",
    "marginal_terms",
    "register_criterion",
    "resolve_criterion",
]
