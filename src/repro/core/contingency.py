"""Contingency-table math — the paper's mapper/combiner payload, in JAX.

In the paper's conventional encoding every mapper emits, per observation and
per (candidate, target) pair, a one-hot contingency table; the combiner and
reducer element-wise sum them (Tables IV/V).  On TPU the whole
map+combine+reduce collapses into a *one-hot matmul*:

    counts[f, v, c] = sum_m  onehot(X[m, f])[v] * onehot(y[m])[c]

i.e. an einsum that runs on the MXU.  This module is the pure-jnp
implementation (and the oracle for ``repro.kernels.contingency``); the
feature axis is processed in blocks so the one-hot expansion never
materialises at full (M, F, V) size.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

Array = jax.Array

# Out-of-range sentinel for fused targets: one-hots to an all-zero row, so
# invalid (padded / masked) observations vanish from the counts.  Plain int,
# not a jnp constant (import-time jnp would initialise the XLA backend).
_OOR = 2**31 - 1


def _onehot(x: Array, depth: int, dtype=jnp.float32) -> Array:
    """One-hot along a new trailing axis. Out-of-range values map to zeros."""
    iota = jnp.arange(depth, dtype=jnp.int32)
    return (x[..., None] == iota).astype(dtype)


def pair_counts(x: Array, y: Array, vx: int, vy: int, dtype=jnp.float32) -> Array:
    """Contingency table of a single feature column against a target column.

    Args:
      x: (M,) int — feature values in [0, vx).
      y: (M,) int — target values in [0, vy).
    Returns:
      (vx, vy) counts.
    """
    return jnp.einsum("mv,mc->vc", _onehot(x, vx, dtype), _onehot(y, vy, dtype))


def batched_counts(
    X: Array,
    y: Array,
    vx: int,
    vy: int,
    *,
    block: int = 64,
    dtype=jnp.float32,
    onehot_dtype=jnp.bfloat16,
) -> Array:
    """Contingency tables of every column of ``X`` against ``y``.

    This is the fused map+combine step of the paper's conventional-encoding
    job for one scoring pass: each (feature, target) pair's table in one
    batched einsum.

    Args:
      X: (M, F) int — feature matrix (discrete values in [0, vx)).
      y: (M,) int — target values in [0, vy).
      block: feature-block size; the (M, block, vx) one-hot is the largest
        intermediate.
    Returns:
      (F, vx, vy) counts, dtype ``dtype``.
    """
    M, F = X.shape
    # One-hots hold only {0,1}: bf16 operands are exact, and the MXU matmul
    # accumulates in f32 (preferred_element_type), so counts stay exact up
    # to 2^24 rows/shard while the materialised one-hot traffic halves
    # (§Perf cell C iteration 2).
    y_oh = _onehot(y, vy, onehot_dtype)  # (M, vy)

    pad = (-F) % block
    if pad:
        # Padded feature columns contribute garbage tables that are sliced off.
        X = jnp.pad(X, ((0, 0), (0, pad)))
    nblk = (F + pad) // block
    Xb = X.reshape(M, nblk, block).transpose(1, 0, 2)  # (nblk, M, block)

    def one_block(xb: Array) -> Array:
        x_oh = _onehot(xb, vx, onehot_dtype)  # (M, block, vx)
        return jnp.einsum(
            "mfv,mc->fvc", x_oh, y_oh, preferred_element_type=jnp.float32
        ).astype(dtype)

    out = jax.lax.map(one_block, Xb)  # (nblk, block, vx, vy)
    out = out.reshape(nblk * block, vx, vy)
    return out[:F]


def counts_with_column(
    X: Array, xj: Array, v: int, *, block: int = 64, dtype=jnp.float32
) -> Array:
    """Tables of every column of X against one feature column (both < v)."""
    return batched_counts(X, xj, v, v, block=block, dtype=dtype)


# ---------------------------------------------------------------------------
# class-conditioned pair counts (JMI / CMIM redundancy statistics)
# ---------------------------------------------------------------------------

def fuse_targets(other: Array, cls: Array, vy: int, num_classes: int) -> Array:
    """Fuse a target column with the class column into one code.

    ``code = other * num_classes + cls`` lands in ``[0, vy * num_classes)``
    exactly when both inputs are in range; any out-of-range input (padding
    sentinels, negatives) maps to the out-of-range sentinel, so fused
    padding vanishes from one-hot counts just like unfused padding.  The
    guard also prevents int32 wraparound of ``sentinel * num_classes``
    from aliasing back into the valid code range.
    """
    o = other.astype(jnp.int32)
    c = cls.astype(jnp.int32)
    ok = (o >= 0) & (o < vy) & (c >= 0) & (c < num_classes)
    return jnp.where(ok, o * num_classes + c, jnp.int32(_OOR))


def conditional_counts(
    X: Array,
    xj: Array,
    y: Array,
    vx: int,
    vy: int,
    num_classes: int,
    *,
    block: int = 64,
    dtype=jnp.float32,
    onehot_dtype=jnp.bfloat16,
) -> Array:
    """3-way counts of every column of ``X`` against ``(xj, y)`` jointly.

    The class axis rides *fused into the target*: ``counts[f, v, w, c]``
    is computed as an ordinary pair count of ``X`` against the code
    ``xj * num_classes + y`` with ``vy * num_classes`` target values, then
    unflattened — so the blocked one-hot einsum (and the Pallas tiling
    that mirrors it) is reused unchanged, no 3-way kernel needed.

    Args:
      X: (M, F) int — feature matrix, values in [0, vx).
      xj: (M,) int — the pair target column, values in [0, vy).
      y: (M,) int — class labels in [0, num_classes).
    Returns:
      (F, vx, vy, num_classes) counts: ``sum(-1)`` is the marginal pair
      table, each ``[..., c]`` slice the within-class pair table.
    """
    fused = fuse_targets(xj, y, vy, num_classes)
    cnt = batched_counts(
        X, fused, vx, vy * num_classes,
        block=block, dtype=dtype, onehot_dtype=onehot_dtype,
    )
    return cnt.reshape(cnt.shape[0], vx, vy, num_classes)


@functools.partial(jax.jit, static_argnames=("vx", "vy"))
def pair_counts_jit(x: Array, y: Array, vx: int, vy: int) -> Array:
    return pair_counts(x, y, vx, vy)
