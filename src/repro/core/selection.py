"""Legacy selection API — thin wrappers over :mod:`repro.core.selector`.

``FeatureSelector`` / ``mrmr_select`` predate the unified ``MRMRSelector``
front door and are kept as a compatibility surface: same fields, same
``layout=`` vocabulary, same results.  New code should use
``repro.MRMRSelector`` directly — it adds auto device planning
(``plan_selection``), an inspectable ``SelectionPlan``, and an engine
registry open to new encodings.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.mrmr import MRMRResult
from repro.core.scores import MIScore, PearsonMIScore, ScoreFn
from repro.core.selector import MRMRSelector

Array = jax.Array


def infer_layout(n_obs: int, n_feat: int) -> str:
    """Paper §III: tall/narrow -> conventional, short/wide -> alternative."""
    return "conventional" if n_obs >= n_feat else "alternative"


@dataclasses.dataclass
class FeatureSelector:
    """mRMR feature selection with the paper's two encodings (+grid).

    Compatibility alias of :class:`repro.core.selector.MRMRSelector`:
    ``layout`` maps onto ``encoding`` ("auto" resolves with the original
    shape rule — grid only when requested explicitly).
    """

    num_select: int
    score: ScoreFn | None = None
    layout: str = "auto"
    mesh: Mesh | None = None
    obs_axes: Sequence[str] | str = ("data",)
    feat_axes: Sequence[str] | str = ("model",)
    incremental: bool = True
    block: int = 64

    selected_: np.ndarray | None = None
    gains_: np.ndarray | None = None

    def _encoding_for(self, X: Array) -> str:
        if self.layout != "auto":
            return self.layout
        m, n = X.shape
        discrete = jnp.issubdtype(X.dtype, jnp.integer) or X.dtype == jnp.bool_
        return infer_layout(m, n) if discrete else "alternative"

    def fit(self, X, y) -> "FeatureSelector":
        """X: (observations, features) — conventional orientation; y: (obs,)."""
        X = jnp.asarray(X)
        sel = MRMRSelector(
            num_select=self.num_select, score=self.score,
            encoding=self._encoding_for(X), mesh=self.mesh,
            obs_axes=self.obs_axes, feat_axes=self.feat_axes,
            incremental=self.incremental, block=self.block,
        ).fit(X, y)
        self.selected_ = sel.selected_
        self.gains_ = sel.gains_
        return self

    def transform(self, X):
        if self.selected_ is None:
            raise RuntimeError("fit() first")
        return np.asarray(X)[:, self.selected_]

    def fit_transform(self, X, y):
        return self.fit(X, y).transform(X)


def mrmr_select(
    X,
    y,
    num_select: int,
    *,
    score: ScoreFn | None = None,
    layout: str = "auto",
    mesh: Mesh | None = None,
    obs_axes=("data",),
    feat_axes=("model",),
    incremental: bool = True,
) -> MRMRResult:
    """One-call mRMR. See :class:`FeatureSelector`."""
    sel = FeatureSelector(
        num_select=num_select, score=score, layout=layout, mesh=mesh,
        obs_axes=obs_axes, feat_axes=feat_axes, incremental=incremental,
    )
    sel.fit(X, y)
    return MRMRResult(
        selected=jnp.asarray(sel.selected_), gains=jnp.asarray(sel.gains_)
    )


__all__ = [
    "FeatureSelector",
    "mrmr_select",
    "MIScore",
    "PearsonMIScore",
    "infer_layout",
]
