"""Public feature-selection API: ``FeatureSelector`` / ``mrmr_select``.

Handles the practicalities the drivers don't: layout choice (the paper's
T/N vs S/W distinction, §III), padding to mesh divisibility (padded
observations use out-of-range category values so their one-hot contingency
contribution is zero; padded features are masked out of the argmax), and
host-side conveniences.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import mrmr as mrmr_mod
from repro.core.mrmr import MRMRResult
from repro.core.scores import MIScore, PearsonMIScore, ScoreFn

Array = jax.Array


def _mesh_extent(mesh: Mesh | None, axes) -> int:
    if mesh is None:
        return 1
    axes = mrmr_mod._axes_tuple(axes)
    ext = 1
    for a in axes:
        ext *= mesh.shape[a]
    return ext


def _pad_axis(x, axis: int, multiple: int, fill):
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def infer_layout(n_obs: int, n_feat: int) -> str:
    """Paper §III: tall/narrow -> conventional, short/wide -> alternative."""
    return "conventional" if n_obs >= n_feat else "alternative"


@dataclasses.dataclass
class FeatureSelector:
    """mRMR feature selection with the paper's two encodings (+grid).

    Args:
      num_select: L, number of features to pick.
      score: a ``ScoreFn`` (default: exact discrete MI, as the paper).
      layout: "auto" | "conventional" | "alternative" | "grid".
        Inputs are ALWAYS given in conventional orientation (observations ×
        features); layout selects the distribution strategy (and, for
        "alternative", the transposed storage) per paper §III.
      mesh: device mesh (None = single device).
      obs_axes / feat_axes: mesh axes for observation / feature sharding.
      incremental: False reproduces the paper's per-iteration redundancy
        recomputation; True enables the O(N·L) running-sum optimisation
        (identical selections, validated by tests).
    """

    num_select: int
    score: ScoreFn | None = None
    layout: str = "auto"
    mesh: Mesh | None = None
    obs_axes: Sequence[str] | str = ("data",)
    feat_axes: Sequence[str] | str = ("model",)
    incremental: bool = True
    block: int = 64

    selected_: np.ndarray | None = None
    gains_: np.ndarray | None = None

    def _resolve(self, X, y) -> tuple[str, ScoreFn]:
        m, n = X.shape
        discrete = jnp.issubdtype(X.dtype, jnp.integer) or X.dtype == jnp.bool_
        layout = self.layout
        if layout == "auto":
            # Paper §III: T/N -> conventional; S/W or continuous -> alternative.
            layout = infer_layout(m, n) if discrete else "alternative"
        score = self.score
        if score is None:
            if discrete:
                score = MIScore(
                    num_values=int(jnp.max(X)) + 1,
                    num_classes=int(jnp.max(y)) + 1,
                )
            else:
                score = PearsonMIScore()
        return layout, score

    def fit(self, X, y) -> "FeatureSelector":
        """X: (observations, features) — conventional orientation; y: (obs,)."""
        X = jnp.asarray(X)
        y = jnp.asarray(y).astype(jnp.int32)
        m, n = X.shape
        layout, score = self._resolve(X, y)
        if layout in ("conventional", "grid"):
            X = X.astype(jnp.int32)

        if layout == "conventional":
            ext = _mesh_extent(self.mesh, self.obs_axes)
            # Pad observations with out-of-range categories: zero one-hot
            # contribution, so contingency tables are exact.
            Xp = _pad_axis(X, 0, ext, fill=np.iinfo(np.int32).max)
            yp = _pad_axis(y, 0, ext, fill=np.iinfo(np.int32).max)
            res = mrmr_mod.mrmr_conventional(
                Xp, yp, self.num_select, score,
                mesh=self.mesh, obs_axes=self.obs_axes,
                incremental=self.incremental, block=self.block,
            )
        elif layout == "alternative":
            ext = _mesh_extent(self.mesh, self.feat_axes)
            Xr = _pad_axis(X.T, 0, ext, fill=0)
            res = mrmr_mod.mrmr_alternative(
                Xr, y, self.num_select, score,
                mesh=self.mesh, feat_axes=self.feat_axes,
                incremental=self.incremental, n_features=n,
            )
        elif layout == "grid":
            if self.mesh is None:
                raise ValueError("grid layout requires a mesh")
            oext = _mesh_extent(self.mesh, self.obs_axes)
            fext = _mesh_extent(self.mesh, self.feat_axes)
            Xp = _pad_axis(X, 0, oext, fill=np.iinfo(np.int32).max)
            Xp = _pad_axis(Xp, 1, fext, fill=0)
            yp = _pad_axis(y, 0, oext, fill=np.iinfo(np.int32).max)
            res = mrmr_mod.mrmr_grid(
                Xp, yp, self.num_select, score,
                mesh=self.mesh, obs_axes=self.obs_axes,
                feat_axes=self.feat_axes,
                incremental=self.incremental, block=self.block,
                n_features=n,
            )
        else:
            raise ValueError(f"unknown layout {layout!r}")

        self.selected_ = np.asarray(res.selected)
        self.gains_ = np.asarray(res.gains)
        return self

    def transform(self, X):
        if self.selected_ is None:
            raise RuntimeError("fit() first")
        return np.asarray(X)[:, self.selected_]

    def fit_transform(self, X, y):
        return self.fit(X, y).transform(X)


def mrmr_select(
    X,
    y,
    num_select: int,
    *,
    score: ScoreFn | None = None,
    layout: str = "auto",
    mesh: Mesh | None = None,
    obs_axes=("data",),
    feat_axes=("model",),
    incremental: bool = True,
) -> MRMRResult:
    """One-call mRMR. See :class:`FeatureSelector`."""
    sel = FeatureSelector(
        num_select=num_select, score=score, layout=layout, mesh=mesh,
        obs_axes=obs_axes, feat_axes=feat_axes, incremental=incremental,
    )
    sel.fit(X, y)
    return MRMRResult(
        selected=jnp.asarray(sel.selected_), gains=jnp.asarray(sel.gains_)
    )


__all__ = [
    "FeatureSelector",
    "mrmr_select",
    "MIScore",
    "PearsonMIScore",
    "infer_layout",
]
