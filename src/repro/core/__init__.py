# The paper's primary contribution: distributed mRMR feature selection.
# The front door is MRMRSelector (repro.core.selector); the driver
# functions remain public for benchmarks and direct engine access.
from repro.core.criteria import (  # noqa: F401
    CIFECriterion,
    CMIMCriterion,
    Criterion,
    ICAPCriterion,
    JMICriterion,
    MIDCriterion,
    MIFSCriterion,
    MIQCriterion,
    MaxRelCriterion,
    available_criteria,
    conditional_terms,
    marginal_terms,
    register_criterion,
    resolve_criterion,
)
from repro.core.mrmr import (  # noqa: F401
    MRMRResult,
    make_alternative_fn,
    make_conventional_fn,
    make_grid_fn,
    mrmr_alternative,
    mrmr_conventional,
    mrmr_grid,
    mrmr_reference,
)
from repro.core.scores import (  # noqa: F401
    CustomScore,
    MIScore,
    PearsonMIScore,
    ScoreFn,
    cmi_from_counts,
    cor2mi,
    entropy_from_counts,
    mi_from_counts,
    mrmr_custom_score,
    pearson_rows,
)
from repro.core.selector import (  # noqa: F401
    MRMRSelector,
    SelectionPlan,
    available_encodings,
    build_engine_fn,
    check_num_select,
    get_engine,
    plan_selection,
    register_engine,
)
from repro.core.selection import FeatureSelector, infer_layout, mrmr_select  # noqa: F401

# Imported last: registers the "streaming" engine against the registry in
# repro.core.selector (the out-of-core DataSource fit path).
from repro.core.streaming import mrmr_streaming  # noqa: F401
