# The paper's primary contribution: distributed mRMR feature selection.
from repro.core.mrmr import (  # noqa: F401
    MRMRResult,
    mrmr_alternative,
    mrmr_conventional,
    mrmr_grid,
    mrmr_reference,
)
from repro.core.scores import (  # noqa: F401
    CustomScore,
    MIScore,
    PearsonMIScore,
    ScoreFn,
    cor2mi,
    entropy_from_counts,
    mi_from_counts,
    mrmr_custom_score,
    pearson_rows,
)
from repro.core.selection import FeatureSelector, infer_layout, mrmr_select  # noqa: F401
