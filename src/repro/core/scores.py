"""Feature-score functions for mRMR — pluggable, per the paper's Listing 7.

The paper scores candidate features with mutual information (conventional
encoding, discrete data) and exposes a custom-score interface in the
alternative encoding (``getResult(variableArray, classArray,
selectedVariablesArray) -> Double``), illustrated with a Pearson-correlation
approximation of MI (Listing 8): ``f(x, y) = -0.5 * log(1 - pcc(x, y)^2)``.

Here a score function is an object with two *batched* primitives —

  * ``relevance(cands, cls)``   -> per-candidate f(x_k; c)
  * ``redundancy(cands, other)``-> per-candidate f(x_k; x_j) for ONE j

from which the driver assembles the mRMR score
``g_k = relevance_k - mean_j redundancy_kj`` (Eq. 1).  Both primitives take
candidates in *feature-major* layout (F, M), matching the alternative
encoding's row-per-feature storage.  ``CustomScore`` adapts a user function
with the paper's exact Listing-7 signature.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Literal, Union

import jax
import jax.numpy as jnp

from repro.core import contingency

Array = jax.Array

_EPS = 1e-12


# ---------------------------------------------------------------------------
# Mutual information from contingency tables
# ---------------------------------------------------------------------------

def mi_from_counts(counts: Array) -> Array:
    """Mutual information (nats) from contingency tables.

    Args:
      counts: (..., V, C) non-negative counts.
    Returns:
      (...,) MI in nats. Zero cells contribute zero (lim p->0 of p log p).
    """
    counts = counts.astype(jnp.float32)
    total = jnp.maximum(counts.sum(axis=(-1, -2), keepdims=True), 1.0)
    p = counts / total
    px = p.sum(axis=-1, keepdims=True)  # (..., V, 1)
    py = p.sum(axis=-2, keepdims=True)  # (..., 1, C)
    ratio = p / jnp.maximum(px * py, _EPS)
    terms = jnp.where(p > 0, p * jnp.log(jnp.maximum(ratio, _EPS)), 0.0)
    return terms.sum(axis=(-1, -2))


def cmi_from_counts(counts: Array) -> Array:
    """Conditional mutual information (nats) from 3-way count tables.

    ``I(x; w | y) = sum_c p(y=c) * I(x; w | y=c)``: per-class MI of each
    class slice, weighted by the empirical class mass.  Empty class slices
    contribute zero (their MI is zero and their weight is zero).

    Args:
      counts: (..., V, W, C) non-negative counts — the layout
        :func:`repro.core.contingency.conditional_counts` produces.
    Returns:
      (...,) conditional MI in nats.
    """
    counts = counts.astype(jnp.float32)
    per_class = mi_from_counts(jnp.moveaxis(counts, -1, -3))  # (..., C)
    cls_mass = counts.sum(axis=(-3, -2))  # (..., C)
    total = jnp.maximum(cls_mass.sum(axis=-1, keepdims=True), 1.0)
    return (per_class * cls_mass / total).sum(axis=-1)


def entropy_from_counts(counts: Array) -> Array:
    """Shannon entropy (nats) of a histogram (..., K)."""
    counts = counts.astype(jnp.float32)
    total = jnp.maximum(counts.sum(axis=-1, keepdims=True), 1.0)
    p = counts / total
    return -jnp.where(p > 0, p * jnp.log(jnp.maximum(p, _EPS)), 0.0).sum(axis=-1)


# ---------------------------------------------------------------------------
# Pearson correlation (batched, feature-major)
# ---------------------------------------------------------------------------

def standardize_rows(X: Array) -> Array:
    """Zero-mean unit-variance rows; constant rows map to all-zeros."""
    X = X.astype(jnp.float32)
    mu = X.mean(axis=-1, keepdims=True)
    xc = X - mu
    sd = jnp.sqrt((xc * xc).mean(axis=-1, keepdims=True))
    return xc / jnp.maximum(sd, _EPS)


def pearson_rows(cands: Array, other: Array) -> Array:
    """Pearson correlation of each row of ``cands`` (F, M) with ``other``.

    ``other`` is (M,) or (T, M); result is (F,) or (F, T).
    """
    xs = standardize_rows(cands)
    squeeze = other.ndim == 1
    ys = standardize_rows(other[None] if squeeze else other)
    corr = xs @ ys.T / cands.shape[-1]
    return corr[:, 0] if squeeze else corr


def cor2mi(corr: Array) -> Array:
    """Gaussian MI approximation from correlation (paper Listing 8)."""
    r2 = jnp.clip(corr * corr, 0.0, 1.0 - 1e-6)
    return -0.5 * jnp.log1p(-r2)


# ---------------------------------------------------------------------------
# Score-function objects
# ---------------------------------------------------------------------------

class ScoreFn:
    """Base interface. ``incremental_safe`` (a class attribute, NOT a
    dataclass field) marks scores of the mRMR additive form, for which the
    driver may carry a running redundancy sum (the beyond-paper O(N·L)
    optimisation) instead of recomputing it (paper baseline).

    Scores that can be computed from *block-wise sufficient statistics* set
    ``supports_streaming`` and implement the three streaming primitives:

      * ``init_state(n_features, target_kind)`` — zeroed statistics pytree
        for scoring every feature against one target column (``"class"``
        or ``"feature"``; MI uses it to size the contingency tables).
      * ``accumulate(state, X_block, target, valid=None)`` — fold one
        observation-block ``(B, N)`` + target column ``(B,)`` into the
        statistics.  ``valid`` masks padded rows (the streaming engine pads
        every block to a fixed size for one compiled accumulate step).
      * ``finalize(state)`` — reduce statistics to ``(N,)`` scores.

    This is the paper's mapper/combiner/reducer factored onto the score
    object: ``accumulate`` is map+combine over a partition, the engine's
    state-carrying loop (or the mesh all-reduce) is the reducer, and
    ``finalize`` is the score evaluation on the reduced statistics.
    """

    incremental_safe: bool = True
    supports_streaming: bool = False
    # Scores whose pair statistic decomposes per class (MI from counts)
    # set this and override redundancy_terms with conditional=True support;
    # conditional criteria (JMI/CMIM) require it.
    supports_conditional: bool = False
    # Scores whose streaming state merges across independent row
    # partitions by plain elementwise addition (contingency counts).
    # Required for obs-partitioned multi-host fits, where each host
    # accumulates its own rows and one psum reduces.  Pearson's running
    # moments do NOT qualify: the mean shifts are frozen from each
    # partition's first block, so summing shifted moments from different
    # partitions mixes incompatible origins.
    supports_state_merge: bool = False

    def relevance(self, cands: Array, cls: Array) -> Array:  # (F, M),(M,)->(F,)
        raise NotImplementedError

    def redundancy(self, cands: Array, other: Array) -> Array:  # ->(F,)
        raise NotImplementedError

    def redundancy_terms(
        self, cands: Array, other: Array, cls: Array | None = None,
        *, conditional: bool = False,
    ) -> dict:
        """The generic redundancy form the criterion fold consumes.

        Returns ``{"marginal": (F,), "conditional": (F,) | None}`` — the
        pairwise score of every candidate against ``other``, and (when
        ``conditional=True``) the same statistic conditioned on the class
        column ``cls``.  The base implementation serves marginal-only
        criteria for any score; conditional support is opt-in
        (``supports_conditional``).
        """
        if conditional:
            raise ValueError(
                f"{type(self).__name__} has no class-conditioned pair "
                "statistic (supports_conditional=False); conditional "
                "criteria like JMI/CMIM need MIScore (pass bins= to "
                "discretise continuous data)"
            )
        return dict(marginal=self.redundancy(cands, other), conditional=None)

    # -- streaming sufficient statistics --------------------------------

    def init_state(self, n_features: int, target_kind: str = "class"):
        raise NotImplementedError(
            f"{type(self).__name__} does not support streaming fits"
        )

    def accumulate(self, state, X_block: Array, target: Array, valid=None):
        raise NotImplementedError(
            f"{type(self).__name__} does not support streaming fits"
        )

    def finalize(self, state) -> Array:
        raise NotImplementedError(
            f"{type(self).__name__} does not support streaming fits"
        )


# Out-of-range category sentinel: its one-hot row is all-zero, so masked
# observations contribute nothing to a contingency table.  Plain int, not a
# jnp constant (import-time jnp values would initialise the XLA backend).
_OOR = 2**31 - 1


@dataclasses.dataclass(frozen=True)
class MIScore(ScoreFn):
    """Exact discrete mutual information (the paper's mRMR score).

    ``num_values`` (``d_v``) / ``num_classes`` (``d_c``) follow the paper:
    the union of categorical values over all features, and over the class.
    Categories must live in ``[0, d)``: out-of-range values (including
    negatives) one-hot to all-zero rows and vanish from the counts — the
    auto-resolution paths (``DataSource.stats`` /
    ``MRMRSelector._resolve_score``) validate this and raise.
    ``use_pallas="auto"`` routes the contingency/MI hot loop through the
    Pallas kernels on TPU and the jnp path elsewhere; ``True`` forces the
    kernels (interpreted off-TPU), ``False`` forces the blocked jnp oracle.
    """

    num_values: int = 2
    num_classes: int = 2
    block: int = 64
    use_pallas: Union[bool, Literal["auto"]] = "auto"

    supports_streaming = True
    supports_conditional = True
    # int32 contingency counts over disjoint row partitions sum exactly:
    # the merged statistics (hence every finalised score) are bitwise-
    # identical to one process having counted every block.
    supports_state_merge = True

    def __post_init__(self):
        if self.use_pallas not in (True, False, "auto"):
            raise ValueError(
                "use_pallas must be True, False or 'auto'; "
                f"got {self.use_pallas!r}"
            )

    def _tables(self, X_cols: Array, tgt: Array, vy: int) -> Array:
        """(M, F) column-layout contingency tables against one target."""
        if self.use_pallas is False:
            return contingency.batched_counts(
                X_cols, tgt, self.num_values, vy, block=self.block
            )
        from repro.kernels import ops  # lazy: avoids core<->kernels cycle

        return ops.contingency_tables(
            X_cols, tgt, self.num_values, vy, use_pallas=self.use_pallas
        )

    def _counts(self, cands: Array, tgt: Array, vy: int) -> Array:
        # feature-major candidates -> (M, F) column layout for the kernels.
        return self._tables(cands.T, tgt, vy)

    def relevance(self, cands: Array, cls: Array) -> Array:
        return mi_from_counts(self._counts(cands, cls, self.num_classes))

    def redundancy(self, cands: Array, other: Array) -> Array:
        return mi_from_counts(self._counts(cands, other, self.num_values))

    # -- class-conditioned pair statistics (JMI / CMIM) -------------------

    def _cond_tables(self, X_cols: Array, xj: Array, cls: Array) -> Array:
        """(M, F) columns -> (F, V, V, C) class-conditioned pair tables."""
        if self.use_pallas is False:
            return contingency.conditional_counts(
                X_cols, xj, cls, self.num_values, self.num_values,
                self.num_classes, block=self.block,
            )
        from repro.kernels import ops  # lazy: avoids core<->kernels cycle

        return ops.conditional_tables(
            X_cols, xj, cls, self.num_values, self.num_classes,
            use_pallas=self.use_pallas,
        )

    def redundancy_conditional(
        self, cands: Array, other: Array, cls: Array
    ) -> Array:
        """Per-candidate ``I(x_k; other | cls)`` (feature-major cands)."""
        return cmi_from_counts(self._cond_tables(cands.T, other, cls))

    def redundancy_terms(
        self, cands: Array, other: Array, cls: Array | None = None,
        *, conditional: bool = False,
    ) -> dict:
        if not conditional:
            return dict(marginal=self.redundancy(cands, other), conditional=None)
        # One 3-way count per pass yields BOTH terms: the marginal table is
        # the class-sum, so a conditional criterion pays one counting
        # sweep, not two.
        counts = self._cond_tables(cands.T, other, cls)
        return dict(
            marginal=mi_from_counts(counts.sum(-1)),
            conditional=cmi_from_counts(counts),
        )

    # -- streaming: per-pair contingency tables, summed block-by-block ----

    def init_state(self, n_features: int, target_kind: str = "class") -> Array:
        # int32 running counts: the per-block f32 tables are exact (block
        # counts < 2^24), but a float running sum would silently saturate
        # past 2^24 rows per cell — the very regime streaming exists for.
        # int32 is exact to ~2.1B observations per cell.
        # "feature_cond" carries the class axis FUSED into the target slot
        # (accumulate sizes the one-hot by state.shape[-1], so the same
        # compiled step serves all three kinds); finalize_conditional
        # unflattens it.  Only conditional criteria ever allocate it —
        # mid/miq state shapes and bytes are untouched.
        vy = {
            "class": self.num_classes,
            "feature": self.num_values,
            "feature_cond": self.num_values * self.num_classes,
        }[target_kind]
        return jnp.zeros((n_features, self.num_values, vy), jnp.int32)

    def accumulate(
        self, state: Array, X_block: Array, target: Array, valid=None
    ) -> Array:
        tgt = target.astype(jnp.int32)
        if valid is not None:
            # An out-of-range target zeroes the whole one-hot product, so
            # padded rows vanish from the counts without touching X.
            tgt = jnp.where(valid, tgt, _OOR)
        tables = self._tables(X_block, tgt, state.shape[-1])
        return state + tables.astype(jnp.int32)

    def finalize(self, state: Array) -> Array:
        return mi_from_counts(state)

    def finalize_conditional(self, state: Array) -> dict:
        """Reduce a ``"feature_cond"`` state to both redundancy terms.

        The fused target axis unflattens to (pair value, class); the
        marginal table is its class-sum — identical counts to an unfused
        redundancy pass, so marginal-only selections are unaffected by
        where the terms came from.
        """
        n, v, vc = state.shape
        counts = state.reshape(n, v, vc // self.num_classes, self.num_classes)
        return dict(
            marginal=mi_from_counts(counts.sum(-1)),
            conditional=cmi_from_counts(counts),
        )


@dataclasses.dataclass(frozen=True)
class PearsonMIScore(ScoreFn):
    """Listing-8 score: MI approximated via Pearson correlation.

    Works for continuous data (alternative encoding only, as in the paper).
    Streams as running moments — sum, sum-of-squares and cross-products —
    so one block-wise pass recovers the exact full-dataset correlation.
    """

    supports_streaming = True

    def relevance(self, cands: Array, cls: Array) -> Array:
        return cor2mi(pearson_rows(cands, cls.astype(jnp.float32)))

    def redundancy(self, cands: Array, other: Array) -> Array:
        return cor2mi(pearson_rows(cands, other.astype(jnp.float32)))

    # -- streaming: running moments -------------------------------------

    def init_state(self, n_features: int, target_kind: str = "class") -> dict:
        z = jnp.zeros((n_features,), jnp.float32)
        s = jnp.zeros((), jnp.float32)
        # mu_x / mu_t: per-column shifts frozen from the first block.  The
        # moments are accumulated on SHIFTED data — cov/var are
        # shift-invariant, but naive uncentered f32 sums cancel
        # catastrophically when |mean| >> std (sxx ~ n·mu² swamps the
        # signal), so the shift keeps the sums near the origin.
        return dict(n=s, mu_x=z, mu_t=s, sx=z, sxx=z, sxt=z, st=s, stt=s)

    def accumulate(
        self, state: dict, X_block: Array, target: Array, valid=None
    ) -> dict:
        X = X_block.astype(jnp.float32)
        t = target.astype(jnp.float32)
        if valid is not None:
            w = valid.astype(jnp.float32)
            n = w.sum()
        else:
            w = jnp.ones((X.shape[0],), jnp.float32)
            n = jnp.float32(X.shape[0])
        denom = jnp.maximum(n, 1.0)
        first = state["n"] == 0
        mu_x = jnp.where(first, (X * w[:, None]).sum(axis=0) / denom,
                         state["mu_x"])
        mu_t = jnp.where(first, (t * w).sum() / denom, state["mu_t"])
        # Shift, then zero padded rows: they drop out of every sum and only
        # n carries the true observation count.
        Xs = (X - mu_x) * w[:, None]
        ts = (t - mu_t) * w
        return dict(
            n=state["n"] + n,
            mu_x=mu_x,
            mu_t=mu_t,
            sx=state["sx"] + Xs.sum(axis=0),
            sxx=state["sxx"] + (Xs * Xs).sum(axis=0),
            sxt=state["sxt"] + (Xs * ts[:, None]).sum(axis=0),
            st=state["st"] + ts.sum(),
            stt=state["stt"] + (ts * ts).sum(),
        )

    def finalize(self, state: dict) -> Array:
        n = jnp.maximum(state["n"], 1.0)
        cov = state["sxt"] - state["sx"] * state["st"] / n
        var_x = state["sxx"] - state["sx"] * state["sx"] / n
        var_t = state["stt"] - state["st"] * state["st"] / n
        corr = cov / jnp.sqrt(jnp.maximum(var_x * var_t, _EPS))
        return cor2mi(jnp.clip(corr, -1.0, 1.0))


@dataclasses.dataclass(frozen=True)
class CustomScore(ScoreFn):
    """Adapter for the paper's Listing-7 ``getResult`` interface.

    ``get_result(variable (M,), class (M,), selected (L, M), n_selected)``
    must return the *complete* feature score for one candidate.  Because an
    arbitrary user score need not decompose into relevance/redundancy, this
    forces the paper-faithful (recompute-every-iteration) driver path, and
    it cannot stream (no sufficient-statistics decomposition to accumulate).
    """

    get_result: Callable[[Array, Array, Array, Array], Array]
    incremental_safe = False

    def __post_init__(self):
        # Fail here, not as an opaque TypeError deep inside the driver's vmap.
        if not callable(self.get_result):
            raise TypeError(
                "CustomScore requires a callable get_result(variable, cls, "
                f"selected, n_selected); got {self.get_result!r}"
            )

    def full_score(
        self, cands: Array, cls: Array, selected: Array, n_selected: Array
    ) -> Array:
        """(F, M), (M,), (L, M), () -> (F,) full scores."""
        return jax.vmap(lambda v: self.get_result(v, cls, selected, n_selected))(
            cands
        )


def mrmr_custom_score(score: ScoreFn) -> CustomScore:
    """Express a relevance/redundancy score through the Listing-7 interface
    (used to validate the custom path against the built-in path)."""

    def get_result(v, cls, selected, n_selected):
        rel = score.relevance(v[None], cls)[0]
        red = score.redundancy(selected, v)  # (L,) scores vs each selected row
        mask = jnp.arange(selected.shape[0]) < n_selected
        red_sum = jnp.where(mask, red, 0.0).sum()
        return rel - red_sum / jnp.maximum(n_selected, 1).astype(jnp.float32)

    return CustomScore(get_result=get_result)
