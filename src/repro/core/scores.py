"""Feature-score functions for mRMR — pluggable, per the paper's Listing 7.

The paper scores candidate features with mutual information (conventional
encoding, discrete data) and exposes a custom-score interface in the
alternative encoding (``getResult(variableArray, classArray,
selectedVariablesArray) -> Double``), illustrated with a Pearson-correlation
approximation of MI (Listing 8): ``f(x, y) = -0.5 * log(1 - pcc(x, y)^2)``.

Here a score function is an object with two *batched* primitives —

  * ``relevance(cands, cls)``   -> per-candidate f(x_k; c)
  * ``redundancy(cands, other)``-> per-candidate f(x_k; x_j) for ONE j

from which the driver assembles the mRMR score
``g_k = relevance_k - mean_j redundancy_kj`` (Eq. 1).  Both primitives take
candidates in *feature-major* layout (F, M), matching the alternative
encoding's row-per-feature storage.  ``CustomScore`` adapts a user function
with the paper's exact Listing-7 signature.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import contingency

Array = jax.Array

_EPS = 1e-12


# ---------------------------------------------------------------------------
# Mutual information from contingency tables
# ---------------------------------------------------------------------------

def mi_from_counts(counts: Array) -> Array:
    """Mutual information (nats) from contingency tables.

    Args:
      counts: (..., V, C) non-negative counts.
    Returns:
      (...,) MI in nats. Zero cells contribute zero (lim p->0 of p log p).
    """
    counts = counts.astype(jnp.float32)
    total = jnp.maximum(counts.sum(axis=(-1, -2), keepdims=True), 1.0)
    p = counts / total
    px = p.sum(axis=-1, keepdims=True)  # (..., V, 1)
    py = p.sum(axis=-2, keepdims=True)  # (..., 1, C)
    ratio = p / jnp.maximum(px * py, _EPS)
    terms = jnp.where(p > 0, p * jnp.log(jnp.maximum(ratio, _EPS)), 0.0)
    return terms.sum(axis=(-1, -2))


def entropy_from_counts(counts: Array) -> Array:
    """Shannon entropy (nats) of a histogram (..., K)."""
    counts = counts.astype(jnp.float32)
    total = jnp.maximum(counts.sum(axis=-1, keepdims=True), 1.0)
    p = counts / total
    return -jnp.where(p > 0, p * jnp.log(jnp.maximum(p, _EPS)), 0.0).sum(axis=-1)


# ---------------------------------------------------------------------------
# Pearson correlation (batched, feature-major)
# ---------------------------------------------------------------------------

def standardize_rows(X: Array) -> Array:
    """Zero-mean unit-variance rows; constant rows map to all-zeros."""
    X = X.astype(jnp.float32)
    mu = X.mean(axis=-1, keepdims=True)
    xc = X - mu
    sd = jnp.sqrt((xc * xc).mean(axis=-1, keepdims=True))
    return xc / jnp.maximum(sd, _EPS)


def pearson_rows(cands: Array, other: Array) -> Array:
    """Pearson correlation of each row of ``cands`` (F, M) with ``other``.

    ``other`` is (M,) or (T, M); result is (F,) or (F, T).
    """
    xs = standardize_rows(cands)
    squeeze = other.ndim == 1
    ys = standardize_rows(other[None] if squeeze else other)
    corr = xs @ ys.T / cands.shape[-1]
    return corr[:, 0] if squeeze else corr


def cor2mi(corr: Array) -> Array:
    """Gaussian MI approximation from correlation (paper Listing 8)."""
    r2 = jnp.clip(corr * corr, 0.0, 1.0 - 1e-6)
    return -0.5 * jnp.log1p(-r2)


# ---------------------------------------------------------------------------
# Score-function objects
# ---------------------------------------------------------------------------

class ScoreFn:
    """Base interface. ``incremental_safe`` (a class attribute, NOT a
    dataclass field) marks scores of the mRMR additive form, for which the
    driver may carry a running redundancy sum (the beyond-paper O(N·L)
    optimisation) instead of recomputing it (paper baseline)."""

    incremental_safe: bool = True

    def relevance(self, cands: Array, cls: Array) -> Array:  # (F, M),(M,)->(F,)
        raise NotImplementedError

    def redundancy(self, cands: Array, other: Array) -> Array:  # ->(F,)
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class MIScore(ScoreFn):
    """Exact discrete mutual information (the paper's mRMR score).

    ``num_values`` (``d_v``) / ``num_classes`` (``d_c``) follow the paper:
    the union of categorical values over all features, and over the class.
    ``use_pallas="auto"`` routes the contingency/MI hot loop through the
    Pallas kernels on TPU and the jnp path elsewhere.
    """

    num_values: int = 2
    num_classes: int = 2
    block: int = 64
    use_pallas: object = "auto"

    def _counts(self, cands: Array, tgt: Array, vy: int) -> Array:
        from repro.kernels import ops  # lazy: avoids core<->kernels cycle

        if self.use_pallas != False:  # noqa: E712  ("auto" or True)
            return ops.contingency_tables(
                cands.T, tgt, self.num_values, vy, use_pallas=self.use_pallas
            )
        # feature-major candidates -> (M, F) column layout for batched_counts.
        return contingency.batched_counts(
            cands.T, tgt, self.num_values, vy, block=self.block
        )

    def relevance(self, cands: Array, cls: Array) -> Array:
        return mi_from_counts(self._counts(cands, cls, self.num_classes))

    def redundancy(self, cands: Array, other: Array) -> Array:
        return mi_from_counts(self._counts(cands, other, self.num_values))


@dataclasses.dataclass(frozen=True)
class PearsonMIScore(ScoreFn):
    """Listing-8 score: MI approximated via Pearson correlation.

    Works for continuous data (alternative encoding only, as in the paper).
    """

    def relevance(self, cands: Array, cls: Array) -> Array:
        return cor2mi(pearson_rows(cands, cls.astype(jnp.float32)))

    def redundancy(self, cands: Array, other: Array) -> Array:
        return cor2mi(pearson_rows(cands, other.astype(jnp.float32)))


@dataclasses.dataclass(frozen=True)
class CustomScore(ScoreFn):
    """Adapter for the paper's Listing-7 ``getResult`` interface.

    ``get_result(variable (M,), class (M,), selected (L, M), n_selected)``
    must return the *complete* feature score for one candidate.  Because an
    arbitrary user score need not decompose into relevance/redundancy, this
    forces the paper-faithful (recompute-every-iteration) driver path.
    """

    get_result: Callable[[Array, Array, Array, Array], Array] = None
    incremental_safe = False

    def full_score(
        self, cands: Array, cls: Array, selected: Array, n_selected: Array
    ) -> Array:
        """(F, M), (M,), (L, M), () -> (F,) full scores."""
        return jax.vmap(lambda v: self.get_result(v, cls, selected, n_selected))(
            cands
        )


def mrmr_custom_score(score: ScoreFn) -> CustomScore:
    """Express a relevance/redundancy score through the Listing-7 interface
    (used to validate the custom path against the built-in path)."""

    def get_result(v, cls, selected, n_selected):
        rel = score.relevance(v[None], cls)[0]
        red = score.redundancy(selected, v)  # (L,) scores vs each selected row
        mask = jnp.arange(selected.shape[0]) < n_selected
        red_sum = jnp.where(mask, red, 0.0).sum()
        return rel - red_sum / jnp.maximum(n_selected, 1).astype(jnp.float32)

    return CustomScore(get_result=get_result)
