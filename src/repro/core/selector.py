"""The front-door selection API: ``MRMRSelector`` / ``SelectionPlan``.

One estimator-style entry point for every distribution strategy in the
repo.  The design splits feature selection into three layers:

1. **Planning** — ``plan_selection`` implements the paper's §III rule
   (tall/narrow -> conventional encoding, wide/short -> alternative,
   both-large -> 2-D grid) and factors the available devices into a mesh
   shape.  The result is a ``SelectionPlan``: a frozen, inspectable record
   of encoding, mesh axes/shape, block size, incremental flag and score.
2. **Engines** — a registry mapping encoding names to fit functions.  The
   four built-in drivers (reference / conventional / alternative / grid)
   register here; new strategies (streaming shards, other score layouts)
   drop in via ``register_engine`` without touching the drivers.
3. **The selector** — ``MRMRSelector.fit(X, y)`` resolves the plan, builds
   the mesh, and hands off to the engine.  Padding to mesh divisibility,
   layout transposition (inputs are ALWAYS observations × features),
   device placement and result unpadding are all owned here; callers never
   see ``shard_map``.

    >>> from repro import MRMRSelector
    >>> sel = MRMRSelector(num_select=10).fit(X, y)
    >>> X_reduced = sel.transform(X)          # columns in selection order
    >>> sel.plan_                             # the resolved SelectionPlan
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import mrmr as mrmr_mod
from repro.core.criteria import Criterion, resolve_criterion
from repro.core.mrmr import MRMRResult, WarmJitCache
from repro.core.scores import MIScore, PearsonMIScore, ScoreFn, _OOR
from repro.data.binning import BinnedSource
from repro.data.sources import ArraySource, DataSource
from repro.dist.meshes import factor_mesh, make_mesh
from repro.dist.sharding import axes_tuple as _axes_tuple, mesh_extent
from repro.dist.streaming import effective_block_obs, resolve_prefetch

Array = jax.Array

# Paper §III aspect-ratio rule: beyond these ratios one axis dominates and
# single-axis sharding wins; between them (and with enough devices and
# data) the 2-D grid removes both memory walls at once.
TALL_RATIO = 4.0      # obs/feat >= this -> conventional (observation-sharded)
WIDE_RATIO = 0.25     # obs/feat <= this -> alternative (feature-sharded)
GRID_MIN_DIM = 512    # both dims at least this before a grid pays off
GRID_MIN_DEVICES = 4  # a 2-D mesh needs at least a 2x2 factorisation


def check_num_select(num_select, n_features: int) -> None:
    """Shared fit-time bounds check: ``1 <= num_select <= num_features``.

    Raised by the front door (both array and DataSource paths) and the
    streaming driver, so an oversized ask fails with one clear message
    instead of an opaque shape error deep inside an engine loop.
    """
    if not 1 <= int(num_select) <= n_features:
        raise ValueError(
            f"num_select={num_select} out of range: need "
            f"1 <= num_select <= num_features ({n_features})"
        )


@dataclasses.dataclass(frozen=True)
class SelectionPlan:
    """Resolved distribution strategy for one ``fit``.

    ``mesh_shape`` aligns with ``obs_axes + feat_axes``; empty means run
    unsharded.  ``score=None`` means "resolve from the data at fit time"
    (discrete -> exact MI, continuous -> Pearson-MI).  ``criterion`` is
    the greedy objective — a registered name or a
    :class:`~repro.core.criteria.Criterion` instance (resolved at use).
    """

    encoding: str                     # reference|conventional|alternative|grid|streaming
    obs_axes: tuple = ()              # mesh axes sharding observations
    feat_axes: tuple = ()             # mesh axes sharding features
    mesh_shape: tuple = ()            # extents, aligned with mesh_axes
    block: int = 64                   # contingency feature-block size
    incremental: bool = True          # running criterion fold vs recompute
    score: ScoreFn | None = None      # score spec (None = auto from data)
    onehot_dtype: str = "bfloat16"    # contingency one-hot storage dtype
    static_inner: bool = False        # fixed-trip recompute loop (dry-run)
    block_obs: int = 65536            # streaming: EFFECTIVE observations per
                                      # block (rounded up to the obs extent)
    prefetch: int = 2                 # streaming: blocks placed ahead of
                                      # device accumulation (0 = synchronous;
                                      # the selector resolves "auto" to an
                                      # int before the plan is recorded)
    criterion: object = "mid"         # greedy objective (name or Criterion);
                                      # appended last for positional compat
    bins: int | None = None           # quantile-binned fit: codes per
                                      # feature (None = data was discrete)
    batch_candidates: int = 1         # streaming: redundancy vectors
                                      # speculated per pass (q; 1 = classic)
    spill_dir: str | None = None      # streaming: encoded-block spill cache
                                      # directory (None = off)
    spill_budget_bytes: int | None = None  # LRU byte budget for spill_dir
    readahead: int = 0                # streaming: raw blocks read across
                                      # pass boundaries (0 = off)
    hosts: int = 1                    # streaming: jax.distributed processes
                                      # sharing the fit (1 = single-host)

    @property
    def mesh_axes(self) -> tuple:
        return self.obs_axes + self.feat_axes

    @property
    def num_shards(self) -> int:
        return math.prod(self.mesh_shape) if self.mesh_shape else 1


def _grid_worthwhile(m: int, n: int, n_dev: int) -> bool:
    """§III both-large gate, shared by the in-memory and streaming
    planners: enough devices for a 2-D factorisation, both dims big
    enough to shard, and no axis dominant enough for 1-D to win."""
    aspect = m / max(n, 1)
    return (
        n_dev >= GRID_MIN_DEVICES
        and min(m, n) >= GRID_MIN_DIM
        and WIDE_RATIO < aspect < TALL_RATIO
    )


def _grid_factor(m: int, n: int, n_dev: int) -> tuple | None:
    """The (obs, feat) device factorisation when a 2-D grid pays off for
    an (m, n) dataset on ``n_dev`` devices, else None (grid not
    worthwhile, or the device count only factors 1-D)."""
    if not _grid_worthwhile(m, n, n_dev):
        return None
    # Weight the device split by the aspect ratio: a taller dataset gets
    # more observation shards.
    od, fd = factor_mesh(n_dev, bias=max(m / max(n, 1), 1e-6))
    return None if min(od, fd) == 1 else (od, fd)


def _device_count(devices) -> int:
    if devices is None:
        return len(jax.devices())
    if isinstance(devices, Mesh):
        return devices.size
    if isinstance(devices, int):
        return devices
    return len(devices)


def plan_selection(
    shape: tuple,
    devices=None,
    score: ScoreFn | None = None,
    *,
    obs_axes: Sequence[str] | str = ("data",),
    feat_axes: Sequence[str] | str = ("model",),
    incremental: bool = True,
    block: int = 64,
    criterion: Criterion | str = "mid",
) -> SelectionPlan:
    """Pick encoding + mesh for a dataset shape (paper §III).

    Args:
      shape: (observations, features) of the conventional-orientation input.
      devices: device budget — an int, a device list, a ``Mesh`` (planning
        is then constrained to its axes), or None for all local devices.
      score: the score spec.  Non-MI scores force the alternative encoding
        (the only map-only layout that supports arbitrary scores, §IV.D).
      criterion: greedy objective name or Criterion — orthogonal to the
        encoding choice; recorded on the plan for the engines.
    """
    criterion = resolve_criterion(criterion)
    m, n = int(shape[0]), int(shape[1])
    obs_axes, feat_axes = _axes_tuple(obs_axes), _axes_tuple(feat_axes)
    n_dev = _device_count(devices)
    mesh = devices if isinstance(devices, Mesh) else None
    if mesh is not None:
        obs_axes = tuple(a for a in obs_axes if a in mesh.shape)
        feat_axes = tuple(a for a in feat_axes if a in mesh.shape)

    mi_ok = score is None or isinstance(score, MIScore)
    aspect = m / max(n, 1)
    can_grid = (
        mi_ok
        and _grid_worthwhile(m, n, n_dev)
        and (mesh is None or (obs_axes and feat_axes))
    )
    if not mi_ok:
        encoding = "alternative"
    elif can_grid:
        encoding = "grid"
    elif aspect >= 1.0:
        encoding = "conventional"
    else:
        encoding = "alternative"

    common = dict(block=block, incremental=incremental, score=score,
                  criterion=criterion)
    if n_dev <= 1 and mesh is None:
        # Single device: encoding still follows the shape (the drivers run
        # unsharded), so plans are stable as the fleet scales.
        if encoding == "grid":
            encoding = "conventional" if aspect >= 1.0 else "alternative"
        return SelectionPlan(encoding=encoding, **common)

    if mesh is not None:
        if encoding == "conventional" and not obs_axes:
            encoding = "alternative" if feat_axes else "reference"
        if encoding == "alternative" and not feat_axes:
            # Only MI scores may reroute to the conventional engine; any
            # other score falls back to the score-agnostic reference.
            encoding = "conventional" if (obs_axes and mi_ok) else "reference"
        if encoding == "reference":
            return SelectionPlan("reference", **common)
        shape_of = lambda axes: tuple(mesh.shape[a] for a in axes)
        if encoding == "conventional":
            return SelectionPlan(
                encoding, obs_axes=obs_axes, mesh_shape=shape_of(obs_axes),
                **common,
            )
        if encoding == "alternative":
            return SelectionPlan(
                encoding, feat_axes=feat_axes, mesh_shape=shape_of(feat_axes),
                **common,
            )
        return SelectionPlan(
            encoding, obs_axes=obs_axes, feat_axes=feat_axes,
            mesh_shape=shape_of(obs_axes + feat_axes), **common,
        )

    if encoding == "grid":
        gf = _grid_factor(m, n, n_dev)
        if gf is None:  # prime device count: grid degenerates
            encoding = "conventional" if aspect >= 1.0 else "alternative"
        else:
            return SelectionPlan(
                "grid", obs_axes=obs_axes[:1] or ("data",),
                feat_axes=feat_axes[:1] or ("model",),
                mesh_shape=gf, **common,
            )
    if encoding == "conventional":
        return SelectionPlan(
            "conventional", obs_axes=obs_axes[:1] or ("data",),
            mesh_shape=(n_dev,), **common,
        )
    return SelectionPlan(
        "alternative", feat_axes=feat_axes[:1] or ("model",),
        mesh_shape=(n_dev,), **common,
    )


# ---------------------------------------------------------------------------
# engine registry
# ---------------------------------------------------------------------------

# name -> fit(X, y, *, num_select, plan, mesh) -> MRMRResult, with X in
# conventional orientation (observations × features) and global feature ids
# in the result.  Engines own their padding / transposition / placement.
_ENGINES: dict = {}


def register_engine(name: str) -> Callable:
    """Register a selection engine under an encoding name (decorator)."""

    def deco(fn):
        _ENGINES[name] = fn
        return fn

    return deco


def get_engine(name: str):
    try:
        return _ENGINES[name]
    except KeyError:
        raise ValueError(
            f"unknown encoding {name!r}; registered: {sorted(_ENGINES)}"
        ) from None


def available_encodings() -> tuple:
    return tuple(sorted(_ENGINES))


def build_engine_fn(
    plan: SelectionPlan, mesh: Mesh | None, num_select: int, n_features: int
):
    """Jitted (X, y) -> (selected, gains, relevance) in the engine's
    NATIVE layout.

    Native layouts: conventional/grid take (obs, feat) [padded to mesh
    divisibility]; reference/alternative take feature-major (feat, obs).
    The relevance output covers the engine's (padded) feature extent.
    Benchmarks use this directly to ``.lower().compile()`` the exact job
    the selector would run.
    """
    enc, score = plan.encoding, plan.score
    crit = resolve_criterion(plan.criterion)
    oh_dt = jnp.dtype(plan.onehot_dtype)
    if enc == "reference":

        def ref_fn(Xr, y):
            res = mrmr_mod.mrmr_reference(
                Xr, y, num_select, score, incremental=plan.incremental,
                criterion=crit,
            )
            return res.selected, res.gains, res.relevance

        return jax.jit(ref_fn)
    if enc == "conventional":
        return mrmr_mod.make_conventional_fn(
            num_select, score, mesh=mesh, obs_axes=plan.obs_axes,
            incremental=plan.incremental, block=plan.block,
            onehot_dtype=oh_dt, static_inner=plan.static_inner,
            criterion=crit,
        )
    if enc == "alternative":
        return mrmr_mod.make_alternative_fn(
            num_select, score, n_features, mesh=mesh,
            feat_axes=plan.feat_axes, incremental=plan.incremental,
            criterion=crit,
        )
    if enc == "grid":
        if mesh is None:
            raise ValueError("grid encoding requires a mesh")
        return mrmr_mod.make_grid_fn(
            num_select, score, n_features, mesh=mesh,
            obs_axes=plan.obs_axes, feat_axes=plan.feat_axes,
            incremental=plan.incremental, block=plan.block,
            criterion=crit,
        )
    raise ValueError(f"unknown encoding {enc!r}")


# Warm engine-fn cache: the built (jit-wrapped) engine callables, keyed by
# everything that shapes the computation.  jax memoises executables per
# wrapper object, so reusing the wrapper across fits makes a repeat fit
# (same engine × criterion × score × geometry — the selection service's
# steady state) skip trace AND compile entirely.
_ENGINE_FN_CACHE = WarmJitCache(capacity=32)


def _engine_fn_key(plan: SelectionPlan, mesh, num_select: int, n_features: int):
    return (
        "engine_fn", plan.encoding, plan.score,
        resolve_criterion(plan.criterion), num_select, n_features, mesh,
        plan.block, plan.incremental, plan.obs_axes, plan.feat_axes,
        plan.onehot_dtype, plan.static_inner,
    )


def cached_engine_fn(
    plan: SelectionPlan, mesh: Mesh | None, num_select: int, n_features: int
):
    """:func:`build_engine_fn` through the warm jit cache.

    Unhashable plan ingredients (a custom criterion or score holding
    mutable state) fall back to an uncached build.
    """
    return _ENGINE_FN_CACHE.get_or_build(
        _engine_fn_key(plan, mesh, num_select, n_features),
        lambda: build_engine_fn(plan, mesh, num_select, n_features),
    )


def engine_fn_cache_stats() -> dict:
    """Hit/miss/eviction counters of the warm engine-fn cache."""
    return _ENGINE_FN_CACHE.stats()


def clear_engine_fn_cache() -> None:
    """Drop every warmed engine fn (tests; frees compiled executables)."""
    _ENGINE_FN_CACHE.clear()


def _pad_axis(x: Array, axis: int, multiple: int, fill) -> Array:
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def _place(x: Array, mesh: Mesh | None, spec: P) -> Array:
    if mesh is None:
        return x
    return jax.device_put(x, NamedSharding(mesh, spec))


# _OOR (imported from scores): out-of-range category -> zero one-hot row,
# the one padding sentinel shared by the in-memory and streaming paths.


def _result(plan: SelectionPlan, engine: str, sel, gains, rel, n: int):
    """Assemble the rich result: slice feature padding off the relevance."""
    return MRMRResult(
        sel, gains, relevance=rel[:n],
        criterion=resolve_criterion(plan.criterion).name, engine=engine,
    )


@register_engine("reference")
def _fit_reference(X, y, *, num_select, plan, mesh) -> MRMRResult:
    del mesh
    fn = cached_engine_fn(plan, None, num_select, X.shape[1])
    sel, gains, rel = fn(jnp.asarray(X).T, y)
    return _result(plan, "reference", sel, gains, rel, X.shape[1])


@register_engine("conventional")
def _fit_conventional(X, y, *, num_select, plan, mesh) -> MRMRResult:
    ext = mesh_extent(mesh, plan.obs_axes)
    # Padded observations carry out-of-range categories: their one-hot rows
    # are all-zero, so contingency tables stay exact without masking.
    Xp = _pad_axis(X.astype(jnp.int32), 0, ext, fill=_OOR)
    yp = _pad_axis(y, 0, ext, fill=_OOR)
    Xp = _place(Xp, mesh, P(plan.obs_axes, None))
    yp = _place(yp, mesh, P(plan.obs_axes))
    fn = cached_engine_fn(plan, mesh, num_select, X.shape[1])
    sel, gains, rel = fn(Xp, yp)
    return _result(plan, "conventional", sel, gains, rel, X.shape[1])


@register_engine("alternative")
def _fit_alternative(X, y, *, num_select, plan, mesh) -> MRMRResult:
    n = X.shape[1]
    ext = mesh_extent(mesh, plan.feat_axes)
    # Feature-major storage; padded feature rows are masked out of the
    # argmax by the driver (ids >= n_features).
    Xr = _pad_axis(jnp.asarray(X).T, 0, ext, fill=0)
    Xr = _place(Xr, mesh, P(plan.feat_axes, None))
    yb = _place(y, mesh, P())
    fn = cached_engine_fn(plan, mesh, num_select, n)
    sel, gains, rel = fn(Xr, yb)
    return _result(plan, "alternative", sel, gains, rel, n)


@register_engine("grid")
def _fit_grid(X, y, *, num_select, plan, mesh) -> MRMRResult:
    if mesh is None:
        raise ValueError("grid encoding requires a mesh")
    n = X.shape[1]
    oext = mesh_extent(mesh, plan.obs_axes)
    fext = mesh_extent(mesh, plan.feat_axes)
    Xp = _pad_axis(X.astype(jnp.int32), 0, oext, fill=_OOR)
    Xp = _pad_axis(Xp, 1, fext, fill=0)
    yp = _pad_axis(y, 0, oext, fill=_OOR)
    Xp = _place(Xp, mesh, P(plan.obs_axes, plan.feat_axes))
    yp = _place(yp, mesh, P(plan.obs_axes))
    fn = cached_engine_fn(plan, mesh, num_select, n)
    sel, gains, rel = fn(Xp, yp)
    return _result(plan, "grid", sel, gains, rel, n)


# ---------------------------------------------------------------------------
# the selector
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MRMRSelector:
    """mRMR feature selection with auto-planned distribution.

    Scikit-learn-style estimator: ``fit(X, y)`` -> self with ``selected_``
    / ``gains_`` / ``plan_``; ``transform(X)`` returns the selected columns
    in selection order.  ``X`` is always (observations × features); the
    encoding only changes how the work is distributed, never the input
    orientation.

    Out-of-core data fits through the same front door: pass a
    :class:`repro.data.sources.DataSource` as the sole argument —
    ``fit(NpySource("X.npy", "y.npy"))`` — and the ``"streaming"`` engine
    runs the selection block-by-block with peak device memory bounded by
    ``block_obs`` rows instead of ``num_obs`` (the streaming engine always
    uses the running criterion fold; selections are identical to the
    recompute baseline for the built-in scores).

    After a fit the selector exposes the sklearn-style read side:
    ``selected_`` (ids in pick order), ``gains_`` (the per-iteration
    objective trajectory), ``scores_`` (the per-feature relevance vector;
    NaN for CustomScore fits, None for custom engines that predate the
    rich report), ``ranking_`` (1-based selection rank, unselected
    features share rank ``num_select + 1``), ``get_support()`` (boolean
    mask, or ascending indices with ``indices=True``) and ``result_``
    (the full :class:`~repro.core.mrmr.MRMRResult` report).

    Args:
      num_select: L, number of features to pick; must satisfy
        ``1 <= num_select <= num_features`` (checked at fit time).
      score: a ``ScoreFn``; None resolves from the data (discrete -> exact
        MI with inferred cardinalities, continuous -> Pearson-MI).
      criterion: the greedy objective — a registered name (``"mid"`` the
        paper's difference form, ``"miq"`` quotient, ``"maxrel"``
        relevance-only, ``"jmi"``/``"cmim"`` the class-conditioned
        objectives) or a :class:`~repro.core.criteria.Criterion`
        instance.  Orthogonal to ``encoding``: any criterion runs on any
        engine, in-memory or streaming.  Conditional criteria need a
        score with a class-conditioned decomposition (``MIScore``; pass
        ``bins=`` to discretise continuous data first).
      encoding: "auto" (paper §III rule via ``plan_selection``) or one of
        ``available_encodings()``.
      mesh: an existing device mesh to run on; None lets the planner build
        one from ``devices``.
      devices: device budget for auto-planning (int, device list, or None
        for all local devices).  Ignored when ``mesh`` is given.
      obs_axes / feat_axes: mesh axis names for observation / feature
        sharding (intersected with the mesh's axes).
      incremental: False reproduces the paper's per-iteration redundancy
        recomputation; True carries the criterion's running fold state
        (identical selections).
      block: contingency feature-block size.
      block_obs: observations per streaming block (``DataSource`` fits) —
        the peak-device-memory knob; larger blocks amortise dispatch and
        host-to-device transfer, smaller blocks cap memory.  The resolved
        ``plan_.block_obs`` records the effective size after rounding up
        to the observation-axes extent.
      prefetch: streaming fits only — host blocks read, padded and placed
        ahead of device accumulation on a background thread (double
        buffering); 0 restores the synchronous placer and the ``"auto"``
        default resolves per backend (off on CPU, where the staging
        thread measurably loses to async dispatch; 2 elsewhere — see
        :func:`repro.dist.streaming.resolve_prefetch`).
      batch_candidates: streaming fits only — redundancy vectors
        speculated per pass (``q``).  Each redundancy pass scores the
        needed column plus the top ``q-1`` remaining candidates in one
        sweep, cutting ``num_select=L`` from ``L-1`` redundancy passes
        toward ``⌈(L-1)/q⌉`` at ``q×`` the statistics memory.
        Selections are bitwise-identical to the default ``q=1``.
      spill_dir: streaming fits only — directory for the encoded-block
        spill cache (:class:`repro.data.block_cache.BlockCacheSource`).
        Pass 1 spills each parsed/encoded block as compact ``.npy``
        chunks; passes 2..L replay them memmapped, so CSV parse and bin
        encode are paid once per dataset instead of once per pass.
      readahead: streaming fits only — raw blocks the cross-pass reader
        streams ahead of the consumer, across pass boundaries, hiding
        each pass's cold-start I/O bubble (0 = off; supersedes
        ``prefetch`` when positive).
      hosts: streaming fits only — run the fit across this many
        ``jax.distributed`` processes (``"auto"`` = ``jax.process_count()``
        after :func:`repro.dist.init_multihost`).  The §III rule then
        applies across *hosts*: each process reads only its block/column
        ranges and per-pass statistics merge with explicit collectives;
        every host returns the identical result.  Per-host devices still
        shard each local block over ``obs_axes``; device feature-sharding
        is disabled under multi-host so cross-host state shapes align.
        ``None``/1 keeps today's single-process behaviour.
      bins: discretise continuous features on the fly into this many
        equal-frequency bins (one streaming quantile-sketch pass; see
        :mod:`repro.data.binning`), so float data runs the exact discrete
        MI path instead of the Pearson approximation.  Applies to float
        arrays and continuous ``DataSource``s when the score is MI (or
        auto); discrete data and explicit non-MI scores ignore it.  The
        resolved ``plan_.bins`` records what ran.

    Streamed fits follow the same §III aspect rule as in-memory plans:
    tall sources shard blocks over ``obs_axes``, wide sources shard blocks
    *and the per-pair statistics state* over ``feat_axes`` (bounding
    per-device statistics memory by ``N/shards`` pairs), and both-large
    sources run a 2-D (obs × feat) grid.  A user ``mesh`` overrides the
    rule with whatever obs/feat axes it carries.
    """

    num_select: int
    score: ScoreFn | None = None
    encoding: str = "auto"
    mesh: Mesh | None = None
    devices: object = None
    obs_axes: Sequence[str] | str = ("data",)
    feat_axes: Sequence[str] | str = ("model",)
    incremental: bool = True
    block: int = 64
    block_obs: int = 65536
    prefetch: int | str = "auto"
    # appended after the pre-1.2 fields so positional construction keeps
    # its old meaning
    criterion: Criterion | str = "mid"
    bins: int | None = None
    batch_candidates: int = 1
    spill_dir: str | None = None
    spill_budget_bytes: int | None = None
    readahead: int = 0
    hosts: int | str | None = None

    selected_: np.ndarray | None = None
    gains_: np.ndarray | None = None
    scores_: np.ndarray | None = None
    ranking_: np.ndarray | None = None
    result_: MRMRResult | None = None
    n_features_in_: int | None = None
    plan_: SelectionPlan | None = None
    mesh_: Mesh | None = None

    def _resolve_score(self, X: Array, y: Array) -> ScoreFn:
        if self.score is not None:
            return self.score
        discrete = (
            jnp.issubdtype(X.dtype, jnp.integer) or X.dtype == jnp.bool_
        )
        if discrete:
            if int(jnp.min(X)) < 0 or int(jnp.min(y)) < 0:
                # One-hot contingency rows for negative categories are
                # all-zero, so those observations would silently vanish
                # from the MI counts — fail instead of scoring wrong.
                raise ValueError(
                    "negative category values in discrete data: one-hot "
                    "contingency counts drop them silently; remap "
                    "categories to 0..K-1 before fitting"
                )
            return MIScore(
                num_values=int(jnp.max(X)) + 1,
                num_classes=int(jnp.max(y)) + 1,
            )
        return PearsonMIScore()

    def _resolve_plan(self, shape: tuple, score: ScoreFn) -> SelectionPlan:
        if self.encoding == "auto":
            devices = self.mesh if self.mesh is not None else self.devices
            return plan_selection(
                shape, devices, score,
                obs_axes=self.obs_axes, feat_axes=self.feat_axes,
                incremental=self.incremental, block=self.block,
                criterion=self.criterion,
            )
        obs = _axes_tuple(self.obs_axes)
        feat = _axes_tuple(self.feat_axes)
        if self.mesh is not None:
            obs = tuple(a for a in obs if a in self.mesh.shape)
            feat = tuple(a for a in feat if a in self.mesh.shape)
        axes = {
            "reference": ((), ()),
            "conventional": (obs, ()),
            "alternative": ((), feat),
            "grid": (obs, feat),
        }.get(self.encoding, (obs, feat))
        if self.mesh is not None:
            shape_of = tuple(self.mesh.shape[a] for a in axes[0] + axes[1])
        else:
            # No mesh given: build one from the device budget, so an
            # explicitly requested encoding still scales out.
            n_dev = _device_count(self.devices)
            m, n = shape
            if self.encoding == "grid":
                # Degenerate 1x1 grid on a single device: the encoding
                # always runs rather than erroring on small hosts.
                axes = (axes[0][:1] or ("data",), axes[1][:1] or ("model",))
                shape_of = (
                    factor_mesh(n_dev, bias=max(m / max(n, 1), 1e-6))
                    if n_dev > 1
                    else (1, 1)
                )
            elif n_dev <= 1 or self.encoding == "reference":
                axes, shape_of = ((), ()), ()
            elif self.encoding == "conventional":
                axes = (axes[0][:1] or ("data",), ())
                shape_of = (n_dev,)
            elif self.encoding == "alternative":
                axes = ((), axes[1][:1] or ("model",))
                shape_of = (n_dev,)
            else:  # custom-registered engine: runs unsharded unless a
                shape_of = ()  # mesh is passed in explicitly

        return SelectionPlan(
            encoding=self.encoding, obs_axes=axes[0], feat_axes=axes[1],
            mesh_shape=shape_of, block=self.block,
            incremental=self.incremental, score=score,
            criterion=resolve_criterion(self.criterion),
        )

    def _resolve_mesh(self, plan: SelectionPlan) -> Mesh | None:
        if self.mesh is not None:
            return self.mesh if plan.mesh_axes else None
        if not plan.mesh_shape:
            return None
        devices = self.devices if not isinstance(self.devices, int) else None
        if getattr(plan, "hosts", 1) > 1 and devices is None:
            # Multi-host: the per-host block mesh is LOCAL — jax.devices()
            # spans every process under jax.distributed, and a mesh over
            # non-addressable devices cannot place host blocks.
            devices = jax.local_devices()
        return make_mesh(plan.mesh_shape, plan.mesh_axes, devices=devices)

    def _resolve_source_score(self, source: DataSource) -> ScoreFn:
        if self.score is not None:
            return self.score
        st = source.stats(self.block_obs)  # scan honours the memory knob
        if st.discrete:
            return MIScore(num_values=st.num_values, num_classes=st.num_classes)
        return PearsonMIScore()

    def _continuous_mi_error(self, what: str) -> ValueError:
        return ValueError(
            f"MIScore needs discrete categories but {what} holds continuous "
            "values: pass bins= to quantile-discretise on the fly — "
            "MRMRSelector(num_select=..., bins=32) — or score with "
            "PearsonMIScore()"
        )

    def _maybe_bin_source(self, source: DataSource) -> DataSource:
        """Wrap a continuous source for on-the-fly discretisation when
        ``bins=`` is set and the fit is headed down the discrete MI path
        (score None or MI).  Discrete sources and explicit non-MI scores
        pass through untouched."""
        if self.bins is None or isinstance(source, BinnedSource):
            return source
        if self.score is not None and not isinstance(self.score, MIScore):
            return source  # Pearson/custom consume continuous data natively
        if self._source_is_discrete(source):
            return source
        return BinnedSource(source, self.bins, fit_block_obs=self.block_obs)

    def _source_is_discrete(self, source: DataSource) -> bool:
        """Discrete-vs-continuous routing, free when the source's
        ``feature_dtype`` is statically known (no ``iter_blocks`` pass —
        the maxrel path's single-pass I/O promise depends on this)."""
        dt = source.feature_dtype
        if dt is not None:
            return not np.issubdtype(dt, np.floating)
        return source.stats(self.block_obs).discrete

    def _bin_score(self, binned: BinnedSource) -> ScoreFn:
        """Score for a binned fit: auto-sized MI, or the user's MIScore
        checked against the code range (codes land in [0, bins))."""
        if self.score is None:
            return MIScore(
                num_values=binned.bins,
                num_classes=binned.stats().num_classes,
            )
        if isinstance(self.score, MIScore) and self.score.num_values < binned.bins:
            raise ValueError(
                f"score num_values={self.score.num_values} < bins="
                f"{binned.bins}: bin codes in [0, {binned.bins}) would "
                "one-hot to all-zero rows and vanish from the counts; "
                "drop the explicit score or set num_values >= bins"
            )
        return self.score

    def _resolve_hosts(self) -> int:
        """The multi-host process count: ``None``/1 single-host, ``"auto"``
        whatever ``jax.distributed`` reports, an int taken at face value
        (mismatches against the actual cluster fail in the collectives)."""
        if self.hosts in (None, 1):
            return 1
        if self.hosts == "auto":
            return int(jax.process_count())
        h = int(self.hosts)
        if h < 1:
            raise ValueError(f"hosts must be >= 1 or 'auto', got {self.hosts!r}")
        return h

    def _resolve_stream_plan(
        self, source: DataSource, score: ScoreFn
    ) -> SelectionPlan:
        """Streaming layout per the paper's §III aspect-ratio rule: tall
        shards blocks over observations, wide shards blocks AND statistics
        over features, both-large runs a 2-D (obs × feat) grid.  A user
        mesh overrides the rule: whatever obs/feat axes it carries are
        used (both present -> 2-D).

        With ``hosts > 1`` the §III rule is applied across *processes*
        (see :func:`repro.dist.multihost.resolve_host_shards`); the
        device layout here is then per-host — blocks shard over this
        host's LOCAL devices on the observation axes only, since device
        feature-sharding would pad the statistics width past the exact
        shard width and break cross-host state alignment."""
        m, n = source.num_obs, source.num_features
        aspect = m / max(n, 1)
        obs = _axes_tuple(self.obs_axes)
        feat = _axes_tuple(self.feat_axes)
        hosts = self._resolve_hosts()
        if hosts > 1:
            if self.mesh is not None:
                raise ValueError(
                    "hosts > 1 plans the per-host device mesh from local "
                    "devices; pass devices= instead of mesh="
                )
            n_dev = (
                len(jax.local_devices())
                if self.devices is None
                else _device_count(self.devices)
            )
            if n_dev <= 1:
                obs, feat, shape = (), (), ()
            else:
                obs, feat, shape = obs[:1] or ("data",), (), (n_dev,)
            block_obs = effective_block_obs(
                self.block_obs, math.prod(shape) if obs else 1
            )
            q = int(self.batch_candidates)
            if q < 1:
                raise ValueError(f"batch_candidates must be >= 1, got {q}")
            if int(self.readahead) < 0:
                raise ValueError(
                    f"readahead must be >= 0, got {self.readahead}"
                )
            return SelectionPlan(
                encoding="streaming", obs_axes=obs, feat_axes=feat,
                mesh_shape=shape, block=self.block, block_obs=block_obs,
                incremental=True, prefetch=resolve_prefetch(self.prefetch),
                score=score, criterion=resolve_criterion(self.criterion),
                batch_candidates=q, spill_dir=self.spill_dir,
                spill_budget_bytes=self.spill_budget_bytes,
                readahead=int(self.readahead), hosts=hosts,
            )
        if self.mesh is not None:
            obs = tuple(a for a in obs if a in self.mesh.shape)
            feat = tuple(a for a in feat if a in self.mesh.shape)
            if not obs and not feat:
                # Silently running unsharded on a user-supplied mesh would
                # betray the device budget; streaming has no fallback
                # engine to reroute to, so fail loudly.
                raise ValueError(
                    f"mesh axes {tuple(self.mesh.shape)} share no axis with "
                    f"obs_axes {_axes_tuple(self.obs_axes)} or feat_axes "
                    f"{_axes_tuple(self.feat_axes)}; streaming shards "
                    "blocks over observation and/or feature axes"
                )
            shape = tuple(self.mesh.shape[a] for a in obs + feat)
        else:
            n_dev = _device_count(self.devices)
            if n_dev <= 1:
                obs, feat, shape = (), (), ()
            elif aspect >= TALL_RATIO:
                obs, feat, shape = obs[:1] or ("data",), (), (n_dev,)
            elif aspect <= WIDE_RATIO:
                obs, feat, shape = (), feat[:1] or ("model",), (n_dev,)
            else:
                gf = _grid_factor(m, n, n_dev)
                if gf is not None:
                    obs = obs[:1] or ("data",)
                    feat = feat[:1] or ("model",)
                    shape = gf
                elif aspect >= 1.0:
                    obs, feat, shape = obs[:1] or ("data",), (), (n_dev,)
                else:
                    obs, feat, shape = (), feat[:1] or ("model",), (n_dev,)
        # Record the EFFECTIVE block size: the placer rounds blocks up to
        # the observation extent, and plan_ must report what actually runs
        # (same rule, one implementation).
        block_obs = effective_block_obs(
            self.block_obs, math.prod(shape[: len(obs)]) if obs else 1
        )
        q = int(self.batch_candidates)
        if q < 1:
            raise ValueError(f"batch_candidates must be >= 1, got {q}")
        if int(self.readahead) < 0:
            raise ValueError(
                f"readahead must be >= 0, got {self.readahead}"
            )
        # Streaming always uses the running criterion fold: the recompute
        # baseline would multiply the number of passes over the data by L.
        # prefetch resolves here ("auto" -> backend heuristic) so plan_
        # records the int that actually ran, like effective block_obs.
        return SelectionPlan(
            encoding="streaming", obs_axes=obs, feat_axes=feat,
            mesh_shape=shape, block=self.block, block_obs=block_obs,
            incremental=True, prefetch=resolve_prefetch(self.prefetch),
            score=score, criterion=resolve_criterion(self.criterion),
            batch_candidates=q, spill_dir=self.spill_dir,
            spill_budget_bytes=self.spill_budget_bytes,
            readahead=int(self.readahead),
        )

    def _finish_fit(
        self, res: MRMRResult, plan: SelectionPlan, mesh: Mesh | None,
        n_features: int,
    ) -> "MRMRSelector":
        """Populate the read side from an engine's result (every fit path)."""
        # Custom-registered engines may omit provenance: backfill both the
        # engine and the criterion from the plan that drove the fit.
        if not res.engine:
            res = dataclasses.replace(res, engine=plan.encoding)
        if not res.criterion:
            res = dataclasses.replace(
                res, criterion=resolve_criterion(plan.criterion).name
            )
        self.selected_ = np.asarray(res.selected)
        self.gains_ = np.asarray(res.gains)
        self.scores_ = (
            None if res.relevance is None else np.asarray(res.relevance)
        )
        ranking = np.full((n_features,), len(self.selected_) + 1, np.int32)
        ranking[self.selected_] = np.arange(1, len(self.selected_) + 1)
        self.ranking_ = ranking
        self.n_features_in_ = int(n_features)
        self.result_ = res
        self.plan_ = plan
        self.mesh_ = mesh
        return self

    def get_support(self, indices: bool = False) -> np.ndarray:
        """Selected-feature mask (or ascending indices), sklearn-style.

        ``indices=False`` returns a ``(num_features,)`` boolean mask;
        ``indices=True`` the selected ids in ASCENDING order (use
        ``selected_`` for selection order).
        """
        if self.selected_ is None or self.n_features_in_ is None:
            raise RuntimeError("fit() first")
        mask = np.zeros((self.n_features_in_,), bool)
        mask[self.selected_] = True
        return np.flatnonzero(mask) if indices else mask

    def _fit_source(self, source: DataSource) -> "MRMRSelector":
        if self.encoding not in ("auto", "streaming"):
            raise ValueError(
                f"encoding {self.encoding!r} needs in-memory arrays; "
                "DataSource inputs run the 'streaming' engine "
                "(materialise the source yourself to force another engine)"
            )
        check_num_select(self.num_select, source.num_features)
        source = self._maybe_bin_source(source)
        if isinstance(source, BinnedSource):
            score = self._bin_score(source)
        else:
            score = self._resolve_source_score(source)
            if isinstance(score, MIScore) and not self._source_is_discrete(
                source
            ):
                # Explicit MI on float blocks would silently truncate to
                # int32 inside the one-hot encode — fail actionably here.
                raise self._continuous_mi_error("the source")
        # Conditional criteria (jmi/cmim) need a score with a class-
        # conditioned decomposition — fail before the first I/O pass.
        mrmr_mod.check_conditional_support(
            score, resolve_criterion(self.criterion)
        )
        plan = self._resolve_stream_plan(source, score)
        if isinstance(source, BinnedSource):
            plan = dataclasses.replace(plan, bins=source.bins)
        mesh = self._resolve_mesh(plan)
        engine = get_engine("streaming")
        res = engine(source, None, num_select=self.num_select, plan=plan,
                     mesh=mesh)
        return self._finish_fit(res, plan, mesh, source.num_features)

    def fit(self, X, y=None) -> "MRMRSelector":
        """X: (observations, features) array + y: (observations,) targets,
        or a ``DataSource`` alone (targets come from its blocks)."""
        if (
            not isinstance(X, DataSource)
            and self.encoding == "streaming"
            and y is not None
        ):
            # Arrays through the streaming engine: wrap in the adapter so
            # one code path owns the block walk.
            X, y = ArraySource(X, y), None
        if isinstance(X, DataSource):
            if y is not None:
                raise ValueError(
                    "y comes from the DataSource; call fit(source) alone"
                )
            return self._fit_source(X)
        if y is None:
            raise ValueError(
                "y is required for array inputs (only DataSource fits "
                "carry their own targets)"
            )
        if self._resolve_hosts() > 1:
            raise ValueError(
                "hosts > 1 runs the streaming engine: pass a DataSource, "
                "or arrays with encoding='streaming'"
            )
        X = jnp.asarray(X)
        y = jnp.asarray(y)
        if X.ndim != 2 or y.shape[0] != X.shape[0]:
            raise ValueError(f"bad shapes X{X.shape} y{y.shape}")
        check_num_select(self.num_select, X.shape[1])
        discrete_X = bool(
            jnp.issubdtype(X.dtype, jnp.integer) or X.dtype == jnp.bool_
        )
        plan_bins = None
        if (
            self.bins is not None
            and not discrete_X
            and (self.score is None or isinstance(self.score, MIScore))
        ):
            # In-memory binned fit: one sketch pass over the wrapped array,
            # then the discrete engines consume the int codes — same edges
            # (and hence same selection) as the streaming path.
            binned = BinnedSource(
                ArraySource(np.asarray(X), np.asarray(y)),
                self.bins,
                fit_block_obs=self.block_obs,
            )
            score = self._bin_score(binned)
            codes, labels = binned.materialize(self.block_obs)
            X, y = jnp.asarray(codes), jnp.asarray(labels)
            plan_bins = binned.bins
        else:
            score = self._resolve_score(X, y)
            if isinstance(score, MIScore) and not discrete_X:
                # The conventional engine would silently astype(int32) the
                # float columns — truncated categories, wrong MI.
                raise self._continuous_mi_error("X")
        # Discrete MI scores need integral class labels; every other score
        # (Pearson, custom) keeps continuous targets intact.
        y = y.astype(jnp.int32 if isinstance(score, MIScore) else jnp.float32)
        # Conditional criteria (jmi/cmim) need a score with a class-
        # conditioned decomposition — fail before planning/compiling.
        mrmr_mod.check_conditional_support(
            score, resolve_criterion(self.criterion)
        )
        plan = self._resolve_plan(X.shape, score)
        if plan.score is None:
            plan = dataclasses.replace(plan, score=score)
        if plan_bins is not None:
            plan = dataclasses.replace(plan, bins=plan_bins)
        mesh = self._resolve_mesh(plan)
        engine = get_engine(plan.encoding)
        res = engine(X, y, num_select=self.num_select, plan=plan, mesh=mesh)
        return self._finish_fit(res, plan, mesh, X.shape[1])

    def transform(self, X):
        """Selected columns of ``X``, ordered by selection rank.

        Accepts a ``DataSource`` too: blocks stream through and only the
        ``(num_obs, num_select)`` result materialises."""
        if self.selected_ is None:
            raise RuntimeError("fit() first")
        if isinstance(X, DataSource):
            return np.concatenate(
                [blk[:, self.selected_]
                 for blk, _ in X.iter_blocks(self.block_obs)]
            )
        return np.asarray(X)[:, self.selected_]

    def fit_transform(self, X, y=None):
        return self.fit(X, y).transform(X)


__all__ = [
    "MRMRSelector",
    "SelectionPlan",
    "check_num_select",
    "plan_selection",
    "register_engine",
    "get_engine",
    "available_encodings",
    "build_engine_fn",
]
