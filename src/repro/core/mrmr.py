"""mRMR greedy drivers — single-device reference + three sharded layouts.

The paper distributes mRMR two ways, keyed by data layout (Section III/IV):

* **conventional** — rows are observations; the dataset is sharded over the
  observation axis.  Scoring = per-shard contingency tables, element-wise
  summed across the cluster (mapper+combiner+reducer -> one ``psum``).
  Discrete data only, MI score only (as in the paper).
* **alternative** — rows are features; the dataset is sharded over the
  feature axis.  The class vector and selected features are broadcast
  (replicated); scoring is entirely local (map-only job), any score fn.
* **grid** (beyond paper) — shard observations *and* features on a 2-D mesh;
  contingency tables psum over the observation axes, argmax over the
  feature axes.  Generalises both encodings and removes the paper's
  single-axis memory walls.

All drivers run the greedy loop as ONE compiled ``lax.fori_loop`` over
static shapes (selected sets become masks), instead of one Spark job per
iteration.  ``incremental=True`` carries the criterion's running fold
state (each iteration scores candidates against only the newly selected
feature — O(N·L) total pair scores); ``incremental=False`` is the
paper-faithful recomputation (O(N·L²)) kept as the reproduction baseline.

The greedy *objective* is pluggable (``criterion=``): every driver folds
per-candidate redundancy terms through a :class:`repro.core.criteria.
Criterion` (``init_state`` / ``update`` / ``objective``) instead of
hard-coding the paper's difference form — ``mid`` (the default, Eq. 1),
``miq`` (quotient) and ``maxrel`` (relevance only, skips pair scoring)
ship built-in; the distributed argmax/psum structure is criterion-
independent.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import math
import threading
from collections import OrderedDict
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import contingency
from repro.core.criteria import Criterion, resolve_criterion
from repro.core.scores import (
    CustomScore,
    MIScore,
    ScoreFn,
    cmi_from_counts,
    mi_from_counts,
)
from repro.dist import compat
from repro.dist.sharding import axes_tuple as _axes_tuple

Array = jax.Array

# Plain Python scalars, NOT jnp values: materialising a jnp constant at
# import time would initialise the XLA backend and lock the device count
# before launchers can set --xla_force_host_platform_device_count.
_NEG_INF = float("-inf")
_BIG_ID = 2**31 - 1


@dataclasses.dataclass
class MRMRResult:
    """Selection report: order, objective trajectory, relevance, provenance.

    ``selected[l]`` is the feature picked at iteration ``l`` and
    ``gains[l]`` the value of the criterion objective it was picked at —
    the per-iteration objective trajectory.  ``relevance`` is the full
    per-feature relevance vector from the fit's first scoring pass
    (NaN-filled for :class:`~repro.core.scores.CustomScore` fits, which
    have no relevance/redundancy decomposition; ``None`` from engines
    predating the richer report).  ``criterion`` and ``engine`` name what
    produced the result (empty when the producer did not say — the
    selector backfills both from the plan).

    ``io`` is the fit's I/O ledger — engines that stream a source report
    ``passes`` / ``blocks_read`` / ``bytes_read`` (plus a ``cache``
    sub-dict splitting parse-vs-replay traffic when a spill cache was
    on); in-memory engines leave it ``None``.
    """

    selected: Array
    gains: Array
    relevance: Array | None = None
    criterion: str = ""
    engine: str = ""
    io: dict | None = None

    @property
    def objective_trajectory(self) -> Array:
        """Alias of ``gains`` — the objective value of each pick."""
        return self.gains

    # -- serialization ---------------------------------------------------
    # The result cache persists entries as JSON and launch/select.py can
    # write one to --output; non-finite floats (CustomScore relevance is
    # NaN-filled) are encoded as the strings "nan"/"inf"/"-inf" so the
    # payload stays strict JSON.

    def to_json(self) -> str:
        """Serialise to a strict-JSON string (``from_json`` round-trips)."""

        def enc(a):
            if a is None:
                return None
            x = np.asarray(a)
            if np.issubdtype(x.dtype, np.floating):
                return [
                    float(v) if math.isfinite(v) else repr(float(v))
                    for v in x.tolist()
                ]
            return x.tolist()

        return json.dumps(
            dict(
                version=1,
                selected=enc(self.selected),
                gains=enc(self.gains),
                relevance=enc(self.relevance),
                criterion=self.criterion,
                engine=self.engine,
                io=self.io,
            )
        )

    @classmethod
    def from_json(cls, payload: str) -> "MRMRResult":
        """Rebuild a result serialised by :meth:`to_json`."""
        d = json.loads(payload)

        def dec(vals, dtype):
            if vals is None:
                return None
            return jnp.asarray(
                [float(v) if isinstance(v, str) else v for v in vals], dtype
            )

        return cls(
            selected=dec(d["selected"], jnp.int32),
            gains=dec(d["gains"], jnp.float32),
            relevance=dec(d.get("relevance"), jnp.float32),
            criterion=d.get("criterion", ""),
            engine=d.get("engine", ""),
            io=d.get("io"),
        )


# ---------------------------------------------------------------------------
# warm jit cache
# ---------------------------------------------------------------------------

class WarmJitCache:
    """Bounded LRU of built (jit-wrapped) callables, keyed by hashables.

    ``jax.jit`` memoises traces/executables *per wrapper object*: a fresh
    ``jax.jit(fn)`` on every fit recompiles even when the job is identical.
    Keeping the wrapper alive across fits keyed by what actually shapes the
    computation (engine × criterion × score × block shape × mesh) means
    repeat traffic — the selection service's whole diet — never pays
    trace or compile again.  Unhashable keys (e.g. a custom criterion
    holding a list) bypass the cache rather than erroring.
    """

    def __init__(self, capacity: int = 32):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._entries: OrderedDict = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.uncacheable = 0

    def get_or_build(self, key, build):
        try:
            hash(key)
        except TypeError:
            with self._lock:
                self.uncacheable += 1
            return build()
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                self.hits += 1
                return self._entries[key]
        fn = build()
        with self._lock:
            self.misses += 1
            self._entries[key] = fn
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.evictions += 1
        return fn

    def stats(self) -> dict:
        with self._lock:
            return dict(
                size=len(self._entries), capacity=self.capacity,
                hits=self.hits, misses=self.misses,
                evictions=self.evictions, uncacheable=self.uncacheable,
            )

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.hits = self.misses = self.evictions = self.uncacheable = 0


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _pvary(x, axes: tuple):
    """Mark ``x`` as varying over ``axes`` (shard_map VMA typing helper)."""
    return compat.pvary(x, axes)


def _flat_axis_index(axes: Sequence[str], mesh_axis_sizes: dict) -> Array:
    """Row-major flattened index of this shard along ``axes``."""
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * mesh_axis_sizes[a] + lax.axis_index(a)
    return idx


def _distributed_argmax(values: Array, ids: Array, axes: tuple):
    """Global (argmax-id, max) of per-shard score slices.

    Ties break toward the smallest global feature id, making the result
    independent of the shard layout (tested property).
    """
    arg_local = jnp.argmax(values)
    best_local = values[arg_local]
    id_local = ids[arg_local]
    if axes:
        best = lax.pmax(best_local, axes)
        cand = jnp.where(best_local >= best, id_local, _BIG_ID)
        k = lax.pmin(cand, axes)
    else:
        best, k = best_local, id_local
    return k, best


def _loop_state(n_local: int, num_select: int):
    return dict(
        mask=jnp.zeros((n_local,), jnp.bool_),
        selected=jnp.full((num_select,), -1, jnp.int32),
        gains=jnp.zeros((num_select,), jnp.float32),
    )


def _check_custom_criterion(score: ScoreFn, crit: Criterion) -> None:
    """CustomScore computes the complete objective itself (Listing 7), so
    it bypasses the criterion fold; any non-default criterion would be
    silently ignored — fail instead."""
    if isinstance(score, CustomScore) and crit.name != "mid":
        raise ValueError(
            f"criterion {crit.name!r} cannot be combined with CustomScore: "
            "a custom get_result computes the complete objective itself "
            "(paper Listing 7); use the default 'mid' criterion"
        )


def _nan_relevance(n: int) -> Array:
    """Relevance placeholder for CustomScore fits (no rel/red split)."""
    return jnp.full((n,), jnp.nan, jnp.float32)


def check_conditional_support(score: ScoreFn, crit: Criterion) -> None:
    """Conditional criteria (JMI/CMIM) need a score whose pair statistic
    decomposes per class; fail at build time with the fix, not with an
    opaque error from inside a traced engine body."""
    if crit.needs_conditional_redundancy and not getattr(
        score, "supports_conditional", False
    ):
        raise ValueError(
            f"criterion {crit.name!r} needs class-conditioned pair "
            f"statistics I(x_k; x_j | y), but {type(score).__name__} has "
            "no conditional decomposition; score with MIScore (pass "
            "bins= to discretise continuous data first)"
        )


# ---------------------------------------------------------------------------
# single-device reference driver (feature-major), any score fn
# ---------------------------------------------------------------------------

def mrmr_reference(
    X_rows: Array,
    y: Array,
    num_select: int,
    score: ScoreFn,
    *,
    incremental: bool = True,
    criterion: Criterion | str = "mid",
) -> MRMRResult:
    """Pure-jnp mRMR on one device. ``X_rows`` is feature-major (N, M)."""
    crit = resolve_criterion(criterion)
    _check_custom_criterion(score, crit)
    check_conditional_support(score, crit)
    n, m = X_rows.shape
    custom = isinstance(score, CustomScore)
    use_incr = incremental and score.incremental_safe and not custom
    fold = crit.needs_redundancy and not custom
    cond = fold and crit.needs_conditional_redundancy

    def red_terms(row):
        return score.redundancy_terms(X_rows, row, y, conditional=cond)

    rel = None if custom else score.relevance(X_rows, y)
    state = _loop_state(n, num_select)
    # Custom scores accumulate selected rows in f32, matching the
    # alternative body (whose psum-gathered rows are always f32).
    sel_dtype = jnp.float32 if custom else X_rows.dtype
    state["sel_rows"] = jnp.zeros((num_select, m), sel_dtype)
    if use_incr and fold:
        state["crit"] = crit.init_state(n)

    def body(l, st):
        if custom:
            g = score.full_score(X_rows, y, st["sel_rows"], l)
        elif not fold:
            g = crit.objective(rel, crit.init_state(n), l)
        elif use_incr:
            g = crit.objective(rel, st["crit"], l)
        else:
            def inner(j, cs):
                return crit.update(cs, red_terms(st["sel_rows"][j]), j)

            cs = lax.fori_loop(0, l, inner, crit.init_state(n))
            g = crit.objective(rel, cs, l)
        g = jnp.where(st["mask"], _NEG_INF, g)
        k = jnp.argmax(g)
        xk = X_rows[k]
        st = dict(st)
        st["mask"] = st["mask"].at[k].set(True)
        st["selected"] = st["selected"].at[l].set(k.astype(jnp.int32))
        st["gains"] = st["gains"].at[l].set(g[k])
        st["sel_rows"] = lax.dynamic_update_slice(
            st["sel_rows"], xk[None].astype(sel_dtype), (l, 0)
        )
        if use_incr and fold:
            st["crit"] = crit.update(st["crit"], red_terms(xk), l)
        return st

    state = lax.fori_loop(0, num_select, body, state)
    return MRMRResult(
        selected=state["selected"],
        gains=state["gains"],
        relevance=_nan_relevance(n) if custom else rel.astype(jnp.float32),
        criterion=crit.name,
        engine="reference",
    )


# ---------------------------------------------------------------------------
# conventional encoding: observations sharded, contingency-table psum
# ---------------------------------------------------------------------------

def _conventional_body(
    X_loc: Array,  # (M_loc, N) int, padded rows hold out-of-range values
    y_loc: Array,  # (M_loc,)
    *,
    num_select: int,
    score: MIScore,
    criterion: Criterion,
    obs_axes: tuple,
    incremental: bool,
    block: int,
    onehot_dtype=jnp.bfloat16,
    static_inner: bool = False,
):
    n = X_loc.shape[1]
    v, c = score.num_values, score.num_classes
    crit = criterion

    def counts_vs(tgt_loc: Array, vy: int) -> Array:
        """Local map+combine, then the reduce: one psum over the obs axes."""
        cnt = contingency.batched_counts(
            X_loc, tgt_loc, v, vy, block=block, onehot_dtype=onehot_dtype
        )
        return lax.psum(cnt, obs_axes) if obs_axes else cnt

    def pair_terms(tgt_loc: Array) -> dict:
        """The criterion's redundancy terms for one selected column.

        Marginal-only criteria keep the exact pre-conditional graph (a
        (N, v, v) count + MI — bitwise-identical selections, no class
        axis).  Conditional criteria fuse the class into the target, so
        ONE psummed (N, v, v*c) count yields both terms.
        """
        if not crit.needs_conditional_redundancy:
            return dict(
                marginal=mi_from_counts(counts_vs(tgt_loc, v)), conditional=None
            )
        fused = contingency.fuse_targets(tgt_loc, y_loc, v, c)
        cnt = counts_vs(fused, v * c).reshape(n, v, v, c)
        return dict(
            marginal=mi_from_counts(cnt.sum(-1)),
            conditional=cmi_from_counts(cnt),
        )

    rel = mi_from_counts(counts_vs(y_loc, c))  # (N,) replicated
    state = _loop_state(n, num_select)
    if incremental and crit.needs_redundancy:
        state["crit"] = crit.init_state(n)

    # Selected *column indices* stand in for the paper's broadcast tables.
    def body(l, st):
        if not crit.needs_redundancy:
            g = crit.objective(rel, crit.init_state(n), l)
        elif incremental:
            g = crit.objective(rel, st["crit"], l)
        else:
            # static_inner trades the data-dependent trip count (paper: l-1
            # passes at step l) for a fixed L-pass masked loop, so the
            # dry-run HLO carries the recompute cost explicitly.
            def inner(j, cs):
                xj = jnp.take(X_loc, st["selected"][j], axis=1)
                folded = crit.update(cs, pair_terms(xj), j)
                if static_inner:
                    # Fold unconditionally (the dry-run carries the cost),
                    # keep the state only for the real j < l iterations.
                    return jax.tree.map(
                        lambda a, b: jnp.where(j < l, b, a), cs, folded
                    )
                return folded

            hi = num_select if static_inner else l
            cs = lax.fori_loop(0, hi, inner, crit.init_state(n))
            g = crit.objective(rel, cs, l)
        g = jnp.where(st["mask"], _NEG_INF, g)
        k = jnp.argmax(g).astype(jnp.int32)
        st = dict(st)
        st["mask"] = st["mask"].at[k].set(True)
        st["selected"] = st["selected"].at[l].set(k)
        st["gains"] = st["gains"].at[l].set(g[k])
        if incremental and crit.needs_redundancy:
            xk = jnp.take(X_loc, k, axis=1)
            st["crit"] = crit.update(st["crit"], pair_terms(xk), l)
        return st

    state = lax.fori_loop(0, num_select, body, state)
    return state["selected"], state["gains"], rel


def mrmr_conventional(
    X: Array,  # (M, N) conventional layout
    y: Array,  # (M,)
    num_select: int,
    score: MIScore,
    *,
    mesh: Mesh | None = None,
    obs_axes=("data",),
    incremental: bool = True,
    block: int = 64,
    criterion: Criterion | str = "mid",
) -> MRMRResult:
    """Paper's conventional-encoding MapReduce job on a device mesh.

    The dataset is sharded over observations (`obs_axes`); contingency
    tables are locally combined and globally summed with one all-reduce per
    scoring pass — the MapReduce shuffle collapsed onto the ICI ring.
    """
    crit = resolve_criterion(criterion)
    fn = make_conventional_fn(
        num_select, score, mesh=mesh, obs_axes=obs_axes,
        incremental=incremental, block=block, criterion=crit,
    )
    sel, gains, rel = fn(X, y)
    return MRMRResult(sel, gains, relevance=rel, criterion=crit.name,
                      engine="conventional")


def make_conventional_fn(
    num_select: int,
    score: MIScore,
    *,
    mesh: Mesh | None = None,
    obs_axes=("data",),
    incremental: bool = True,
    block: int = 64,
    onehot_dtype=jnp.bfloat16,
    static_inner: bool = False,
    criterion: Criterion | str = "mid",
):
    """Jitted (X, y) -> (selected, gains, relevance) for the conventional
    encoding.

    Exposed separately so benchmarks can ``.lower().compile()`` the job and
    run the same HLO collective analysis as the LM dry-run cells.
    """
    if not isinstance(score, MIScore):
        raise ValueError(
            "conventional encoding works with discrete MI only (paper §IV.B); "
            "use the alternative encoding for custom scores"
        )
    kwargs = dict(
        num_select=num_select,
        score=score,
        criterion=resolve_criterion(criterion),
        incremental=incremental,
        block=block,
        onehot_dtype=onehot_dtype,
        static_inner=static_inner,
    )
    if mesh is None:
        return jax.jit(functools.partial(_conventional_body, obs_axes=(), **kwargs))
    obs_axes = _axes_tuple(obs_axes)
    body = functools.partial(_conventional_body, obs_axes=obs_axes, **kwargs)
    return jax.jit(
        compat.shard_map(
            body,
            mesh=mesh,
            in_specs=(P(obs_axes, None), P(obs_axes)),
            out_specs=P(),
        )
    )


# ---------------------------------------------------------------------------
# alternative encoding: features sharded, broadcast class/selected, map-only
# ---------------------------------------------------------------------------

def _alternative_body(
    X_loc: Array,  # (N_loc, M) feature-major shard
    y: Array,  # (M,) replicated (the paper's broadcast v_class)
    *,
    num_select: int,
    n_features: int,
    score: ScoreFn,
    criterion: Criterion,
    feat_axes: tuple,
    axis_sizes: dict,
    incremental: bool,
):
    n_loc, m = X_loc.shape
    crit = criterion
    shard = _flat_axis_index(feat_axes, axis_sizes) if feat_axes else jnp.int32(0)
    ids = shard * n_loc + jnp.arange(n_loc, dtype=jnp.int32)
    valid = ids < n_features
    custom = isinstance(score, CustomScore)
    use_incr = incremental and score.incremental_safe and not custom
    fold = crit.needs_redundancy and not custom
    cond = fold and crit.needs_conditional_redundancy

    def red_terms(row):
        # y is replicated (the paper's broadcast v_class), so the
        # class-conditioned pair statistic stays a map-only local job.
        return score.redundancy_terms(X_loc, row, y, conditional=cond)

    rel = None if custom else score.relevance(X_loc, y)
    state = _loop_state(n_loc, num_select)
    # mask and the criterion's fold state are per-shard slices -> varying
    # along the feature axes.
    state["mask"] = _pvary(state["mask"], feat_axes)
    if use_incr and fold:
        state["crit"] = _pvary(crit.init_state(n_loc), feat_axes)
    # The paper's broadcast v_s: replicated buffer of selected feature rows.
    state["sel_rows"] = jnp.zeros((num_select, m), jnp.float32)

    def fetch_row(k):
        """getEntry: psum of the masked local rows -> replicated (M,)."""
        mine = (ids == k).astype(jnp.float32)
        row = (X_loc.astype(jnp.float32) * mine[:, None]).sum(axis=0)
        return lax.psum(row, feat_axes) if feat_axes else row

    def body(l, st):
        if custom:
            g = score.full_score(X_loc, y, st["sel_rows"], l)
        elif not fold:
            g = crit.objective(rel, _pvary(crit.init_state(n_loc), feat_axes), l)
        elif use_incr:
            g = crit.objective(rel, st["crit"], l)
        else:
            def inner(j, cs):
                return crit.update(cs, red_terms(st["sel_rows"][j]), j)

            cs0 = _pvary(crit.init_state(n_loc), feat_axes)
            cs = lax.fori_loop(0, l, inner, cs0)
            g = crit.objective(rel, cs, l)
        g = jnp.where(st["mask"] | ~valid, _NEG_INF, g)
        k, best = _distributed_argmax(g, ids, feat_axes)
        xk = fetch_row(k)
        st = dict(st)
        st["mask"] = st["mask"] | (ids == k)
        st["selected"] = st["selected"].at[l].set(k)
        st["gains"] = st["gains"].at[l].set(best)
        st["sel_rows"] = lax.dynamic_update_slice(st["sel_rows"], xk[None], (l, 0))
        if use_incr and fold:
            st["crit"] = crit.update(st["crit"], red_terms(xk), l)
        return st

    state = lax.fori_loop(0, num_select, body, state)
    rel_out = _nan_relevance(n_loc) if custom else rel.astype(jnp.float32)
    return state["selected"], state["gains"], rel_out


def mrmr_alternative(
    X_rows: Array,  # (N, M) alternative layout (rows = features)
    y: Array,
    num_select: int,
    score: ScoreFn,
    *,
    mesh: Mesh | None = None,
    feat_axes=("model",),
    incremental: bool = True,
    n_features: int | None = None,
    criterion: Criterion | str = "mid",
) -> MRMRResult:
    """Paper's alternative-encoding job: feature-sharded, map-only scoring."""
    crit = resolve_criterion(criterion)
    n_features = int(n_features if n_features is not None else X_rows.shape[0])
    fn = make_alternative_fn(
        num_select, score, n_features, mesh=mesh, feat_axes=feat_axes,
        incremental=incremental, criterion=crit,
    )
    sel, gains, rel = fn(X_rows, y)
    return MRMRResult(sel, gains, relevance=rel[:n_features],
                      criterion=crit.name, engine="alternative")


def make_alternative_fn(
    num_select: int,
    score: ScoreFn,
    n_features: int,
    *,
    mesh: Mesh | None = None,
    feat_axes=("model",),
    incremental: bool = True,
    criterion: Criterion | str = "mid",
):
    """Jitted (X_rows, y) -> (selected, gains, relevance) for the
    alternative encoding.  The relevance output covers the PADDED feature
    extent (callers slice ``[:n_features]``)."""
    crit = resolve_criterion(criterion)
    _check_custom_criterion(score, crit)
    check_conditional_support(score, crit)
    kwargs = dict(
        num_select=num_select,
        n_features=int(n_features),
        score=score,
        criterion=crit,
        incremental=incremental,
    )
    if mesh is None:
        return jax.jit(
            functools.partial(
                _alternative_body, feat_axes=(), axis_sizes={}, **kwargs
            )
        )
    feat_axes = _axes_tuple(feat_axes)
    axis_sizes = {a: mesh.shape[a] for a in feat_axes}
    body = functools.partial(
        _alternative_body, feat_axes=feat_axes, axis_sizes=axis_sizes, **kwargs
    )
    return jax.jit(
        compat.shard_map(
            body,
            mesh=mesh,
            in_specs=(P(feat_axes, None), P()),
            # selected/gains replicate; the relevance slices concatenate
            # back to the (padded) global feature extent.
            out_specs=(P(), P(), P(feat_axes)),
        )
    )


# ---------------------------------------------------------------------------
# grid encoding (beyond paper): shard observations AND features
# ---------------------------------------------------------------------------

def _grid_body(
    X_loc: Array,  # (M_loc, N_loc) conventional-layout tile
    y_loc: Array,  # (M_loc,)
    *,
    num_select: int,
    n_features: int,
    score: MIScore,
    criterion: Criterion,
    obs_axes: tuple,
    feat_axes: tuple,
    axis_sizes: dict,
    block: int,
    incremental: bool,
):
    m_loc, n_loc = X_loc.shape
    v, c = score.num_values, score.num_classes
    crit = criterion
    shard = _flat_axis_index(feat_axes, axis_sizes) if feat_axes else jnp.int32(0)
    ids = shard * n_loc + jnp.arange(n_loc, dtype=jnp.int32)
    valid = ids < n_features

    def counts_vs(tgt_loc: Array, vy: int) -> Array:
        cnt = contingency.batched_counts(X_loc, tgt_loc, v, vy, block=block)
        return lax.psum(cnt, obs_axes) if obs_axes else cnt

    def pair_terms(tgt_loc: Array) -> dict:
        """Redundancy terms for one fetched column — the class fuses into
        the target locally (y_loc is this tile's row slice), so the 3-way
        counts ride the same single psum as the marginal counts."""
        if not crit.needs_conditional_redundancy:
            return dict(
                marginal=mi_from_counts(counts_vs(tgt_loc, v)), conditional=None
            )
        fused = contingency.fuse_targets(tgt_loc, y_loc, v, c)
        cnt = counts_vs(fused, v * c).reshape(n_loc, v, v, c)
        return dict(
            marginal=mi_from_counts(cnt.sum(-1)),
            conditional=cmi_from_counts(cnt),
        )

    def fetch_col(k):
        """Local rows of global column k, replicated across feature axes."""
        k_loc = k - shard * n_loc
        own = (k_loc >= 0) & (k_loc < n_loc)
        col = jnp.take(X_loc, jnp.clip(k_loc, 0, n_loc - 1), axis=1)
        col = jnp.where(own, col, 0).astype(jnp.float32)
        col = lax.psum(col, feat_axes) if feat_axes else col
        return col.astype(X_loc.dtype)

    rel = mi_from_counts(counts_vs(y_loc, c))
    state = _loop_state(n_loc, num_select)
    state["mask"] = _pvary(state["mask"], feat_axes)
    if incremental and crit.needs_redundancy:
        state["crit"] = _pvary(crit.init_state(n_loc), feat_axes)

    def body(l, st):
        if not crit.needs_redundancy:
            g = crit.objective(rel, _pvary(crit.init_state(n_loc), feat_axes), l)
        elif incremental:
            g = crit.objective(rel, st["crit"], l)
        else:
            def inner(j, cs):
                xj = fetch_col(st["selected"][j])
                return crit.update(cs, pair_terms(xj), j)

            cs0 = _pvary(crit.init_state(n_loc), feat_axes)
            cs = lax.fori_loop(0, l, inner, cs0)
            g = crit.objective(rel, cs, l)
        g = jnp.where(st["mask"] | ~valid, _NEG_INF, g)
        k, best = _distributed_argmax(g, ids, feat_axes)
        st = dict(st)
        st["mask"] = st["mask"] | (ids == k)
        st["selected"] = st["selected"].at[l].set(k)
        st["gains"] = st["gains"].at[l].set(best)
        if incremental and crit.needs_redundancy:
            xk = fetch_col(k)
            st["crit"] = crit.update(st["crit"], pair_terms(xk), l)
        return st

    state = lax.fori_loop(0, num_select, body, state)
    return state["selected"], state["gains"], rel


def mrmr_grid(
    X: Array,  # (M, N) conventional layout, sharded both ways
    y: Array,
    num_select: int,
    score: MIScore,
    *,
    mesh: Mesh,
    obs_axes=("data",),
    feat_axes=("model",),
    incremental: bool = True,
    block: int = 64,
    n_features: int | None = None,
    criterion: Criterion | str = "mid",
) -> MRMRResult:
    """2-D sharded mRMR: observation axes × feature axes (beyond paper)."""
    crit = resolve_criterion(criterion)
    n_features = int(n_features if n_features is not None else X.shape[1])
    fn = make_grid_fn(
        num_select, score, n_features, mesh=mesh, obs_axes=obs_axes,
        feat_axes=feat_axes, incremental=incremental, block=block,
        criterion=crit,
    )
    sel, gains, rel = fn(X, y)
    return MRMRResult(sel, gains, relevance=rel[:n_features],
                      criterion=crit.name, engine="grid")


def make_grid_fn(
    num_select: int,
    score: MIScore,
    n_features: int,
    *,
    mesh: Mesh,
    obs_axes=("data",),
    feat_axes=("model",),
    incremental: bool = True,
    block: int = 64,
    criterion: Criterion | str = "mid",
):
    """Jitted (X, y) -> (selected, gains, relevance) for the grid encoding.
    The relevance output covers the PADDED feature extent."""
    if not isinstance(score, MIScore):
        raise ValueError("grid encoding is discrete/MI only")
    obs_axes, feat_axes = _axes_tuple(obs_axes), _axes_tuple(feat_axes)
    axis_sizes = {a: mesh.shape[a] for a in feat_axes}
    body = functools.partial(
        _grid_body,
        num_select=num_select,
        n_features=int(n_features),
        score=score,
        criterion=resolve_criterion(criterion),
        obs_axes=obs_axes,
        feat_axes=feat_axes,
        axis_sizes=axis_sizes,
        block=block,
        incremental=incremental,
    )
    return jax.jit(
        compat.shard_map(
            body,
            mesh=mesh,
            in_specs=(P(obs_axes, feat_axes), P(obs_axes)),
            out_specs=(P(), P(), P(feat_axes)),
        )
    )
