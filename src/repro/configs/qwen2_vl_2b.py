"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, M-RoPE + dynamic resolution [arXiv:2409.12191; hf].

Vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings; M-RoPE (t, h, w) position streams are
first-class (sections 16/24/24 over head_dim/2 = 64 frequency slots).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    input_mode="embeddings",
    tie_embeddings=True,
)
