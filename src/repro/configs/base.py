"""Architecture + shape configuration system (``--arch``/``--shape``)."""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One assigned architecture (exact public-literature config)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    rope_theta: float = 1e4
    norm_type: str = "rms"  # "rms" | "ln" (whisper)
    mlp_gated: bool = True  # False -> GELU MLP with biases (whisper)
    use_rope: bool = True  # False -> absolute positions only (whisper)

    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_softmax_topk: bool = True  # False -> sigmoid gates (llama4-style)

    # --- SSM (Mamba-2 / SSD) -------------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # --- hybrid (Jamba) ------------------------------------------------------
    attn_period: int = 0  # one attention layer per `attn_period` layers
    attn_offset: int = 4  # its index within the period (Jamba uses 4)
    moe_period: int = 0  # MoE replaces dense MLP every `moe_period` layers
    mlp_in_ssm_blocks: bool = True  # hybrid blocks carry their own MLP

    # --- encoder-decoder (Whisper) -------------------------------------------
    encoder_layers: int = 0
    decoder_layers: int = 0

    # --- VLM (Qwen2-VL M-RoPE) ------------------------------------------------
    mrope_sections: tuple = ()  # head_dim/2 split into (t, h, w) sections

    # --- frontend stub ---------------------------------------------------------
    input_mode: str = "tokens"  # "tokens" | "embeddings" (audio/vision stub)

    # --- runtime/distribution knobs (tunable; see EXPERIMENTS.md §Perf) -------
    dtype: str = "bfloat16"
    remat: str = "full"  # none | dots | full
    scan_layers: bool = True
    attn_block_q: int = 1024
    attn_block_kv: int = 1024
    # Blockwise (flash-style) attention at/above this sequence length.
    # §Perf iteration 3 (refuted): lowering to 4096 does NOT reduce HLO-level
    # HBM traffic (blocks sum to the same S² bytes and scan carries add
    # copies) — the traffic win belongs to the Pallas flash kernel on real
    # TPU.  Kept at 8192 where the *footprint* forces the blockwise path.
    blockwise_attn_threshold: int = 8192
    fsdp: bool = True  # shard params/optimizer over the data axis
    seq_shard_activations: bool = True  # Megatron-SP style residual sharding
    # TP activation strategy (§Perf iteration 5): "megatron" pins attention
    # heads / MLP hidden to the model axis (partial-sum reductions of token
    # blocks); "gather" leaves them unconstrained, and XLA gathers the
    # model-sharded weights while tokens stay seq-sharded (ZeRO-3-like).
    # Collective bytes favour "gather" when per-layer token-block bytes
    # exceed per-layer param bytes and vice versa — measured per cell in
    # EXPERIMENTS.md §Perf.
    tp_style: str = "megatron"  # "megatron" | "gather"
    microbatches: int = 1  # gradient accumulation
    optimizer_moment_dtype: str = "float32"  # "bfloat16" for the largest archs
    logits_f32: bool = True
    # Inference weights: training keeps f32 masters, but serving reads every
    # weight once per token — storing them at compute precision removes the
    # f32-read + bf16-write convert traffic (3x the bf16 bytes) that
    # dominated the jamba long_500k decode cell (§Perf iteration B1).
    serve_params_dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(
                self, "head_dim", self.d_model // max(self.num_heads, 1)
            )

    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM / hybrid only)."""
        return self.family in ("ssm", "hybrid")

    def layer_kind(self, l: int) -> str:
        """'attn' or 'ssm' mixer at layer l (hybrid interleave)."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid" and self.attn_period:
            return "attn" if (l % self.attn_period) == self.attn_offset else "ssm"
        return "attn"

    def ffn_kind(self, l: int) -> str:
        """'moe', 'dense', or 'none' FFN at layer l."""
        if self.d_ff == 0:
            return "none"
        if self.num_experts:
            if self.moe_period:
                return "moe" if (l % self.moe_period) == 1 else "dense"
            return "moe"
        return "dense"


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The assigned LM shape set (identical across the 10 architectures).
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment's skip rules."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "skipped: pure full-attention arch; long_500k is reserved for "
            "sub-quadratic (SSM/hybrid) families per the assignment"
        )
    return True, ""
