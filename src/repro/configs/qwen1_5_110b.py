"""qwen1.5-110b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064, QKV bias [hf:Qwen/Qwen1.5-0.5B; hf].

§Perf hillclimb cell A (most collective-bound): the deployable train_4k
config is microbatches=8 + bf16 Adam moments + Megatron TP activations —
13.3 GiB/device on the single pod (fits v5e HBM) at a 0.38 roofline-MFU
bound.  ``tp_style="gather"`` with microbatches=1 is ~29% better on the
memory bound (0.54) but needs 45 GiB/device — see EXPERIMENTS.md §Perf.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=49152,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
    microbatches=8,
    optimizer_moment_dtype="bfloat16",
)
