"""whisper-tiny [audio]: enc-dec, conv frontend stubbed (frame embeddings).

4L (4 enc + 4 dec) d_model=384 6H (kv=6) d_ff=1536 vocab=51865
[arXiv:2212.04356; unverified]. LayerNorm + GELU MLP, absolute sinusoidal
positions (no RoPE), attention biases.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    num_layers=8,          # 4 encoder + 4 decoder
    encoder_layers=4,
    decoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    qkv_bias=True,
    norm_type="ln",
    mlp_gated=False,
    use_rope=False,
    input_mode="embeddings",
    tie_embeddings=True,
)
