"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576, MoE 16 experts top-2, Mamba+attention 1:7 interleave
[arXiv:2403.19887; hf].

Superblock of 8 layers: attention at offset 4, Mamba elsewhere; MoE
replaces the dense MLP on odd layers (period 2).  We use Mamba-2 mixers
(unified SSM substrate; Jamba ships Mamba-1 — recorded deviation).
Largest arch in the pool: bf16 optimizer moments (see DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    num_experts=16,
    experts_per_token=2,
    attn_period=8,
    attn_offset=4,
    moe_period=2,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
    optimizer_moment_dtype="bfloat16",
    microbatches=8,  # §Perf A6: fits v5e HBM (EXPERIMENTS.md)
)
