"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

Backbone only (text stream); top-1 routing uses sigmoid gates as in the
Llama-4 router.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    num_experts=16,
    experts_per_token=1,
    num_shared_experts=1,
    router_softmax_topk=False,
    rope_theta=5e5,
    microbatches=4,  # §Perf A6: fits v5e HBM (EXPERIMENTS.md)
    optimizer_moment_dtype="bfloat16",
)
