"""Architecture registry: ``--arch <id>`` -> exact public config.

``smoke_config()`` derives the reduced same-family configs used by the
per-arch CPU smoke tests (full configs are exercised only via the dry-run).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, shape_applicable  # noqa: F401
from repro.configs import (
    dbrx_132b,
    jamba_1_5_large_398b,
    llama4_scout_17b_a16e,
    mamba2_1_3b,
    minitron_4b,
    qwen1_5_0_5b,
    qwen1_5_110b,
    qwen2_vl_2b,
    whisper_tiny,
    yi_6b,
)

REGISTRY: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        whisper_tiny,
        qwen1_5_110b,
        minitron_4b,
        yi_6b,
        qwen1_5_0_5b,
        qwen2_vl_2b,
        dbrx_132b,
        llama4_scout_17b_a16e,
        mamba2_1_3b,
        jamba_1_5_large_398b,
    )
}


def list_archs() -> list[str]:
    return sorted(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    return REGISTRY[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: small widths/layers/experts/vocab."""
    cfg = get_config(name)
    upd: dict = dict(
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2),
        head_dim=32,
        d_ff=0 if cfg.d_ff == 0 else 256,
        vocab_size=512,
        dtype="float32",
        remat="none",
        fsdp=False,
        seq_shard_activations=False,
    )
    if cfg.family == "hybrid":
        upd["num_layers"] = cfg.attn_period  # one superblock
    elif cfg.is_encdec:
        upd["num_layers"] = 4
        upd["encoder_layers"] = 2
        upd["decoder_layers"] = 2
    else:
        upd["num_layers"] = 2
    if cfg.num_experts:
        upd["num_experts"] = 4
        upd["experts_per_token"] = min(cfg.experts_per_token, 2)
        upd["capacity_factor"] = 2.0
    if cfg.family in ("ssm", "hybrid"):
        upd["ssm_state"] = 32
        upd["ssm_headdim"] = 32
        upd["ssm_chunk"] = 32
    if cfg.mrope_sections:
        upd["mrope_sections"] = (4, 6, 6)  # head_dim/2 = 16 slots
    if cfg.is_encdec:
        upd["num_kv_heads"] = 4  # whisper is MHA: keep kv == heads
    return dataclasses.replace(cfg, **upd)
