"""mamba2-1.3b [ssm]: 48L d_model=2048 attention-free, d_ff=0,
vocab=50280, ssm_state=128, SSD (state-space duality)
[arXiv:2405.21060; unverified].

d_inner = 2*d_model = 4096, headdim 64 -> 64 SSM heads, ngroups=1.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=1,       # unused (attention-free)
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
)
