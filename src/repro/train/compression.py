"""Gradient compression: int8 quantised all-reduce with error feedback.

Distributed-optimization trick for bandwidth-bound data parallelism: before
the DP gradient sync, each leaf is quantised to int8 with a per-leaf scale;
the quantisation error is carried in a residual buffer and added back the
next step (error feedback, Seide et al. / 1-bit SGD lineage), so the
compression is unbiased over time and training converges (validated in
tests/test_compression.py against uncompressed training).

Usage (composes with any train step):

    comp = GradCompression.init(params)
    grads_q, comp = comp.compress(grads)        # int8 payload on the wire
    grads   = lax.psum(grads_q, 'data')         # 4x fewer collective bytes
    grads   = comp.dequantize(grads, n_shards)

or end-to-end via ``compressed_psum(grads, axes, state)``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array


def _leaf_scale(g: Array) -> Array:
    """Symmetric per-leaf scale mapping max|g| -> 127."""
    m = jnp.max(jnp.abs(g))
    return jnp.where(m > 0, m / 127.0, 1.0).astype(jnp.float32)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GradCompression:
    """Error-feedback residuals, one per gradient leaf."""

    residual: Any

    @classmethod
    def init(cls, params) -> "GradCompression":
        return cls(
            residual=jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
        )

    def compress(self, grads):
        """-> ((int8 leaves, f32 scales), new_state)."""

        def one(g, r):
            g = g.astype(jnp.float32) + r
            s = _leaf_scale(g)
            q = jnp.clip(jnp.round(g / s), -127, 127).astype(jnp.int8)
            new_r = g - q.astype(jnp.float32) * s
            return q, s, new_r

        flat, treedef = jax.tree.flatten(grads)
        rflat = treedef.flatten_up_to(self.residual)
        qs = [one(g, r) for g, r in zip(flat, rflat)]
        q = treedef.unflatten([t[0] for t in qs])
        s = treedef.unflatten([t[1] for t in qs])
        new = GradCompression(residual=treedef.unflatten([t[2] for t in qs]))
        return (q, s), new


def compressed_psum(grads, axes, state: GradCompression, world: int):
    """Quantise -> psum(int8 widened to int32) -> dequantise -> mean.

    Wire payload per leaf: 1 byte/elem + one scalar scale (vs 4 bytes/elem
    for f32 psum).  Scales are all-reduced with max so dequantisation is
    shard-consistent.
    """
    (q, s), new_state = state.compress(grads)
    s_max = jax.tree.map(lambda v: lax.pmax(v, axes), s)
    # requantise against the shared scale so the integer sum is exact
    def requant(qi, si, sm):
        g = qi.astype(jnp.float32) * si
        return jnp.clip(jnp.round(g / sm), -127, 127).astype(jnp.int8)

    q = jax.tree.map(requant, q, s, s_max)
    summed = jax.tree.map(
        lambda qi: lax.psum(qi.astype(jnp.int32), axes), q
    )
    out = jax.tree.map(
        lambda qsum, sm: qsum.astype(jnp.float32) * sm / world, summed, s_max
    )
    return out, new_state
