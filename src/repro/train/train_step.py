"""Train-step builder: value_and_grad + AdamW + microbatch gradient
accumulation, with sharding-spec trees for pjit in/out."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    step: Array

    @classmethod
    def create(cls, params, opt_cfg: AdamWConfig):
        return cls(
            params=params,
            opt=adamw_init(params, opt_cfg),
            step=jnp.zeros((), jnp.int32),
        )


def _split_microbatches(batch, k: int):
    """(B, ...) -> (k, B/k, ...) for every array leaf with a batch dim."""

    def split(x):
        if x.ndim == 0:
            return jnp.broadcast_to(x, (k,))
        b = x.shape[0]
        assert b % k == 0, (b, k)
        return x.reshape((k, b // k) + x.shape[1:])

    return jax.tree.map(split, batch)


def make_train_step(bundle, opt_cfg: AdamWConfig):
    """-> train_step(state, batch) -> (state, metrics). jit-ready."""
    micro = max(1, bundle.cfg.microbatches)

    def loss_fn(params, batch):
        loss, metrics = bundle.train_loss(params, batch)
        return loss, metrics

    def train_step(state: TrainState, batch):
        if micro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(state.params, batch)
        else:
            mb = _split_microbatches(batch, micro)

            def acc_body(carry, mb_i):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mb_i
                )
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l), None

            gz = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (gsum, lsum), _ = jax.lax.scan(acc_body, (gz, 0.0), mb)
            grads = jax.tree.map(lambda g: g / micro, gsum)
            loss = lsum / micro
            metrics = {"loss": loss, "aux_loss": jnp.zeros((), jnp.float32)}

        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt, state.params, opt_cfg
        )
        metrics = dict(metrics, **opt_metrics, total_loss=loss)
        return (
            TrainState(params=new_params, opt=new_opt, step=state.step + 1),
            metrics,
        )

    return train_step


def make_train_state_specs(bundle):
    """PartitionSpec tree for TrainState (opt moments inherit param specs)."""
    pspecs = bundle.specs()
    return TrainState(
        params=pspecs,
        opt={
            "m": pspecs,
            "v": pspecs,
            "count": P(),
        },
        step=P(),
    )


def train_state_shapes(bundle, opt_cfg: AdamWConfig):
    """ShapeDtypeStruct TrainState for the dry-run (no allocation)."""
    pshapes = bundle.shapes()
    mdt = jnp.dtype(opt_cfg.moment_dtype)
    mom = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, mdt), pshapes)
    return TrainState(
        params=pshapes,
        opt={
            "m": mom,
            "v": mom,
            "count": jax.ShapeDtypeStruct((), jnp.int32),
        },
        step=jax.ShapeDtypeStruct((), jnp.int32),
    )
