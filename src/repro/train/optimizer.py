"""AdamW with global-norm clipping, decoupled weight decay, LR schedules and
configurable moment dtype.

``moment_dtype="bfloat16"`` halves the optimizer-state HBM footprint — the
distributed-optimization lever that fits the 398B hybrid's train state on a
single 256-chip v5e pod (see DESIGN.md §5 / EXPERIMENTS.md §Perf).  Moments
are stored in the low precision but all update math runs in f32.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


def warmup_cosine(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    """Linear warmup then cosine decay to ``floor * peak_lr``."""

    def schedule(step: Array) -> Array:
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak_lr + (1 - floor) * peak_lr * 0.5 * (
            1 + jnp.cos(jnp.pi * frac)
        )
        return jnp.where(step < warmup, warm, cos)

    return schedule


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float | Callable = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    moment_dtype: str = "float32"

    def lr_at(self, step: Array) -> Array:
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.asarray(self.learning_rate, jnp.float32)


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    """-> (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip_norm / jnp.maximum(gnorm, 1e-12))
    dt = jnp.dtype(cfg.moment_dtype)
    lr = cfg.lr_at(count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / b1c
        vhat = v32 / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2 and cfg.weight_decay:  # decay matrices, not norms/bias
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step
        return new_p.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    is_triple = lambda x: isinstance(x, tuple) and len(x) == 3
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=is_triple)
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=is_triple)
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=is_triple)
    new_state = {"m": new_m, "v": new_v, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
