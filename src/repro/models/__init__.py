from repro.models.model import build_model, ModelBundle  # noqa: F401
