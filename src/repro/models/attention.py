"""GQA attention: full, blockwise (flash-style), and cached decode paths.

* ``full``      — materialises (bq, kv) scores; used for train_4k.
* ``blockwise`` — online-softmax over KV blocks with a python loop over query
  blocks, so causal skipping is *static*: query block i only scans KV blocks
  [0, ceil((i+1)·bq / bkv)), halving prefill FLOPs and keeping the largest
  live buffer at (B, KV, G, bq, bkv).  This is the Rabe–Staats/Flash
  adaptation for XLA; on real TPU the same schedule drops into a Pallas
  flash kernel, but the dry-run must lower on the CPU backend, so the
  memory-efficient schedule lives at the jnp level.
* ``decode``    — one query position against a (B, S, KV, D) cache.

All paths share GQA via a (KV, G) head split and compute softmax in f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamDef, dense_def

Array = jax.Array

_NEG = -1e30


def attention_defs(cfg) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    defs = {
        "wq": ParamDef((d, h * hd), ("fsdp", "heads"), scale=d**-0.5),
        "wk": ParamDef((d, kv * hd), ("fsdp", "kv_heads"), scale=d**-0.5),
        "wv": ParamDef((d, kv * hd), ("fsdp", "kv_heads"), scale=d**-0.5),
        "wo": ParamDef((h * hd, d), ("heads", "fsdp"), scale=(h * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((h * hd,), ("heads",), init="zeros")
        defs["bk"] = ParamDef((kv * hd,), ("kv_heads",), init="zeros")
        defs["bv"] = ParamDef((kv * hd,), ("kv_heads",), init="zeros")
    return defs


def qkv_project(p: dict, x: Array, cfg, xkv: Array | None = None):
    """-> q (B,S,H,D), k/v (B,T,KV,D). ``xkv`` enables cross-attention."""
    b, s, _ = x.shape
    xkv = x if xkv is None else xkv
    t = xkv.shape[1]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype))
    k = (xkv @ p["wk"].astype(x.dtype))
    v = (xkv @ p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    return (
        q.reshape(b, s, h, hd),
        k.reshape(b, t, kv, hd),
        v.reshape(b, t, kv, hd),
    )


def out_project(p: dict, o: Array) -> Array:
    b, s, h, hd = o.shape
    return o.reshape(b, s, h * hd) @ p["wo"].astype(o.dtype)


def _split_gqa(q: Array, num_kv: int) -> Array:
    """(B, S, H, D) -> (B, S, KV, G, D)."""
    b, s, h, d = q.shape
    return q.reshape(b, s, num_kv, h // num_kv, d)


def repeat_kv(k: Array, groups: int) -> Array:
    """(B, T, KV, D) -> (B, T, KV*G, D): Megatron-style KV-head replication.

    Under TP > kv_heads the grouped (KV, G) score layout cannot shard over
    the model axis (the head reshape splits the sharded dim); replicating KV
    up to the query head count keeps every attention tensor sharded H-ways.
    Per device this is *smaller* than the replicated-KV fallback whenever
    TP > G, and the broadcast is collective-free (source is replicated).
    """
    if groups == 1:
        return k
    b, t, kv, d = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, t, kv, groups, d))
    return k.reshape(b, t, kv * groups, d)


def full_attention(q: Array, k: Array, v: Array, *, causal: bool) -> Array:
    """q (B,S,H,D), k/v (B,T,KV,D) -> (B,S,H,D)."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    qg = _split_gqa(q, kvh) * (d**-0.5)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    if causal:
        t = k.shape[1]
        mask = jnp.tril(jnp.ones((s, t), jnp.bool_), k=t - s)
        scores = jnp.where(mask[None, None, None], scores, _NEG)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, d)


def blockwise_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool,
    block_q: int = 1024,
    block_kv: int = 1024,
) -> Array:
    """Memory-efficient attention; q (B,S,H,D), k/v (B,T,KV,D)."""
    b, s, h, d = q.shape
    t = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    block_q = min(block_q, s)
    block_kv = min(block_kv, t)
    assert s % block_q == 0 and t % block_kv == 0, (s, t, block_q, block_kv)
    nq, nkv = s // block_q, t // block_kv

    qg = _split_gqa(q, kvh) * (d**-0.5)  # (B, S, KV, G, D)
    kb = k.reshape(b, nkv, block_kv, kvh, d)
    vb = v.reshape(b, nkv, block_kv, kvh, d)
    offset = t - s if causal else 0  # query i attends keys <= i + offset

    outs = []
    for qi in range(nq):  # python loop: static causal skipping
        q_blk = jax.lax.dynamic_slice_in_dim(qg, qi * block_q, block_q, axis=1)
        q_hi = offset + (qi + 1) * block_q  # exclusive key bound
        hi = min(nkv, -(-q_hi // block_kv)) if causal else nkv

        def body(carry, kv_blk):
            m, l, acc = carry
            kj, vj, j = kv_blk
            sc = jnp.einsum("bskgd,btkd->bkgst", q_blk, kj).astype(jnp.float32)
            if causal:
                qpos = offset + qi * block_q + jnp.arange(block_q)
                kpos = j * block_kv + jnp.arange(block_kv)
                msk = qpos[:, None] >= kpos[None, :]
                sc = jnp.where(msk[None, None, None], sc, _NEG)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bkgst,btkd->bkgsd", p.astype(q.dtype), vj)
            acc_new = acc * alpha[..., None].astype(q.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, block_q), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, block_q), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, block_q, d), q.dtype)
        ks = jnp.moveaxis(kb[:, :hi], 1, 0)  # (hi, B, bkv, KV, D)
        vs = jnp.moveaxis(vb[:, :hi], 1, 0)
        js = jnp.arange(hi)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, js))
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(q.dtype)
        outs.append(jnp.moveaxis(out, 3, 1))  # (B, bq, KV, G, D)

    return jnp.concatenate(outs, axis=1).reshape(b, s, h, d)


def decode_attention(
    q: Array, k_cache: Array, v_cache: Array, pos: Array | None = None
) -> Array:
    """q (B, 1, H, D) against cache (B, T, KV, D) -> (B, 1, H, D).

    ``pos`` (scalar decode cursor) masks cache positions > pos, so caches
    over-allocated to the generation budget attend only to written slots.
    """
    b, _, h, d = q.shape
    kvh = k_cache.shape[2]
    qg = _split_gqa(q, kvh) * (d**-0.5)
    sc = jnp.einsum("bskgd,btkd->bkgst", qg, k_cache).astype(jnp.float32)
    if pos is not None:
        kpos = jnp.arange(k_cache.shape[1], dtype=jnp.int32)
        sc = jnp.where(kpos <= pos, sc, _NEG)
    probs = jax.nn.softmax(sc, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v_cache)
    return out.reshape(b, 1, h, d)


def attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool,
    block_q: int,
    block_kv: int,
    blockwise_threshold: int = 8192,
) -> Array:
    """Dispatch: full attention below the threshold, blockwise at/above."""
    if q.shape[1] == 1:
        return decode_attention(q, k, v)
    if max(q.shape[1], k.shape[1]) >= blockwise_threshold:
        return blockwise_attention(
            q, k, v, causal=causal, block_q=block_q, block_kv=block_kv
        )
    return full_attention(q, k, v, causal=causal)
