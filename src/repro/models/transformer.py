"""Decoder stack assembly: scan-over-layer-groups, remat, SP residual stream.

One machinery covers all decoder-only families:

* uniform stacks (dense / MoE / SSM / VLM): group size 1, scanned L times;
* hybrid (Jamba): group = ``attn_period`` layers with a static intra-group
  pattern (attn at ``attn_offset``, MoE every ``moe_period``), scanned
  L/period times — heterogeneous layers become a homogeneous scan.

The residual stream is optionally sequence-sharded between blocks
(Megatron-SP): XLA inserts the all-gather before attention QKV and the
reduce-scatter after the output projections.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models.layers import (
    ParamDef,
    mlp_apply,
    mlp_defs,
    norm_apply,
    norm_defs,
)
from repro.models.rope import apply_mrope, apply_rope, text_mrope_positions

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class RunCtx:
    """Per-call runtime context (mesh, positions, decode cursor)."""

    cfg: Any
    mesh: Optional[Mesh]
    batch_axes: tuple = ("pod", "data")
    seq_axis: Optional[str] = "model"
    positions: Optional[Array] = None  # (B, S) or (B, S, 3) for M-RoPE
    pos: Optional[Array] = None  # scalar decode cursor
    causal: bool = True
    collect_cache: bool = False  # prefill: emit per-layer caches

    def axes(self):
        if self.mesh is None:
            return (), None
        ba = tuple(a for a in self.batch_axes if a in self.mesh.shape)
        sa = self.seq_axis if (
            self.seq_axis in self.mesh.shape and self.cfg.seq_shard_activations
        ) else None
        return ba, sa

    def constrain_residual(self, x: Array) -> Array:
        """Residual stream sharding: P(batch, seq(SP), None)."""
        if self.mesh is None:
            return x
        ba, sa = self.axes()
        if x.shape[1] == 1:
            sa = None  # decode: a single position cannot be sequence-sharded
        elif sa is not None and x.shape[1] % self.mesh.shape[sa] != 0:
            sa = None
        return lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(ba, sa, None))
        )

    def constrain_tp(self, x: Array, tp_dim: int) -> Array:
        """Pin a tensor-parallel intermediate: batch on dim 0, ``tp_dim``
        sharded over the model axis.

        This is the Megatron invariant that keeps the BACKWARD pass sharded:
        without it, XLA's sharding propagation through scan+remat can drop
        the TP annotation of the MLP hidden / attention heads, materialise
        *full-size* f32 weight gradients per layer, and sync them with a
        model-axis all-reduce — measured at 87% of all collective bytes on
        qwen1.5-110b/train_4k before this constraint (EXPERIMENTS.md §Perf).
        """
        if self.mesh is None or "model" not in self.mesh.shape:
            return x
        if getattr(self.cfg, "tp_style", "megatron") != "megatron":
            return x  # "gather" style: let XLA move weights, not tokens
        ba, _ = self.axes()
        spec: list = [None] * x.ndim
        ext = 1
        for a in ba:
            ext *= self.mesh.shape[a]
        if ba and x.shape[0] % ext == 0:
            spec[0] = ba
        if x.shape[tp_dim] % self.mesh.shape["model"] == 0:
            spec[tp_dim] = "model"
        return lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec))
        )


# ---------------------------------------------------------------------------
# per-layer defs / apply
# ---------------------------------------------------------------------------

def layer_defs(cfg, kind: str, ffn_kind: str) -> dict:
    d = cfg.d_model
    defs: dict = {"ln1": norm_defs(d, cfg.norm_type)}
    if kind == "attn":
        defs["attn"] = attn_mod.attention_defs(cfg)
    else:
        defs["ssm"] = mamba_mod.mamba_defs(cfg)
    if ffn_kind == "dense":
        defs["ln2"] = norm_defs(d, cfg.norm_type)
        defs["mlp"] = mlp_defs(
            d, cfg.d_ff, gated=cfg.mlp_gated, bias=not cfg.mlp_gated
        )
    elif ffn_kind == "moe":
        defs["ln2"] = norm_defs(d, cfg.norm_type)
        defs["moe"] = moe_mod.moe_defs(cfg)
    return defs


def _apply_rope_qk(q, k, ctx: RunCtx):
    cfg = ctx.cfg
    if cfg.mrope_sections:
        pos = ctx.positions
        if pos.ndim == 2:  # text-only stream: t=h=w
            pos = text_mrope_positions(pos)
        q = apply_mrope(q, pos, cfg.mrope_sections, theta=cfg.rope_theta)
        k = apply_mrope(k, pos, cfg.mrope_sections, theta=cfg.rope_theta)
    else:
        q = apply_rope(q, ctx.positions, theta=cfg.rope_theta)
        k = apply_rope(k, ctx.positions, theta=cfg.rope_theta)
    return q, k


def attn_block(p: dict, h: Array, ctx: RunCtx, cache: dict | None):
    cfg = ctx.cfg
    q, k, v = attn_mod.qkv_project(p, h, cfg)
    if cfg.use_rope:
        q, k = _apply_rope_qk(q, k, ctx)
    if cache is not None:
        # decode: write this step's K/V at the cursor, attend over the cache.
        kc = lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), ctx.pos, axis=1
        )
        vc = lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), ctx.pos, axis=1
        )
        out = attn_mod.decode_attention(q, kc, vc, ctx.pos)
        return attn_mod.out_project(p, out), {"k": kc, "v": vc}
    # Decode caches keep the compact KV-head layout; compute replicates KV
    # heads up to H so scores/probs shard over the model axis (see
    # attn_mod.repeat_kv) and pins every head tensor with constrain_tp —
    # the Megatron TP invariant that keeps weight grads sharded in bwd.
    new_cache = {"k": k, "v": v} if ctx.collect_cache else None
    # Repeat KV heads up to H ONLY when H shards over the model axis —
    # otherwise the repeated (replicated) K/V and the (B, H, S, S) probs
    # blow up by the group factor (measured: minitron 24H on tp=16 went to
    # 101 GiB/device before this guard; see EXPERIMENTS.md §Perf).
    tp = ctx.mesh.shape["model"] if (
        ctx.mesh is not None and "model" in ctx.mesh.shape
    ) else 1
    if cfg.tp_style == "megatron" and q.shape[2] % tp == 0 and tp > 1:
        k = attn_mod.repeat_kv(k, q.shape[2] // k.shape[2])
        v = attn_mod.repeat_kv(v, q.shape[2] // v.shape[2])
        q = ctx.constrain_tp(q, 2)
        k = ctx.constrain_tp(k, 2)
        v = ctx.constrain_tp(v, 2)
    out = attn_mod.attention(
        q, k, v,
        causal=ctx.causal,
        block_q=cfg.attn_block_q,
        block_kv=cfg.attn_block_kv,
        blockwise_threshold=cfg.blockwise_attn_threshold,
    )
    out = ctx.constrain_tp(out, 2)
    return attn_mod.out_project(p, out), new_cache


def block_apply(
    p: dict,
    x: Array,
    ctx: RunCtx,
    kind: str,
    ffn_kind: str,
    cache: dict | None,
):
    """One transformer block. Returns (x, aux_loss, new_cache)."""
    cfg = ctx.cfg
    h = norm_apply(p["ln1"], x, cfg.norm_type, cfg.norm_eps)
    emit = cache is not None or ctx.collect_cache
    new_cache = None
    if kind == "attn":
        mix, sub = attn_block(
            p["attn"], h, ctx, cache.get("attn") if cache else None
        )
        if emit:
            new_cache = {"attn": sub}
    else:
        mix, sub = mamba_mod.mamba_apply(
            p["ssm"], h, cfg=cfg, cache=cache.get("ssm") if cache else None,
            collect=ctx.collect_cache,
            constrain=lambda t: ctx.constrain_tp(t, t.ndim - 1),
        )
        if emit:
            new_cache = {"ssm": sub}
    # Constrain the projection output *before* the add: the partial-sum of
    # the TP out-projection then lowers as a reduce-scatter onto the
    # seq-sharded residual instead of a full-size all-reduce (XLA's CPU
    # pipeline lacks the AR->RS rewrite pass; see EXPERIMENTS.md §Perf).
    x = ctx.constrain_residual(x + ctx.constrain_residual(mix))

    aux = jnp.zeros((), jnp.float32)
    if ffn_kind == "dense":
        h2 = norm_apply(p["ln2"], x, cfg.norm_type, cfg.norm_eps)
        y = mlp_apply(
            p["mlp"], h2, gated=cfg.mlp_gated,
            constrain=lambda t: ctx.constrain_tp(t, 2),
        )
        x = ctx.constrain_residual(x + ctx.constrain_residual(y))
    elif ffn_kind == "moe":
        h2 = norm_apply(p["ln2"], x, cfg.norm_type, cfg.norm_eps)
        ba, _ = ctx.axes()
        y, aux = moe_mod.moe_apply(
            p["moe"], h2, cfg=cfg, mesh=ctx.mesh, batch_axes=ba
        )
        x = ctx.constrain_residual(x + y)
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# group pattern + stack
# ---------------------------------------------------------------------------

def group_pattern(cfg) -> list[tuple[str, str]]:
    """Static (mixer_kind, ffn_kind) pattern of one scan group."""
    period = cfg.attn_period if cfg.family == "hybrid" else 1
    return [(cfg.layer_kind(j), cfg.ffn_kind(j)) for j in range(period)]


def stack_defs_tree(cfg) -> dict:
    """{'g0': defs, 'g1': ...} one entry per intra-group position, each to be
    scanned over L/period groups."""
    from repro.models.layers import stack_defs

    pattern = group_pattern(cfg)
    n_groups = cfg.num_layers // len(pattern)
    assert cfg.num_layers % len(pattern) == 0, (cfg.num_layers, len(pattern))
    return {
        f"g{j}": stack_defs(layer_defs(cfg, kind, ffn), n_groups)
        for j, (kind, ffn) in enumerate(pattern)
    }


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)  # "full": save nothing inside the block


def stack_apply(
    params: dict, x: Array, ctx: RunCtx, caches: dict | None
):
    """Run the full layer stack. caches: {'g{j}': stacked cache} or None.

    Returns (x, total_aux, new_caches_or_None).
    """
    cfg = ctx.cfg
    pattern = group_pattern(cfg)

    def group_body(carry, xs):
        x, aux = carry
        new_caches = {}
        for j, (kind, ffn) in enumerate(pattern):
            p_j = xs[f"g{j}"]
            c_j = xs.get(f"cache_g{j}")

            def fn(p, xx, cc, _kind=kind, _ffn=ffn):
                return block_apply(p, xx, ctx, _kind, _ffn, cc)

            x, aux_j, nc = _remat(fn, cfg.remat)(p_j, x, c_j)
            aux = aux + aux_j
            if nc is not None:
                new_caches[f"cache_g{j}"] = nc
        return (x, aux), new_caches

    xs = {k: v for k, v in params.items() if k.startswith("g")}
    if caches is not None:
        xs.update({f"cache_{k}": v for k, v in caches.items()})
    (x, aux), new_caches_stacked = lax.scan(
        group_body, (x, jnp.zeros((), jnp.float32)), xs
    )
    if caches is not None or ctx.collect_cache:
        new_caches = {
            k[len("cache_"):]: v for k, v in new_caches_stacked.items()
        }
        return x, aux, new_caches
    return x, aux, None
