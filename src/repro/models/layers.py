"""Parameter definitions + primitive layers (pure-functional, pytree params).

Single-source-of-truth parameter system: every weight is declared once as a
``ParamDef`` carrying shape, *logical* sharding axes, and init; the same def
tree then yields (a) materialised params, (b) ``ShapeDtypeStruct`` stand-ins
for the dry-run, and (c) ``NamedSharding`` trees — so shardings can never
drift from shapes.
"""

from __future__ import annotations

import dataclasses
import hashlib

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.dist.sharding import ShardingRules, logical_to_spec

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    logical: tuple  # logical axis names (len == len(shape))
    init: str = "normal"  # normal | zeros | ones
    scale: float = 0.02
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def dense_def(d_in: int, d_out: int, logical=("fsdp", "ff"), dtype="float32"):
    return ParamDef(
        (d_in, d_out), logical, init="normal", scale=d_in ** -0.5, dtype=dtype
    )


def stack_defs(defs, n: int):
    """Prepend a scan-over-layers axis to every def in the tree."""
    return jax.tree.map(
        lambda d: ParamDef(
            (n,) + d.shape, ("none",) + d.logical, d.init, d.scale, d.dtype
        ),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def init_params(defs, key: Array):
    """Materialise a param pytree (path-keyed fold_in: order-independent)."""

    def leaf(path, d: ParamDef):
        h = int.from_bytes(
            hashlib.md5(_path_str(path).encode()).digest()[:4], "little"
        )
        k = jax.random.fold_in(key, h)
        if d.init == "zeros":
            return jnp.zeros(d.shape, d.dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, d.dtype)
        return (jax.random.normal(k, d.shape, d.dtype) * d.scale).astype(d.dtype)

    return jax.tree_util.tree_map_with_path(
        leaf, defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )


def param_shapes(defs):
    """ShapeDtypeStruct tree (dry-run stand-ins; no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype)),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def param_specs(defs, mesh: Mesh, rules: ShardingRules):
    return jax.tree.map(
        lambda d: logical_to_spec(d.logical, d.shape, mesh, rules),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def param_shardings(defs, mesh: Mesh, rules: ShardingRules):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        param_specs(defs, mesh, rules),
        is_leaf=lambda x: not isinstance(x, (dict, list, tuple)),
    )


def count_params(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    total = 0
    for d in leaves:
        n = 1
        for s in d.shape:
            n *= s
        total += n
    return total


# ---------------------------------------------------------------------------
# primitive layers
# ---------------------------------------------------------------------------

def rms_norm(x: Array, w: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * w.astype(dt)


def layer_norm(x: Array, w: Array, b: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * w.astype(dt) + b.astype(dt)


def linear(x: Array, w: Array, b: Array | None = None) -> Array:
    y = x @ w.astype(x.dtype)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def _id(x: Array) -> Array:
    return x


def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array,
           constrain=_id) -> Array:
    """``constrain`` pins the ff-sharded hidden (Megatron TP invariant)."""
    g = jax.nn.silu(constrain(linear(x, w_gate)))
    h = constrain(g * constrain(linear(x, w_up)))
    return linear(h, w_down)


def gelu_mlp(x: Array, w_in: Array, b_in, w_out: Array, b_out,
             constrain=_id) -> Array:
    return linear(constrain(jax.nn.gelu(constrain(linear(x, w_in, b_in)))),
                  w_out, b_out)


def norm_defs(d: int, norm_type: str = "rms") -> dict:
    defs = {"w": ParamDef((d,), ("none",), init="ones")}
    if norm_type == "ln":
        defs["b"] = ParamDef((d,), ("none",), init="zeros")
    return defs


def norm_apply(p: dict, x: Array, norm_type: str = "rms", eps: float = 1e-5):
    if norm_type == "ln":
        return layer_norm(x, p["w"], p["b"], eps)
    return rms_norm(x, p["w"], eps)


def mlp_defs(d_model: int, d_ff: int, *, gated: bool = True, bias: bool = False):
    if gated:
        return {
            "gate": dense_def(d_model, d_ff, ("fsdp", "ff")),
            "up": dense_def(d_model, d_ff, ("fsdp", "ff")),
            "down": dense_def(d_ff, d_model, ("ff", "fsdp")),
        }
    defs = {
        "in": dense_def(d_model, d_ff, ("fsdp", "ff")),
        "out": dense_def(d_ff, d_model, ("ff", "fsdp")),
    }
    if bias:
        defs["b_in"] = ParamDef((d_ff,), ("ff",), init="zeros")
        defs["b_out"] = ParamDef((d_model,), ("none",), init="zeros")
    return defs


def mlp_apply(p: dict, x: Array, *, gated: bool = True, constrain=_id) -> Array:
    if gated:
        return swiglu(x, p["gate"], p["up"], p["down"], constrain)
    return gelu_mlp(x, p["in"], p.get("b_in"), p["out"], p.get("b_out"),
                    constrain)


def cross_entropy_loss(
    logits: Array, targets: Array, mask: Array | None = None
) -> Array:
    """Mean next-token CE in nats; logits (B, S, V) f32, targets (B, S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return nll.mean()
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
