"""build_model(): one bundle per architecture — defs, losses, serve steps,
input/cache specs and shardings for every (arch × shape × mesh) cell."""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist.sharding import ShardingRules, rules_for
from repro.models import encdec as encdec_mod
from repro.models import mamba as mamba_mod
from repro.models.layers import (
    ParamDef,
    cross_entropy_loss,
    init_params,
    norm_apply,
    norm_defs,
    param_shapes,
    param_specs,
    count_params,
)
from repro.models.transformer import RunCtx, group_pattern, stack_apply, stack_defs_tree

Array = jax.Array

AUX_COEF = 0.01


def _pad_vocab(v: int, multiple: int = 16) -> int:
    return -(-v // multiple) * multiple


@dataclasses.dataclass
class ModelBundle:
    cfg: ModelConfig
    mesh: Optional[Mesh]
    defs: dict
    rules: ShardingRules

    # ------------------------------------------------------------------ params
    def init(self, key: Array):
        return init_params(self.defs, key)

    def shapes(self):
        return param_shapes(self.defs)

    def specs(self):
        if self.mesh is None:
            return jax.tree.map(
                lambda d: P(), self.defs,
                is_leaf=lambda x: isinstance(x, ParamDef),
            )
        return param_specs(self.defs, self.mesh, self.rules)

    def shardings(self):
        assert self.mesh is not None
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), self.specs(),
            is_leaf=lambda x: isinstance(x, P),
        )

    def num_params(self) -> int:
        return count_params(self.defs)

    # ------------------------------------------------------------------ ctx
    def _ctx(self, positions, pos=None, causal=True, collect=False) -> RunCtx:
        return RunCtx(
            cfg=self.cfg,
            mesh=self.mesh,
            positions=positions,
            pos=pos,
            causal=causal,
            collect_cache=collect,
        )

    def _batch_axes(self) -> tuple:
        if self.mesh is None:
            return ()
        return tuple(a for a in ("pod", "data") if a in self.mesh.shape)

    # ------------------------------------------------------------------ forward
    def _embed_in(self, params, batch, ctx):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        if "embeds" in batch:  # modality-stub inputs (vlm/audio prefill)
            return batch["embeds"].astype(dt)
        # text path (always present: decode generates tokens)
        return jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)

    def _decoder_logits(self, params, x, ctx, caches):
        cfg = self.cfg
        x, aux, new_caches = stack_apply(params, x, ctx, caches)
        x = norm_apply(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = jnp.einsum(
                "bsd,vd->bsv", x, params["embed"].astype(x.dtype)
            )
        else:
            logits = x @ params["unembed"].astype(x.dtype)
        # vocab-sharded logits: keeps the unembed grad + CE logsumexp sharded
        return ctx.constrain_tp(logits, 2), aux, new_caches

    def train_loss(self, params, batch):
        """-> (loss, metrics). Batch per-family (see input_specs)."""
        cfg = self.cfg
        if cfg.is_encdec:
            return self._encdec_loss(params, batch)
        b, s = batch["targets"].shape
        positions = batch.get(
            "positions",
            jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s)),
        )
        ctx = self._ctx(positions)
        x = self._embed_in(params, batch, ctx)
        x = ctx.constrain_residual(x)
        logits, aux, _ = self._decoder_logits(params, x, ctx, None)
        loss = cross_entropy_loss(logits, batch["targets"])
        total = loss + AUX_COEF * aux
        return total, {"loss": loss, "aux_loss": aux}

    def _encdec_loss(self, params, batch):
        cfg = self.cfg
        ctx = self._ctx(None, causal=False)
        enc_out = encdec_mod.encode(params, batch["enc_embeds"], ctx)
        dctx = self._ctx(None, causal=True)
        dec_in = encdec_mod.embed_decoder_tokens(
            params, batch["dec_tokens"], dctx, 0
        )
        dec_in = dctx.constrain_residual(dec_in)
        logits, _ = encdec_mod.decode_stack(params, dec_in, dctx, enc_out, None)
        loss = cross_entropy_loss(logits, batch["targets"])
        return loss, {"loss": loss, "aux_loss": jnp.zeros((), jnp.float32)}

    # ------------------------------------------------------------------ serving
    def prefill(self, params, batch):
        """Forward pass emitting (last-position logits, decode caches)."""
        cfg = self.cfg
        if cfg.is_encdec:
            ctx = self._ctx(None, causal=False, collect=True)
            enc_out = encdec_mod.encode(params, batch["enc_embeds"], ctx)
            dctx = self._ctx(None, causal=True, collect=True)
            dec_in = encdec_mod.embed_decoder_tokens(
                params, batch["dec_tokens"], dctx, 0
            )
            logits, caches = encdec_mod.decode_stack(
                params, dec_in, dctx, enc_out, None
            )
            return logits[:, -1], caches
        b, s = (
            batch["tokens"].shape
            if "tokens" in batch
            else batch["embeds"].shape[:2]
        )
        positions = batch.get(
            "positions",
            jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s)),
        )
        ctx = self._ctx(positions, collect=True)
        x = self._embed_in(params, batch, ctx)
        x = ctx.constrain_residual(x)
        logits, _, caches = self._decoder_logits(params, x, ctx, None)
        return logits[:, -1], caches

    def serve_step(self, params, batch):
        """One decode step: batch = {tokens (B,1), pos (), caches}."""
        cfg = self.cfg
        pos = batch["pos"]
        tokens = batch["tokens"]
        b = tokens.shape[0]
        if cfg.is_encdec:
            dctx = self._ctx(
                jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32), pos=pos
            )
            dec_in = encdec_mod.embed_decoder_tokens(params, tokens, dctx, pos)
            logits, caches = encdec_mod.decode_stack(
                params, dec_in, dctx, None, batch["caches"]
            )
            return logits, caches
        positions = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(pos, (b, 1, 3)).astype(jnp.int32)
        ctx = self._ctx(positions, pos=pos)
        x = self._embed_in(params, {"tokens": tokens}, ctx)
        logits, _, caches = self._decoder_logits(
            params, x, ctx, batch["caches"]
        )
        return logits, caches

    # ------------------------------------------------------------------ specs
    def _cache_shapes(self, shape: ShapeConfig):
        """Decode-cache ShapeDtypeStructs, keyed like stack_apply expects."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        dt = jnp.dtype(cfg.dtype)
        kv, hd = cfg.num_kv_heads, cfg.head_dim
        if cfg.is_encdec:
            n = cfg.decoder_layers
            kvs = jax.ShapeDtypeStruct((n, b, s, kv, hd), dt)
            return {
                "k": kvs, "v": kvs,
                "xk": jax.ShapeDtypeStruct((n, b, s, kv, hd), dt),
                "xv": jax.ShapeDtypeStruct((n, b, s, kv, hd), dt),
            }
        pattern = group_pattern(cfg)
        ng = cfg.num_layers // len(pattern)
        out = {}
        for j, (kind, _) in enumerate(pattern):
            if kind == "attn":
                sds = jax.ShapeDtypeStruct((ng, b, s, kv, hd), dt)
                out[f"g{j}"] = {"attn": {"k": sds, "v": sds}}
            else:
                md = mamba_mod.mamba_cache_defs(cfg, b)
                out[f"g{j}"] = {
                    "ssm": jax.tree.map(
                        lambda x: jax.ShapeDtypeStruct((ng,) + x.shape, x.dtype),
                        md,
                    )
                }
        return out

    def _cache_specs(self, shape: ShapeConfig):
        """PartitionSpecs mirroring _cache_shapes."""
        cfg = self.cfg
        mesh = self.mesh
        ba = self._batch_axes()
        b, s = shape.global_batch, shape.seq_len

        def ext(axes):
            e = 1
            for a in axes:
                e *= mesh.shape[a]
            return e

        batch_ax = ba if (ba and b % ext(ba) == 0) else None
        model_ok = mesh is not None and "model" in mesh.shape
        kv_ax = (
            "model"
            if model_ok and cfg.num_kv_heads % mesh.shape["model"] == 0
            else None
        )
        # If neither batch nor kv shard, spread the sequence axis.
        seq_axes = []
        if model_ok and kv_ax is None:
            seq_axes.append("model")
        if batch_ax is None and ba:
            seq_axes = [a for a in ba] + seq_axes
        seq_ax = tuple(seq_axes) if seq_axes and s % ext(seq_axes) == 0 else None

        kv_spec = P(None, batch_ax, seq_ax, kv_ax, None)
        if cfg.is_encdec:
            return {"k": kv_spec, "v": kv_spec, "xk": kv_spec, "xv": kv_spec}

        d_in, h, g = mamba_mod.mamba_dims(cfg)
        h_ax = "model" if model_ok and h % mesh.shape["model"] == 0 else None
        c_ax = "model" if model_ok and d_in % mesh.shape["model"] == 0 else None
        ssm_spec = {
            "state": P(None, batch_ax, h_ax, None, None),
            "conv_x": P(None, batch_ax, None, c_ax),
            "conv_b": P(None, batch_ax, None, None),
            "conv_c": P(None, batch_ax, None, None),
        }
        pattern = group_pattern(cfg)
        out = {}
        for j, (kind, _) in enumerate(pattern):
            if kind == "attn":
                out[f"g{j}"] = {"attn": {"k": kv_spec, "v": kv_spec}}
            else:
                out[f"g{j}"] = {"ssm": dict(ssm_spec)}
        return out

    def input_specs(self, shape: ShapeConfig):
        """ShapeDtypeStructs for every model input of this shape cell."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        dt = jnp.dtype(cfg.dtype)
        i32 = jnp.int32
        if shape.kind == "train":
            if cfg.is_encdec:
                return {
                    "enc_embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt),
                    "dec_tokens": jax.ShapeDtypeStruct((b, s), i32),
                    "targets": jax.ShapeDtypeStruct((b, s), i32),
                }
            out = {"targets": jax.ShapeDtypeStruct((b, s), i32)}
            if cfg.input_mode == "embeddings":
                out["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
            else:
                out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
            if cfg.mrope_sections:
                out["positions"] = jax.ShapeDtypeStruct((b, s, 3), i32)
            return out
        if shape.kind == "prefill":
            if cfg.is_encdec:
                return {
                    "enc_embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt),
                    "dec_tokens": jax.ShapeDtypeStruct((b, s), i32),
                }
            out = {}
            if cfg.input_mode == "embeddings":
                out["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
            else:
                out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
            if cfg.mrope_sections:
                out["positions"] = jax.ShapeDtypeStruct((b, s, 3), i32)
            return out
        # decode
        return {
            "tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32),
            "caches": self._cache_shapes(shape),
        }

    def input_shardings(self, shape: ShapeConfig):
        """PartitionSpec tree matching input_specs."""
        cfg = self.cfg
        ba = self._batch_axes()
        mesh = self.mesh

        def ext(axes):
            e = 1
            for a in axes:
                e *= mesh.shape[a]
            return e

        b = shape.global_batch
        batch_ax = ba if (ba and b % ext(ba) == 0) else None
        sa = (
            "model"
            if mesh is not None
            and "model" in mesh.shape
            and cfg.seq_shard_activations
            and shape.seq_len % mesh.shape["model"] == 0
            else None
        )
        tok = P(batch_ax, None)
        emb = P(batch_ax, sa, None)
        if shape.kind == "train":
            if cfg.is_encdec:
                return {
                    "enc_embeds": emb, "dec_tokens": tok, "targets": tok,
                }
            out = {"targets": tok}
            if cfg.input_mode == "embeddings":
                out["embeds"] = emb
            else:
                out["tokens"] = tok
            if cfg.mrope_sections:
                out["positions"] = P(batch_ax, None, None)
            return out
        if shape.kind == "prefill":
            if cfg.is_encdec:
                return {"enc_embeds": emb, "dec_tokens": tok}
            out = {}
            if cfg.input_mode == "embeddings":
                out["embeds"] = emb
            else:
                out["tokens"] = tok
            if cfg.mrope_sections:
                out["positions"] = P(batch_ax, None, None)
            return out
        return {
            "tokens": P(batch_ax, None),
            "pos": P(),
            "caches": self._cache_specs(shape),
        }


def build_model(cfg: ModelConfig, mesh: Mesh | None = None) -> ModelBundle:
    """Construct the bundle (param defs + fns) for one architecture."""
    vocab = _pad_vocab(cfg.vocab_size)
    if vocab != cfg.vocab_size:
        cfg = dataclasses.replace(cfg, vocab_size=vocab)
    rules = (
        rules_for(mesh, fsdp=cfg.fsdp, seq_shard=cfg.seq_shard_activations)
        if mesh is not None
        else ShardingRules()
    )
    if cfg.is_encdec:
        defs = encdec_mod.encdec_defs(cfg)
    else:
        d, v = cfg.d_model, vocab
        defs = dict(stack_defs_tree(cfg))
        # Embeddings-stub archs still decode text: keep the embed table for
        # serve_step's token path.
        defs["embed"] = ParamDef((v, d), ("vocab", "fsdp"), scale=0.02)
        defs["final_norm"] = norm_defs(d, cfg.norm_type)
        if not cfg.tie_embeddings:
            defs["unembed"] = ParamDef((d, v), ("fsdp", "vocab"), scale=d**-0.5)
    return ModelBundle(cfg=cfg, mesh=mesh, defs=defs, rules=rules)
