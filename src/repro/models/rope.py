"""Rotary position embeddings: standard RoPE + M-RoPE (Qwen2-VL) + sinusoidal.

M-RoPE splits the head_dim/2 frequency slots into (t, h, w) sections, each
rotated by its own position stream; for pure-text streams all three position
ids coincide and M-RoPE reduces exactly to RoPE (tested invariant).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def rope_freqs(head_dim: int, theta: float) -> Array:
    """(head_dim/2,) inverse frequencies."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def _rotate(x: Array, cos: Array, sin: Array) -> Array:
    """x (..., D) with interleaved-half convention: [x1, x2] halves."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def apply_rope(
    x: Array, positions: Array, *, theta: float = 1e4
) -> Array:
    """x (B, S, H, D), positions (B, S) int -> rotated x."""
    freqs = rope_freqs(x.shape[-1], theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]  # (B, S, 1, D/2)
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x, cos, sin)


def apply_mrope(
    x: Array, positions: Array, sections: tuple, *, theta: float = 1e4
) -> Array:
    """Qwen2-VL multimodal RoPE.

    x (B, S, H, D); positions (B, S, 3) = (t, h, w) ids; ``sections`` splits
    the D/2 frequency slots, sum(sections) == D//2.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)  # (D/2,)
    # angle per position stream: (B, S, 3, D/2)
    ang_all = positions[..., None].astype(jnp.float32) * freqs[None, None, None]
    # Per-frequency-slot stream selector: slot i of D/2 belongs to stream
    # idx[i] in {0=t, 1=h, 2=w}; gather that stream's angle per slot.
    idx = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=half
    )  # (D/2,)
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang_all, 2, 3),  # (B, S, D/2, 3)
        idx[None, None, :, None].astype(jnp.int32),
        axis=3,
    )[..., 0]  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x, cos, sin)


def text_mrope_positions(positions: Array) -> Array:
    """(B, S) -> (B, S, 3): text tokens use identical t/h/w ids."""
    return jnp.broadcast_to(positions[..., None], positions.shape + (3,))


def sinusoidal_positions(seq_len: int, d_model: int) -> Array:
    """Whisper-style fixed sinusoidal table (S, D)."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / d_model))
    out = jnp.zeros((seq_len, d_model), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang))
    out = out.at[:, 1::2].set(jnp.cos(ang))
    return out
