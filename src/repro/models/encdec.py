"""Whisper-style encoder–decoder backbone.

Per the assignment the audio conv frontend is a STUB: ``input_specs()``
supplies precomputed frame embeddings (B, S_enc, d) directly to the encoder.
The decoder is a standard causal stack with cross-attention; both stacks are
scanned + remat'd like the decoder-only families.  Deviation (DESIGN.md):
decoder positions are sinusoidal rather than learned, so parameter shapes
stay independent of the runtime sequence length.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention as attn_mod
from repro.models.layers import (
    ParamDef,
    mlp_apply,
    mlp_defs,
    norm_apply,
    norm_defs,
    stack_defs,
)
from repro.models.rope import sinusoidal_positions
from repro.models.transformer import RunCtx, _remat

Array = jax.Array


def _enc_layer_defs(cfg) -> dict:
    d = cfg.d_model
    return {
        "ln1": norm_defs(d, cfg.norm_type),
        "attn": attn_mod.attention_defs(cfg),
        "ln2": norm_defs(d, cfg.norm_type),
        "mlp": mlp_defs(d, cfg.d_ff, gated=cfg.mlp_gated, bias=not cfg.mlp_gated),
    }


def _dec_layer_defs(cfg) -> dict:
    d = cfg.d_model
    return {
        "ln1": norm_defs(d, cfg.norm_type),
        "self": attn_mod.attention_defs(cfg),
        "lnx": norm_defs(d, cfg.norm_type),
        "cross": attn_mod.attention_defs(cfg),
        "ln2": norm_defs(d, cfg.norm_type),
        "mlp": mlp_defs(d, cfg.d_ff, gated=cfg.mlp_gated, bias=not cfg.mlp_gated),
    }


def encdec_defs(cfg) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    defs = {
        "embed": ParamDef((v, d), ("vocab", "fsdp"), scale=0.02),
        "enc_blocks": stack_defs(_enc_layer_defs(cfg), cfg.encoder_layers),
        "enc_final": norm_defs(d, cfg.norm_type),
        "dec_blocks": stack_defs(_dec_layer_defs(cfg), cfg.decoder_layers),
        "dec_final": norm_defs(d, cfg.norm_type),
    }
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef((d, v), ("fsdp", "vocab"), scale=d**-0.5)
    return defs


def _enc_block(p, x, ctx: RunCtx):
    cfg = ctx.cfg
    h = norm_apply(p["ln1"], x, cfg.norm_type, cfg.norm_eps)
    q, k, v = attn_mod.qkv_project(p["attn"], h, cfg)
    out = attn_mod.attention(
        q, k, v, causal=False,
        block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
        blockwise_threshold=cfg.blockwise_attn_threshold,
    )
    x = ctx.constrain_residual(x + attn_mod.out_project(p["attn"], out))
    h2 = norm_apply(p["ln2"], x, cfg.norm_type, cfg.norm_eps)
    x = ctx.constrain_residual(
        x + mlp_apply(p["mlp"], h2, gated=cfg.mlp_gated,
                      constrain=lambda y: ctx.constrain_tp(y, 2))
    )
    return x


def encode(params, enc_embeds: Array, ctx: RunCtx) -> Array:
    """(B, S_enc, d) frame embeddings -> encoder states."""
    cfg = ctx.cfg
    s = enc_embeds.shape[1]
    x = enc_embeds + sinusoidal_positions(s, cfg.d_model).astype(
        enc_embeds.dtype
    )
    x = ctx.constrain_residual(x)
    # ctx is a plain dataclass (not a pytree): close over it so remat only
    # sees array args.
    fn = _remat(lambda p, xx: _enc_block(p, xx, ctx), cfg.remat)

    x, _ = lax.scan(lambda c, p: (fn(p, c), None), x, params["enc_blocks"])
    return norm_apply(params["enc_final"], x, cfg.norm_type, cfg.norm_eps)


def _dec_block(p, x, ctx: RunCtx, enc_out, cache):
    """cache: {'k','v' (self), 'xk','xv' (cross)} or None."""
    cfg = ctx.cfg
    emit = cache is not None or ctx.collect_cache
    # --- causal self-attention ---------------------------------------------
    h = norm_apply(p["ln1"], x, cfg.norm_type, cfg.norm_eps)
    q, k, v = attn_mod.qkv_project(p["self"], h, cfg)
    new_cache = None
    if cache is not None:
        kc = lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), ctx.pos, axis=1
        )
        vc = lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), ctx.pos, axis=1
        )
        out = attn_mod.decode_attention(q, kc, vc, ctx.pos)
    else:
        out = attn_mod.attention(
            q, k, v, causal=True,
            block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
            blockwise_threshold=cfg.blockwise_attn_threshold,
        )
        kc, vc = k, v
    x = ctx.constrain_residual(x + attn_mod.out_project(p["self"], out))

    # --- cross-attention ------------------------------------------------------
    hx = norm_apply(p["lnx"], x, cfg.norm_type, cfg.norm_eps)
    if cache is not None:
        xk, xv = cache["xk"], cache["xv"]
        qx = attn_mod.qkv_project(p["cross"], hx, cfg)[0]
        outx = attn_mod.decode_attention(qx, xk, xv)
    else:
        qx, xk, xv = attn_mod.qkv_project(p["cross"], hx, cfg, xkv=enc_out)
        outx = attn_mod.attention(
            qx, xk, xv, causal=False,
            block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
            blockwise_threshold=cfg.blockwise_attn_threshold,
        )
    x = ctx.constrain_residual(x + attn_mod.out_project(p["cross"], outx))

    # --- MLP --------------------------------------------------------------------
    h2 = norm_apply(p["ln2"], x, cfg.norm_type, cfg.norm_eps)
    x = ctx.constrain_residual(
        x + mlp_apply(p["mlp"], h2, gated=cfg.mlp_gated,
                      constrain=lambda y: ctx.constrain_tp(y, 2))
    )
    if emit:
        new_cache = {"k": kc, "v": vc, "xk": xk, "xv": xv}
    return x, new_cache


def decode_stack(
    params, dec_in: Array, ctx: RunCtx, enc_out: Array | None, caches
):
    """Decoder stack. Returns (logits, new_caches_or_None)."""
    cfg = ctx.cfg
    # enc_out/ctx are closed over (None / non-pytree are not remat operands).
    fn = _remat(
        lambda p, xx, cc: _dec_block(p, xx, ctx, enc_out, cc), cfg.remat
    )

    def body(carry, xs):
        x, nc = fn(xs["p"], carry, xs.get("cache"))
        return x, nc

    xs = {"p": params["dec_blocks"]}
    if caches is not None:
        xs["cache"] = caches
    x, new_caches = lax.scan(body, dec_in, xs)
    x = norm_apply(params["dec_final"], x, cfg.norm_type, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = x @ params["unembed"].astype(x.dtype)
    if caches is None and not ctx.collect_cache:
        new_caches = None
    return logits, new_caches


def embed_decoder_tokens(params, tokens: Array, ctx: RunCtx, pos0: Array | int):
    cfg = ctx.cfg
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    if isinstance(pos0, int):  # full sequence starting at pos0 == 0
        s = tokens.shape[1]
        x = x + sinusoidal_positions(s, cfg.d_model).astype(x.dtype)
    else:  # decode: one token at traced position pos0
        d = cfg.d_model
        dim = jnp.arange(0, d, 2, dtype=jnp.float32)
        a = pos0.astype(jnp.float32) / (10000.0 ** (dim / d))
        row = jnp.zeros((d,), jnp.float32)
        row = row.at[0::2].set(jnp.sin(a)).at[1::2].set(jnp.cos(a))
        x = x + row.astype(x.dtype)
    return x
