"""Mamba-2 block (state-space duality / SSD), chunked scan + recurrent decode.

Implements the SSD algorithm of Mamba-2 [arXiv:2405.21060]: within-chunk
quadratic attention-like einsums + an inter-chunk state recurrence, which is
the TPU-friendly formulation (chunk einsums land on the MXU; the recurrence
is an O(S/Q) ``lax.scan`` over small states).  Decode is the exact O(1)
recurrence on a (B, H, P, N) state.

Deviations from the reference CUDA kernel (recorded in DESIGN.md): the
in-projection is split per stream (z/x/B/C/dt) so each weight shards cleanly
on the model axis, and the depthwise causal conv runs as three small
convs (x, B, C) instead of one fused channel block.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import ParamDef

Array = jax.Array


def mamba_dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    heads = d_in // cfg.ssm_headdim
    groups = 1
    return d_in, heads, groups


def mamba_defs(cfg) -> dict:
    d = cfg.d_model
    n, k = cfg.ssm_state, cfg.ssm_conv
    d_in, h, g = mamba_dims(cfg)
    return {
        "in_z": ParamDef((d, d_in), ("fsdp", "ff"), scale=d**-0.5),
        "in_x": ParamDef((d, d_in), ("fsdp", "ff"), scale=d**-0.5),
        "in_b": ParamDef((d, g * n), ("fsdp", "none"), scale=d**-0.5),
        "in_c": ParamDef((d, g * n), ("fsdp", "none"), scale=d**-0.5),
        "in_dt": ParamDef((d, h), ("fsdp", "ssm_heads"), scale=d**-0.5),
        "conv_x": ParamDef((k, d_in), ("none", "ff"), scale=k**-0.5),
        "conv_b": ParamDef((k, g * n), ("none", "none"), scale=k**-0.5),
        "conv_c": ParamDef((k, g * n), ("none", "none"), scale=k**-0.5),
        "dt_bias": ParamDef((h,), ("ssm_heads",), init="zeros"),
        "a_log": ParamDef((h,), ("ssm_heads",), init="zeros"),
        "d_skip": ParamDef((h,), ("ssm_heads",), init="ones"),
        "norm": ParamDef((d_in,), ("ff",), init="ones"),
        "out": ParamDef((d_in, d), ("ff", "fsdp"), scale=d_in**-0.5),
    }


def _causal_conv(x: Array, w: Array, cache: Array | None = None):
    """Depthwise causal conv. x (B, S, C), w (K, C).

    Returns (y, new_cache) where cache holds the last K-1 inputs.
    """
    k = w.shape[0]
    if cache is not None:
        ctx = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
        new_cache = ctx[:, -(k - 1):] if k > 1 else cache
    else:
        ctx = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
        new_cache = None
    # (B, S+K-1, C) -> windows: y[t] = sum_j w[j] * ctx[t + j]
    y = jnp.zeros_like(x)
    s = x.shape[1]
    for j in range(k):
        y = y + ctx[:, j : j + s, :] * w[j].astype(x.dtype)
    return y, new_cache


def _segsum(a: Array) -> Array:
    """a (..., Q) -> (..., Q, Q) lower-tri pairwise sums: out[q, t] =
    sum_{i in (t, q]} a[i] for t <= q, -inf above the diagonal."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), jnp.bool_), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: Array,  # (B, S, H, P) inputs (already dt-scaled OUTSIDE? no: raw)
    dt: Array,  # (B, S, H) positive
    a: Array,  # (H,) negative decay rates
    b: Array,  # (B, S, H, N)
    c: Array,  # (B, S, H, N)
    *,
    chunk: int,
    initial_state: Array | None = None,
):
    """SSD: y[t] = c[t]·state[t], state[t] = exp(a·dt[t])·state[t-1] + dt[t]·b[t]·x[t].

    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    adt = a[None, None, :] * dt  # (B, S, H), negative
    xdt = x * dt[..., None].astype(x.dtype)

    # chunked views: (B, NC, Q, ...)
    xc = xdt.reshape(bsz, nc, q, h, p)
    bc = b.reshape(bsz, nc, q, h, n)
    cc = c.reshape(bsz, nc, q, h, n)
    ac = adt.reshape(bsz, nc, q, h)

    # --- intra-chunk (quadratic within chunk; MXU einsums) -----------------
    l = jnp.exp(_segsum(jnp.moveaxis(ac, -1, -2)))  # (B, NC, H, Q, Q)
    scores = jnp.einsum("bcqhn,bcthn->bchqt", cc, bc)  # (B, NC, H, Q, Q)
    y_diag = jnp.einsum(
        "bchqt,bcthp->bcqhp", (scores * l).astype(x.dtype), xc
    )

    # --- chunk states -------------------------------------------------------
    cum = jnp.cumsum(ac, axis=2)  # (B, NC, Q, H)
    total = cum[:, :, -1:, :]  # (B, NC, 1, H)
    decay_to_end = jnp.exp(total - cum)  # (B, NC, Q, H)
    states = jnp.einsum(
        "bcqhn,bcqhp->bchpn", bc * decay_to_end[..., None].astype(bc.dtype), xc
    )  # (B, NC, H, P, N)

    # --- inter-chunk recurrence ---------------------------------------------
    chunk_decay = jnp.exp(total[:, :, 0, :])  # (B, NC, H)
    s0 = (
        initial_state
        if initial_state is not None
        else jnp.zeros((bsz, h, p, n), x.dtype)
    )

    def step(carry, inp):
        st, dec = inp  # (B, H, P, N), (B, H)
        new = carry * dec[:, :, None, None].astype(carry.dtype) + st
        return new, carry  # emit the state *entering* this chunk

    (final_state, prev_states) = lax.scan(
        step,
        s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (B, NC, H, P, N)

    # --- inter-chunk contribution -------------------------------------------
    decay_from_start = jnp.exp(cum)  # (B, NC, Q, H)
    y_off = jnp.einsum(
        "bcqhn,bchpn->bcqhp",
        (cc * decay_from_start[..., None].astype(cc.dtype)),
        prev_states,
    )

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y, final_state


def ssd_recurrent_step(
    state: Array,  # (B, H, P, N)
    x: Array,  # (B, 1, H, P)
    dt: Array,  # (B, 1, H)
    a: Array,  # (H,)
    b: Array,  # (B, 1, H, N)
    c: Array,  # (B, 1, H, N)
):
    """Exact single-token recurrence for decode."""
    adt = jnp.exp(a[None, :] * dt[:, 0])  # (B, H)
    upd = jnp.einsum(
        "bhn,bhp->bhpn", b[:, 0] * dt[:, 0, :, None].astype(b.dtype), x[:, 0]
    )
    new_state = state * adt[:, :, None, None].astype(state.dtype) + upd
    y = jnp.einsum("bhn,bhpn->bhp", c[:, 0], new_state)[:, None]  # (B,1,H,P)
    return y, new_state


def _gated_rmsnorm(y: Array, z: Array, w: Array, eps: float) -> Array:
    """Mamba-2 output norm: RMSNorm(y * silu(z)) * w."""
    g = (y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32)))
    var = jnp.mean(g * g, axis=-1, keepdims=True)
    return (g * lax.rsqrt(var + eps)).astype(y.dtype) * w.astype(y.dtype)


def mamba_apply(
    p: dict,
    xres: Array,  # (B, S, d) residual-stream input
    *,
    cfg,
    cache: dict | None = None,
    collect: bool = False,
    constrain=lambda t: t,
):
    """Mamba-2 mixer. Returns (y (B,S,d), new_cache_or_None).

    ``cache``: {"state": (B,H,P,N), "conv_x": (B,K-1,d_in),
    "conv_b"/"conv_c": (B,K-1,g*n)} for decode; None for train/prefill.
    ``collect=True`` (prefill) emits the final recurrent state + conv tails
    as a fresh decode cache.
    ``constrain`` pins channel-sharded intermediates to the model axis (the
    same Megatron invariant as attention/MLP — without it XLA drops the TP
    sharding of the in/out-projection gradients in bwd; §Perf cell A/jamba).
    """
    bsz, s, d = xres.shape
    d_in, h, g = mamba_dims(cfg)
    n, hd = cfg.ssm_state, cfg.ssm_headdim
    decode = cache is not None

    z = constrain(xres @ p["in_z"].astype(xres.dtype))  # (B, S, d_in)
    xs = constrain(xres @ p["in_x"].astype(xres.dtype))
    bs = xres @ p["in_b"].astype(xres.dtype)  # (B, S, g*n): tiny, replicated
    cs = xres @ p["in_c"].astype(xres.dtype)
    dt_raw = constrain(xres @ p["in_dt"].astype(xres.dtype))  # (B, S, H)

    if collect and not decode:
        k = cfg.ssm_conv
        pre_x, pre_b, pre_c = xs, bs, cs  # pre-conv streams feed the cache

    xs, cache_x = _causal_conv(
        xs, p["conv_x"], cache["conv_x"] if decode else None
    )
    bs, cache_b = _causal_conv(
        bs, p["conv_b"], cache["conv_b"] if decode else None
    )
    cs, cache_c = _causal_conv(
        cs, p["conv_c"], cache["conv_c"] if decode else None
    )
    xs, bs, cs = jax.nn.silu(xs), jax.nn.silu(bs), jax.nn.silu(cs)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # (B, S, H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (H,)

    xh = xs.reshape(bsz, s, h, hd)
    # ngroups == 1: broadcast the single B/C group across all SSM heads.
    bh = jnp.broadcast_to(bs[:, :, None, :], (bsz, s, h, n))
    ch = jnp.broadcast_to(cs[:, :, None, :], (bsz, s, h, n))

    if decode:
        y, new_state = ssd_recurrent_step(cache["state"], xh, dt, a, bh, ch)
    else:
        y, new_state = ssd_chunked(xh, dt, a, bh, ch, chunk=cfg.ssm_chunk)

    y = y + xh * p["d_skip"].astype(y.dtype)[None, None, :, None]
    y = constrain(y.reshape(bsz, s, d_in))
    y = constrain(_gated_rmsnorm(y, z, p["norm"], cfg.norm_eps))
    out = y @ p["out"].astype(y.dtype)

    if decode:
        new_cache = {
            "state": new_state,
            "conv_x": cache_x,
            "conv_b": cache_b,
            "conv_c": cache_c,
        }
        return out, new_cache
    if collect:
        return out, {
            "state": new_state,
            "conv_x": pre_x[:, -(k - 1):],
            "conv_b": pre_b[:, -(k - 1):],
            "conv_c": pre_c[:, -(k - 1):],
        }
    return out, None


def mamba_cache_defs(cfg, batch: int) -> dict:
    """ShapeDtype spec dict for the decode cache of one mamba layer."""
    d_in, h, g = mamba_dims(cfg)
    n, k = cfg.ssm_state, cfg.ssm_conv
    dt = jnp.dtype(cfg.dtype)
    return {
        "state": jax.ShapeDtypeStruct((batch, h, cfg.ssm_headdim, n), dt),
        "conv_x": jax.ShapeDtypeStruct((batch, k - 1, d_in), dt),
        "conv_b": jax.ShapeDtypeStruct((batch, k - 1, g * n), dt),
        "conv_c": jax.ShapeDtypeStruct((batch, k - 1, g * n), dt),
    }
