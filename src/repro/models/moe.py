"""Mixture-of-Experts FFN with expert parallelism (GShard-style, shard_map).

Two execution paths share one routing/dispatch core:

* **train/prefill** (`moe_shard_map`) — tokens arrive sequence-sharded over
  the ``model`` axis (SP residual stream) and batch-sharded over
  ``(pod, data)``; experts are sharded over ``model``.  Each shard routes
  its local tokens, builds a capacity-bounded (E, C, d) dispatch, and two
  ``all_to_all`` collectives move tokens to expert owners and back — the
  canonical EP schedule, with exact active-FLOPs batched GEMMs
  (``ecd,edf->ecf``).
* **decode** (`moe_einsum`) — token counts are tiny (≤ global batch), so a
  dense one-hot dispatch einsum under plain pjit is cheaper than paying the
  shard_map/a2a latency; XLA propagates the expert sharding.

Capacity overflow drops tokens (zero contribution), as in GShard; tests
validate exactness against the dense reference at high capacity factors.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist import compat
from repro.models.layers import ParamDef

Array = jax.Array


def moe_defs(cfg) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    defs = {
        "router": ParamDef((d, e), ("fsdp", "none"), scale=d**-0.5),
        # d_ff (not d_model) carries the data-axis storage split: see
        # ShardingRules.expert_ff — decode reads experts gather-free.
        "gate": ParamDef((e, d, f), ("experts", "none", "expert_ff"), scale=d**-0.5),
        "up": ParamDef((e, d, f), ("experts", "none", "expert_ff"), scale=d**-0.5),
        "down": ParamDef((e, f, d), ("experts", "expert_ff", "none"), scale=f**-0.5),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        defs["shared_gate"] = ParamDef((d, fs), ("fsdp", "ff"), scale=d**-0.5)
        defs["shared_up"] = ParamDef((d, fs), ("fsdp", "ff"), scale=d**-0.5)
        defs["shared_down"] = ParamDef((fs, d), ("ff", "fsdp"), scale=fs**-0.5)
    return defs


def _route(x2d: Array, wr: Array, k: int, softmax_topk: bool):
    """-> (ids (T,k) int32, gates (T,k) f32, probs (T,E) f32)."""
    logits = (x2d.astype(jnp.float32)) @ wr.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_ids = lax.top_k(logits, k)
    if softmax_topk:
        gates = jax.nn.softmax(top_vals, axis=-1)
    else:
        gates = jax.nn.sigmoid(top_vals)
    return top_ids.astype(jnp.int32), gates, probs


def _capacity(tokens: int, k: int, e: int, factor: float) -> int:
    c = int(tokens * k / e * factor) + 1
    return max(8, -(-c // 8) * 8)  # round up to 8


def _dispatch_sorted(ids: Array, gates: Array, tokens: int, e: int, cap: int):
    """Sort-based capacity dispatch.

    -> buf_tok (E, C) int32 token index or -1; buf_gate (E, C) f32.
    """
    t, k = ids.shape
    flat_e = ids.reshape(t * k)
    flat_g = gates.reshape(t * k)
    order = jnp.argsort(flat_e, stable=True)  # slot order grouped by expert
    sorted_e = flat_e[order]
    # Rank within the expert group = position - group start.
    group_start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    pos = jnp.arange(t * k) - group_start[sorted_e]
    keep = pos < cap
    e_idx = jnp.where(keep, sorted_e, 0)
    p_idx = jnp.where(keep, pos, cap - 1)
    tok = jnp.where(keep, order // k, -1)
    gat = jnp.where(keep, flat_g[order], 0.0)
    buf_tok = jnp.full((e, cap), -1, jnp.int32).at[e_idx, p_idx].max(
        tok.astype(jnp.int32), mode="drop"
    )
    buf_gate = jnp.zeros((e, cap), jnp.float32).at[e_idx, p_idx].max(
        gat, mode="drop"
    )
    buf_gate = jnp.where(buf_tok >= 0, buf_gate, 0.0)
    return buf_tok, buf_gate


def _expert_ffn(xe: Array, p: dict, dtype) -> Array:
    """(E_loc, C', d) tokens through per-expert SwiGLU."""
    wg = p["gate"].astype(dtype)
    wu = p["up"].astype(dtype)
    wd = p["down"].astype(dtype)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg))
    h = g * jnp.einsum("ecd,edf->ecf", xe, wu)
    return jnp.einsum("ecf,efd->ecd", h, wd)


def _aux_loss(probs: Array, ids: Array, e: int) -> Array:
    """Switch/GShard load-balance loss: E * sum_e f_e * p_e."""
    onehot = jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32)
    f = onehot.mean(axis=0)
    pbar = probs.mean(axis=0)
    return e * jnp.sum(f * pbar)


def _shared_ffn(p: dict, x: Array) -> Array:
    g = jax.nn.silu(x @ p["shared_gate"].astype(x.dtype))
    h = g * (x @ p["shared_up"].astype(x.dtype))
    return h @ p["shared_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# shard_map path (train / prefill)
# ---------------------------------------------------------------------------

def _moe_body(x, p, *, cfg, model_axis: str | None, ep: int,
              batch_axes: tuple = (), ff_axis: str | None = None):
    """x (B_loc, S_loc, d) local tokens; expert weights local (E_loc,...).

    ``ff_axis``: weight-stationary second EP level — expert matrices keep
    their d_ff shards on the ``ff_axis`` (= the storage split, see
    ShardingRules.expert_ff) and TOKENS move instead: all-gather the
    dispatched tokens over ``ff_axis``, compute the f-sliced partial FFN,
    psum-scatter the partial outputs back.  Token payloads are
    microbatch-proportional; weight payloads are not — measured 3-4x fewer
    collective bytes on jamba/dbrx train cells (EXPERIMENTS.md §Perf).
    """
    bl, sl, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    tloc = bl * sl
    cap = _capacity(tloc, k, e, cfg.capacity_factor)
    x2d = x.reshape(tloc, d)

    ids, gates, probs = _route(x2d, p["router"], k, cfg.router_softmax_topk)
    buf_tok, buf_gate = _dispatch_sorted(ids, gates, tloc, e, cap)
    xe = jnp.where(
        (buf_tok >= 0)[..., None], x2d[jnp.clip(buf_tok, 0)], 0
    )  # (E, C, d)

    if ep > 1:
        # tokens -> expert owners: (E, C, d) -> (E/ep, ep*C, d)
        xe = lax.all_to_all(xe, model_axis, split_axis=0, concat_axis=1,
                            tiled=True)
    if ff_axis is not None:
        # level 2: bring every ff-shard this expert's tokens (tokens are
        # small; the weights stay put)
        xe = lax.all_gather(xe, ff_axis, axis=1, tiled=True)
    ye = _expert_ffn(xe, p, x.dtype)
    if ff_axis is not None:
        # sum the f-sliced partials and return each shard its own tokens
        ye = lax.psum_scatter(ye, ff_axis, scatter_dimension=1, tiled=True)
    if ep > 1:
        ye = lax.all_to_all(ye, model_axis, split_axis=1, concat_axis=0,
                            tiled=True)

    contrib = ye * buf_gate[..., None].astype(ye.dtype)  # (E, C, d)
    y2d = jnp.zeros((tloc, d), x.dtype).at[jnp.clip(buf_tok, 0)].add(
        jnp.where((buf_tok >= 0)[..., None], contrib, 0), mode="drop"
    )
    y = y2d.reshape(bl, sl, d)
    if cfg.num_shared_experts:
        y = y + _shared_ffn(p, x)
    # Invariant scalar aux loss: mean over every shard's local loss.
    aux = _aux_loss(probs, ids, e)
    reduce_axes = tuple(batch_axes) + ((model_axis,) if ep > 1 else ())
    if reduce_axes:
        aux = lax.pmean(aux, reduce_axes)
    return y, aux


def moe_apply(
    p: dict,
    x: Array,
    *,
    cfg,
    mesh: Mesh | None,
    batch_axes: tuple = ("pod", "data"),
    model_axis: str = "model",
) -> tuple[Array, Array]:
    """MoE FFN. x (B, S, d) -> (y, aux_loss).

    Uses the shard_map EP path when a mesh with a model axis is present and
    the sequence is shardable; otherwise the einsum path (decode / smoke).
    """
    e = cfg.num_experts
    if mesh is not None:
        batch_axes = tuple(a for a in batch_axes if a in mesh.shape)
        have_model = model_axis in mesh.shape
    else:
        have_model = False

    seq_ok = have_model and x.shape[1] % mesh.shape[model_axis] == 0
    if mesh is None or not have_model or not seq_ok or x.shape[1] == 1:
        return moe_einsum(p, x, cfg=cfg)

    ep = mesh.shape[model_axis]
    # Weight-stationary second level: keep the d_ff storage shards in place
    # when they exist (mirror of ShardingRules.expert_ff + divisibility).
    ff_axis = (
        "data"
        if cfg.fsdp and "data" in mesh.shape
        and cfg.d_ff % mesh.shape["data"] == 0
        else None
    )
    body = functools.partial(
        _moe_body, cfg=cfg, model_axis=model_axis, ep=ep,
        batch_axes=batch_axes, ff_axis=ff_axis,
    )
    wff = ff_axis  # None -> gathered by shard_map (legacy ZeRO-style path)
    in_specs = (
        P(batch_axes, model_axis, None),  # x: batch + sequence sharded
        {
            "router": P(),
            "gate": P(model_axis, None, wff),
            "up": P(model_axis, None, wff),
            "down": P(model_axis, wff, None),
            **(
                {
                    "shared_gate": P(),
                    "shared_up": P(),
                    "shared_down": P(),
                }
                if cfg.num_shared_experts
                else {}
            ),
        },
    )
    out_specs = (P(batch_axes, model_axis, None), P())
    fn = compat.shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    pl = {key: p[key] for key in in_specs[1]}
    y, aux = fn(x, pl)
    return y, aux


# ---------------------------------------------------------------------------
# einsum path (decode / single device)
# ---------------------------------------------------------------------------

def moe_einsum(p: dict, x: Array, *, cfg) -> tuple[Array, Array]:
    """One-hot dispatch einsum MoE (small token counts)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    cap = _capacity(t, k, e, cfg.capacity_factor)
    x2d = x.reshape(t, d)
    ids, gates, probs = _route(x2d, p["router"], k, cfg.router_softmax_topk)
    buf_tok, buf_gate = _dispatch_sorted(ids, gates, t, e, cap)
    xe = jnp.where((buf_tok >= 0)[..., None], x2d[jnp.clip(buf_tok, 0)], 0)
    ye = _expert_ffn(xe, p, x.dtype)
    contrib = ye * buf_gate[..., None].astype(ye.dtype)
    y2d = jnp.zeros((t, d), x.dtype).at[jnp.clip(buf_tok, 0)].add(
        jnp.where((buf_tok >= 0)[..., None], contrib, 0), mode="drop"
    )
    y = y2d.reshape(b, s, d)
    if cfg.num_shared_experts:
        y = y + _shared_ffn(p, x)
    return y, _aux_loss(probs, ids, e)


def moe_dense_reference(p: dict, x: Array, *, cfg) -> Array:
    """Oracle: full dense compute over every expert (tests only)."""
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    ids, gates, _ = _route(x2d, p["router"], cfg.experts_per_token,
                           cfg.router_softmax_topk)
    y = jnp.zeros_like(x2d)
    for e_idx in range(cfg.num_experts):
        g = jax.nn.silu(x2d @ p["gate"][e_idx].astype(x.dtype))
        h = g * (x2d @ p["up"][e_idx].astype(x.dtype))
        ye = h @ p["down"][e_idx].astype(x.dtype)
        w = ((ids == e_idx).astype(jnp.float32) * gates).sum(axis=1)
        y = y + ye * w[:, None].astype(x.dtype)
    y = y.reshape(b, s, d)
    if cfg.num_shared_experts:
        y = y + _shared_ffn(p, x.reshape(b, s, d))
    return y
