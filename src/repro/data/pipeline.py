"""Sharded, deterministic, resumable data pipeline.

This is the *placement* half of the data layer: block generation lives in
``repro.data.sources`` (the same host-blocks protocol the selection
engines stream from) and this module lands those blocks on a mesh.  The
pipeline consumes any step-indexed source — an object with
``block(step, lo, hi) -> np.ndarray`` that is a pure function of
``(seed, step)`` — and materialises, per host, only the addressable shard
of the global batch (``jax.make_array_from_callback``).

Fault-tolerance by construction: because the source is step-indexed, a
restart from checkpoint step k replays the identical stream with no
data-loader state to persist, and the pipeline scales to any mesh.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data.sources import SyntheticTokenSource

Array = jax.Array


@dataclasses.dataclass
class ShardedDataPipeline:
    """Token pipeline sharded over the batch axis.

    Args:
      mesh: device mesh; batches are sharded P(batch_axes, None).
      global_batch: global batch size (divisible by the batch-axes extent).
      seq_len, vocab: token geometry.
      seed: stream seed. ``batch_at(step)`` is pure in (seed, step).
      source: step-indexed block source; None builds the default
        :class:`~repro.data.sources.SyntheticTokenSource` from the fields
        above.  Any object with a pure ``block(step, lo, hi)`` works —
        swapping the source swaps the dataset, never the placement.
    """

    mesh: Mesh
    global_batch: int
    seq_len: int
    vocab: int
    seed: int = 0
    batch_axes: tuple = ("pod", "data")
    source: object = None

    def __post_init__(self):
        axes = tuple(a for a in self.batch_axes if a in self.mesh.shape)
        self.batch_axes = axes
        ext = 1
        for a in axes:
            ext *= self.mesh.shape[a]
        if self.global_batch % ext:
            raise ValueError(
                f"global_batch {self.global_batch} not divisible by "
                f"batch-axes extent {ext}"
            )
        if self.source is None:
            self.source = SyntheticTokenSource(
                self.global_batch, self.seq_len, self.vocab, self.seed
            )
        self._sharding = NamedSharding(self.mesh, P(self.batch_axes, None))

    def _host_block(self, step: int, lo: int, hi: int) -> np.ndarray:
        """Rows [lo, hi) of the global batch at ``step`` (host-side numpy)."""
        return self.source.block(step, lo, hi)

    def batch_at(self, step: int) -> dict:
        """Global sharded batch at ``step``: tokens/targets (B, S) int32."""
        shape = (self.global_batch, self.seq_len + 1)

        def cb(index):
            rows = index[0]
            lo = rows.start or 0
            hi = rows.stop if rows.stop is not None else self.global_batch
            block = self._host_block(step, lo, hi)
            cols = index[1]
            return block[:, cols]

        full = jax.make_array_from_callback(shape, self._sharding, cb)
        return {
            "tokens": jax.lax.slice_in_dim(full, 0, self.seq_len, axis=1),
            "targets": jax.lax.slice_in_dim(full, 1, self.seq_len + 1, axis=1),
        }

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
