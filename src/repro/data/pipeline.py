"""Sharded, deterministic, resumable data pipeline.

Fault-tolerance by construction: batches are a pure function of
``(seed, step)`` (step-indexed PRNG), so a restart from checkpoint step k
replays the identical stream with no data-loader state to persist.  Each
host materialises only its addressable shard of the global batch
(`jax.make_array_from_callback`), so the pipeline scales to any mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array


@dataclasses.dataclass
class ShardedDataPipeline:
    """Synthetic-token pipeline sharded over the batch axis.

    Args:
      mesh: device mesh; batches are sharded P(batch_axes, None).
      global_batch: global batch size (divisible by the batch-axes extent).
      seq_len, vocab: token geometry.
      seed: stream seed. ``batch_at(step)`` is pure in (seed, step).
    """

    mesh: Mesh
    global_batch: int
    seq_len: int
    vocab: int
    seed: int = 0
    batch_axes: tuple = ("pod", "data")

    def __post_init__(self):
        axes = tuple(a for a in self.batch_axes if a in self.mesh.shape)
        self.batch_axes = axes
        ext = 1
        for a in axes:
            ext *= self.mesh.shape[a]
        if self.global_batch % ext:
            raise ValueError(
                f"global_batch {self.global_batch} not divisible by "
                f"batch-axes extent {ext}"
            )
        self._sharding = NamedSharding(self.mesh, P(self.batch_axes, None))

    def _host_block(self, step: int, lo: int, hi: int) -> np.ndarray:
        """Rows [lo, hi) of the global batch at ``step`` (host-side numpy)."""
        rng = np.random.default_rng((self.seed, step))
        # Advance cheaply to the row block: regenerate only needed rows.
        u = rng.random((self.global_batch, self.seq_len + 1))[lo:hi]
        return (u * u * self.vocab).astype(np.int32)

    def batch_at(self, step: int) -> dict:
        """Global sharded batch at ``step``: tokens/targets (B, S) int32."""
        shape = (self.global_batch, self.seq_len + 1)

        def cb(index):
            rows = index[0]
            lo = rows.start or 0
            hi = rows.stop if rows.stop is not None else self.global_batch
            block = self._host_block(step, lo, hi)
            cols = index[1]
            return block[:, cols]

        full = jax.make_array_from_callback(
            shape, NamedSharding(self.mesh, P(self.batch_axes, None)), cb
        )
        return {
            "tokens": jax.lax.slice_in_dim(full, 0, self.seq_len, axis=1),
            "targets": jax.lax.slice_in_dim(full, 1, self.seq_len + 1, axis=1),
        }

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
