"""Synthetic datasets — the paper's CorrAL-style generator (Eq. 3) + LM tokens.

The paper evaluates on binary artificial datasets where the class depends on
8 features:

    c = ((x1 ^ x2) v (x3 ^ x4)) ^ ((x5 ^ x6) v (x7 ^ x8))        (Eq. 3)

with all remaining features irrelevant noise.  We reproduce that generator
(deterministically, chunked so millions of rows stream without a host-memory
spike) and add: a partially-correlated column (as in CorrAL), continuous
variants for the alternative-encoding/Pearson path, and LM token batches for
the architecture workloads.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

RELEVANT = 8  # features participating in Eq. 3 (placed at indices 0..7)


def _class_from_relevant(xr: Array) -> Array:
    """Eq. 3 of the paper applied to the first 8 boolean columns."""
    x1, x2, x3, x4, x5, x6, x7, x8 = (xr[:, i] for i in range(8))
    return (((x1 & x2) | (x3 & x4)) & ((x5 & x6) | (x7 & x8))).astype(jnp.int32)


def corral_dataset(
    num_rows: int,
    num_cols: int,
    *,
    seed: int = 0,
    flip_prob: float = 0.05,
    correlated_col: bool = True,
    dtype=jnp.int8,
):
    """Paper §V dataset: binary, class from Eq. 3, remaining cols irrelevant.

    Returns (X (num_rows, num_cols) in {0,1}, y (num_rows,) in {0,1}).
    Column layout: 0..7 relevant; 8 (optionally) partially correlated with
    the class (CorrAL-style, 75% agreement); the rest iid noise.
    ``flip_prob`` injects label noise so MI values are non-degenerate.
    """
    if num_cols < RELEVANT + 1:
        raise ValueError(f"need at least {RELEVANT + 1} columns")
    key = jax.random.PRNGKey(seed)
    kx, kc, kf = jax.random.split(key, 3)
    X = jax.random.bernoulli(kx, 0.5, (num_rows, num_cols)).astype(jnp.bool_)
    y = _class_from_relevant(X[:, :RELEVANT])
    if correlated_col:
        agree = jax.random.bernoulli(kc, 0.75, (num_rows,))
        corr_col = jnp.where(agree, y.astype(jnp.bool_), ~y.astype(jnp.bool_))
        X = X.at[:, RELEVANT].set(corr_col)
    if flip_prob > 0:
        flips = jax.random.bernoulli(kf, flip_prob, (num_rows,))
        y = jnp.where(flips, 1 - y, y)
    return X.astype(dtype), y


def corral_dataset_np(
    num_rows: int,
    num_cols: int,
    *,
    seed: int = 0,
    flip_prob: float = 0.05,
    chunk: int = 1_000_000,
) -> tuple[np.ndarray, np.ndarray]:
    """Streaming numpy generator for benchmark-scale datasets (paper uses up
    to 10M rows): builds int8 chunks without a (rows, cols) float allocation."""
    rng = np.random.default_rng(seed)
    X = np.empty((num_rows, num_cols), dtype=np.int8)
    y = np.empty((num_rows,), dtype=np.int8)
    for start in range(0, num_rows, chunk):
        stop = min(start + chunk, num_rows)
        blk = rng.integers(0, 2, size=(stop - start, num_cols), dtype=np.int8)
        x = [blk[:, i].astype(bool) for i in range(8)]
        c = (((x[0] & x[1]) | (x[2] & x[3]))
             & ((x[4] & x[5]) | (x[6] & x[7])))
        agree = rng.random(stop - start) < 0.75
        blk[:, RELEVANT] = np.where(agree, c, ~c)
        if flip_prob > 0:
            flips = rng.random(stop - start) < flip_prob
            c = np.where(flips, ~c, c)
        X[start:stop] = blk
        y[start:stop] = c.astype(np.int8)
    return X, y


def continuous_wide_dataset(
    num_rows: int,
    num_cols: int,
    *,
    seed: int = 0,
    signal_cols: int = 8,
    noise: float = 0.5,
):
    """Continuous S/W-style dataset for the alternative/Pearson path.

    The first ``signal_cols`` columns carry graded linear signal about a
    binary class; later signal columns are partially redundant copies of
    earlier ones, so mRMR's redundancy term is exercised (not just ranking).
    """
    key = jax.random.PRNGKey(seed)
    ky, kx, kn, kr = jax.random.split(key, 4)
    y = jax.random.bernoulli(ky, 0.5, (num_rows,)).astype(jnp.float32)
    X = jax.random.normal(kx, (num_rows, num_cols), jnp.float32)
    strengths = jnp.linspace(1.5, 0.5, signal_cols)
    sig = y[:, None] * strengths[None, :] + noise * jax.random.normal(
        kn, (num_rows, signal_cols)
    )
    X = X.at[:, :signal_cols].set(sig)
    # Redundant shadow of column 0 -> should be down-ranked by mRMR.
    if num_cols > signal_cols:
        X = X.at[:, signal_cols].set(
            X[:, 0] + 0.1 * jax.random.normal(kr, (num_rows,))
        )
    return X, y.astype(jnp.int32)


# ---------------------------------------------------------------------------
# LM token stream for the architecture workloads
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LMBatch:
    tokens: Array  # (B, S) int32
    targets: Array  # (B, S) int32 (next-token shifted)
    mask: Array  # (B, S) float32 loss mask


def lm_token_batches(
    key: Array, batch: int, seq_len: int, vocab: int, num_batches: int = 1
):
    """Deterministic synthetic token batches (Zipf-ish marginal)."""
    for i in range(num_batches):
        k = jax.random.fold_in(key, i)
        # Zipf-like: square a uniform to skew mass toward low token ids.
        u = jax.random.uniform(k, (batch, seq_len + 1))
        tokens = (u * u * vocab).astype(jnp.int32)
        yield LMBatch(
            tokens=tokens[:, :-1],
            targets=tokens[:, 1:],
            mask=jnp.ones((batch, seq_len), jnp.float32),
        )
