"""Streaming discretisation — mergeable quantile sketches + binned sources.

MI scoring needs discrete inputs, but the paper's target traffic (and most
real numeric-tabular data) is continuous.  This module is the front stage
that bridges the two at streaming scale, the same shape as Spark ITFS's
mandatory distributed-discretisation step and sklearn's histogram-GBDT
``_BinMapper`` (subsample -> quantile -> map), but built on this repo's
block protocol so it never materialises the dataset:

1. :class:`QuantileSketch` — a per-feature KLL-style sketch of bounded
   memory: levelled buffers of capacity ``k`` where a full buffer sorts,
   keeps every other element at doubled weight and promotes it one level
   up.  ``update`` ingests ``(B, N)`` observation-blocks (all features
   sketched at once, vectorised); ``merge`` combines sketches built on
   different blocks or shards, so the one cheap stats pass MapReduces the
   same way the scoring passes do.  Ingestion compacts at exact capacity
   boundaries, which makes the sketch a pure function of the row stream —
   identical for every ``block_obs``, like every other source-derived
   quantity in this repo.
2. :class:`QuantileBinner` — ``fit(source)`` runs that one pass (also
   validating the target holds discrete class labels) and cuts
   ``bins - 1`` interior edges at equal-frequency quantiles;
   ``transform`` maps floats to int codes in ``[0, bins)`` via
   ``searchsorted(side="right")``.
3. :class:`BinnedSource` — any float :class:`~repro.data.sources.
   DataSource` wrapped to yield int codes on the fly inside
   ``iter_blocks``, making it consumable by every discrete engine.  Its
   ``fingerprint()`` derives from the base source's fingerprint × the bin
   config (never the fitted edges — those are a pure function of both),
   so the selection service's result cache distinguishes ``bins=16`` from
   ``bins=64`` and binned from pre-discretised data for free.  The binner
   fit is lazy and memoised across instances by that fingerprint, so a
   fresh wrapper over already-sketched content costs zero I/O.

Everything here is numpy-only (importing it never initialises a jax
backend); the device-side hot path — binning fused with contingency
accumulation — lives in ``repro.kernels.binning`` and is wired up by
``repro.core.streaming`` whenever a :class:`BinnedSource` streams through
an MI fit.

    >>> from repro.data.binning import BinnedSource
    >>> src = BinnedSource(NpySource("X.npy", "y.npy"), bins=32)
    >>> MRMRSelector(num_select=10).fit(src)        # or just bins=32 on
    ...                                             # the selector
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Iterator

import numpy as np

from repro.data.sources import Block, DataSource, SourceStats

# Fitted-binner memo, keyed by the BinnedSource fingerprint (base × bin
# config): the selection service builds a fresh wrapper per request, and
# re-running the sketch pass on already-sketched content would cost a full
# pass of I/O each time.  Bounded LRU, same shape as sources._STATS_MEMO.
_BINNER_MEMO: OrderedDict = OrderedDict()
_BINNER_MEMO_CAP = 64
_BINNER_LOCK = threading.Lock()


def clear_binner_memo() -> None:
    """Drop every memoised fitted binner (tests / changed files)."""
    with _BINNER_LOCK:
        _BINNER_MEMO.clear()


def _as_class_labels(y: np.ndarray) -> np.ndarray:
    """Validate + cast a target block to int32 class labels.

    ``bins=`` discretises *features* only: a float target must already
    hold integral class labels (CSV parsers commonly emit ``1.0``); a
    genuinely continuous target has no MI class axis to count against.
    """
    y = np.asarray(y)
    if np.issubdtype(y.dtype, np.integer) or y.dtype == np.bool_:
        yi = y.astype(np.int32)
    else:
        yi = np.floor(y).astype(np.int64)
        if not np.array_equal(yi, y):
            raise ValueError(
                "bins= discretises features only, but the target holds "
                "non-integral values: MI needs discrete class labels "
                "(remap / round the target to 0..K-1 before fitting)"
            )
        yi = yi.astype(np.int32)
    if yi.size and int(yi.min()) < 0:
        raise ValueError(
            "negative class labels in target: one-hot contingency counts "
            "drop them silently; remap classes to 0..K-1 before fitting"
        )
    return yi


class QuantileSketch:
    """Mergeable per-feature quantile sketch (KLL-style, numpy-only).

    Level ``h`` holds at most ``k`` values per feature, each standing for
    ``2**h`` observations.  A full level sorts per-feature, keeps every
    other element (per-feature random parity, deterministic in ``seed``
    and the compaction index) and promotes the survivors one level up at
    doubled weight — total memory is ``O(k · log(n/k))`` values per
    feature regardless of stream length, with rank error ``O(log(n/k)/k)``.

    Ingestion fills level 0 to *exactly* ``k`` before each compaction, so
    the sketch state is a pure function of the row stream — the same
    block-size independence every ``DataSource`` guarantees.
    """

    def __init__(self, num_features: int, k: int = 512, seed: int = 0):
        if num_features < 1:
            raise ValueError(f"num_features must be >= 1, got {num_features}")
        if k < 8 or k % 2:
            raise ValueError(f"sketch capacity k must be even and >= 8, got {k}")
        self.num_features = int(num_features)
        self.k = int(k)
        self.seed = int(seed)
        self.count = 0          # total (weighted) rows ingested
        self._bufs: list = []   # level h: (k, num_features) float32
        self._fill: list = []   # rows used per level
        self._ncompact: list = []  # compactions per level (rng stream key)

    def _ensure_level(self, h: int) -> None:
        while len(self._bufs) <= h:
            self._bufs.append(
                np.empty((self.k, self.num_features), np.float32)
            )
            self._fill.append(0)
            self._ncompact.append(0)

    def _compact(self, h: int) -> None:
        """Sort a FULL level, promote every other element at weight 2x."""
        srt = np.sort(self._bufs[h], axis=0)  # per-feature (column) sort
        rng = np.random.default_rng((self.seed, h, self._ncompact[h]))
        self._ncompact[h] += 1
        # Independent parity per feature: unbiased survivor choice without
        # correlating the error across columns.
        off = rng.integers(0, 2, size=self.num_features)
        rows = off[None, :] + 2 * np.arange(self.k // 2)[:, None]
        survivors = np.take_along_axis(srt, rows, axis=0)
        self._fill[h] = 0
        self._ingest_rows(h + 1, survivors)

    def _ingest_rows(self, h: int, rows: np.ndarray) -> None:
        """Append rows to level ``h``, compacting at exact capacity
        boundaries (the block-size-independence invariant)."""
        self._ensure_level(h)
        pos, total = 0, rows.shape[0]
        while pos < total:
            take = min(self.k - self._fill[h], total - pos)
            buf, fill = self._bufs[h], self._fill[h]
            buf[fill : fill + take] = rows[pos : pos + take]
            self._fill[h] += take
            pos += take
            if self._fill[h] == self.k:
                self._compact(h)

    def update(self, X_block: np.ndarray) -> "QuantileSketch":
        """Ingest one ``(B, num_features)`` observation-block."""
        X = np.asarray(X_block)
        if X.ndim != 2 or X.shape[1] != self.num_features:
            raise ValueError(
                f"block shape {X.shape} does not match "
                f"num_features={self.num_features}"
            )
        X = X.astype(np.float32, copy=False)
        if not np.isfinite(X).all():
            raise ValueError(
                "non-finite feature values (nan/inf): quantile sketches "
                "have no ordering for them; clean or impute first"
            )
        self._ingest_rows(0, X)
        self.count += X.shape[0]
        return self

    def merge(self, other: "QuantileSketch") -> "QuantileSketch":
        """Fold another sketch (same geometry) into this one — the reduce
        step when shards sketch their partitions independently."""
        if (
            other.num_features != self.num_features
            or other.k != self.k
        ):
            raise ValueError(
                f"cannot merge sketches of different geometry: "
                f"({self.num_features}, k={self.k}) vs "
                f"({other.num_features}, k={other.k})"
            )
        for h in range(len(other._bufs)):
            fill = other._fill[h]
            if fill:
                self._ingest_rows(h, other._bufs[h][:fill])
        self.count += other.count
        return self

    def quantiles(self, qs) -> np.ndarray:
        """``(num_features, len(qs))`` approximate quantile values.

        Rank semantics: the returned value for quantile ``q`` is the
        smallest stored value whose cumulative (weighted) rank reaches
        ``q * count``.
        """
        qs = np.atleast_1d(np.asarray(qs, np.float64))
        if self.count == 0:
            raise ValueError("empty sketch: update() with data first")
        vals, weights = [], []
        for h in range(len(self._bufs)):
            fill = self._fill[h]
            if fill:
                vals.append(self._bufs[h][:fill])
                weights.append(np.full((fill,), 1 << h, np.int64))
        v = np.concatenate(vals, axis=0)        # (T, n)
        w = np.concatenate(weights)             # (T,)
        order = np.argsort(v, axis=0, kind="stable")
        sv = np.take_along_axis(v, order, axis=0)
        cum = np.cumsum(w[order], axis=0)       # (T, n); cum[-1] == count
        targets = np.clip(qs, 0.0, 1.0) * self.count
        out = np.empty((self.num_features, len(qs)), np.float32)
        last = sv.shape[0] - 1
        for j in range(self.num_features):
            idx = np.searchsorted(cum[:, j], targets, side="left")
            out[j] = sv[np.minimum(idx, last), j]
        return out

    @property
    def levels(self) -> int:
        return len(self._bufs)


@dataclasses.dataclass
class QuantileBinner:
    """Equal-frequency discretiser: one sketch pass -> ``bins - 1`` edges.

    ``fit(source)`` streams the source once through a
    :class:`QuantileSketch` (validating the target is discrete on the
    same pass, so ``BinnedSource.stats()`` costs no extra I/O), then cuts
    interior edges at quantiles ``i / bins``.  ``transform`` encodes a
    float block to int32 codes in ``[0, bins)`` — ``searchsorted(edges,
    x, side="right")``, ties to the upper bin.  Edges and comparisons are
    float32, matching ``repro.kernels.binning`` bit-for-bit so host and
    device encodes of the same block always agree.

    Duplicate edges (heavy ties) simply leave some bins empty — harmless
    for contingency counting.
    """

    bins: int
    sketch_k: int = 512
    seed: int = 0

    # fitted: edges_ (num_features, bins - 1) float32, num_classes_,
    # n_obs_, sketch_

    def __post_init__(self):
        if self.bins < 2:
            raise ValueError(f"bins must be >= 2, got {self.bins}")

    @property
    def fitted(self) -> bool:
        return getattr(self, "edges_", None) is not None

    def fit(self, source: DataSource, block_obs: int = 65536) -> "QuantileBinner":
        """One streaming pass: sketch every feature, validate the target."""
        sketch = QuantileSketch(
            source.num_features, k=self.sketch_k, seed=self.seed
        )
        y_max, n_obs = 0, 0
        for X_blk, y_blk in source.iter_blocks(block_obs):
            labels = _as_class_labels(y_blk)
            sketch.update(X_blk)
            if labels.size:
                y_max = max(y_max, int(labels.max()))
            n_obs += X_blk.shape[0]
        qs = np.arange(1, self.bins) / self.bins
        # maximum.accumulate guards monotonicity against f32 rounding of
        # near-equal quantiles; normally a no-op.
        self.edges_ = np.maximum.accumulate(sketch.quantiles(qs), axis=1)
        self.num_classes_ = y_max + 1
        self.n_obs_ = n_obs
        self.sketch_ = sketch
        return self

    def transform(self, X_block: np.ndarray) -> np.ndarray:
        """(B, N) floats -> (B, N) int32 codes in ``[0, bins)``."""
        if not self.fitted:
            raise RuntimeError("fit() the binner before transform()")
        X = np.asarray(X_block, np.float32)
        out = np.empty(X.shape, np.int32)
        for j in range(X.shape[1]):
            out[:, j] = np.searchsorted(self.edges_[j], X[:, j], side="right")
        return out

    def encode_column(self, j: int, col: np.ndarray) -> np.ndarray:
        """Encode one feature column (the streaming engine's redundancy
        target) without touching the rest of the block."""
        return np.searchsorted(
            self.edges_[j], np.asarray(col, np.float32), side="right"
        ).astype(np.int32)


class BinnedSource(DataSource):
    """A float source wearing int codes: on-the-fly quantile discretisation.

    Wraps any :class:`~repro.data.sources.DataSource` whose blocks hold
    continuous features; ``iter_blocks`` yields the binner's int32 codes
    (and the validated int class labels), so every discrete engine —
    in-memory or streaming — consumes it unchanged.  The binner fit (one
    sketch pass over the base) is lazy: constructing the wrapper is free,
    and the fitted binner is memoised across instances by fingerprint.

    ``fingerprint()`` = base fingerprint × ``(bins, sketch_k, seed)``:
    distinct bin configs never collide in the selection service's result
    cache, and the identity never needs the edges (they are a pure
    function of base content + config).

    ``stats()`` is I/O-free once the binner is fitted: codes are discrete
    with exactly ``bins`` values, and the class count was recorded on the
    sketch pass.
    """

    def __init__(
        self,
        base: DataSource,
        bins: int | None = None,
        *,
        binner: QuantileBinner | None = None,
        sketch_k: int = 512,
        seed: int = 0,
        fit_block_obs: int = 65536,
    ):
        if not isinstance(base, DataSource):
            raise TypeError(
                f"BinnedSource wraps a DataSource, got {type(base).__name__}"
            )
        if isinstance(base, BinnedSource):
            raise ValueError("base source is already binned")
        if (bins is None) == (binner is None):
            raise ValueError("pass exactly one of bins= or binner=")
        self.base = base
        self._binner = (
            binner
            if binner is not None
            else QuantileBinner(int(bins), sketch_k=sketch_k, seed=seed)
        )
        self.bins = self._binner.bins
        self._fit_block_obs = int(fit_block_obs)

    @property
    def num_obs(self) -> int:
        return self.base.num_obs

    @property
    def num_features(self) -> int:
        return self.base.num_features

    @property
    def binner(self) -> QuantileBinner:
        """The fitted binner — running the sketch pass on first access,
        or reusing a memoised fit for this fingerprint (zero I/O)."""
        if self._binner.fitted:
            return self._binner
        fp = self.fingerprint()
        with _BINNER_LOCK:
            memo = _BINNER_MEMO.get(fp)
            if memo is not None:
                _BINNER_MEMO.move_to_end(fp)
        if memo is not None:
            self._binner = memo
            return memo
        self._binner.fit(self.base, block_obs=self._fit_block_obs)
        with _BINNER_LOCK:
            _BINNER_MEMO[fp] = self._binner
            _BINNER_MEMO.move_to_end(fp)
            while len(_BINNER_MEMO) > _BINNER_MEMO_CAP:
                _BINNER_MEMO.popitem(last=False)
        return self._binner

    def iter_blocks(self, block_obs: int) -> Iterator[Block]:
        binner = self.binner
        for X_blk, y_blk in self.base.iter_blocks(block_obs):
            yield binner.transform(X_blk), _as_class_labels(y_blk)

    def iter_shard_blocks(
        self,
        block_obs: int,
        obs_range: "tuple | None" = None,
        col_range: "tuple | None" = None,
    ) -> Iterator[Block]:
        # Shard the RAW window through the base (direct-slicing overrides
        # stay in effect), then encode only the window's columns with the
        # GLOBAL edges — the binner fit is a pure function of the whole
        # base stream, so every host cuts identical edges and the shard's
        # codes match a full-source encode bit-for-bit.
        binner = self.binner
        clo, _ = col_range if col_range is not None else (0, self.num_features)
        for X_blk, y_blk in self.base.iter_shard_blocks(
            block_obs, obs_range, col_range
        ):
            codes = np.empty(X_blk.shape, np.int32)
            for idx in range(X_blk.shape[1]):
                codes[:, idx] = binner.encode_column(clo + idx, X_blk[:, idx])
            yield codes, _as_class_labels(y_blk)

    @property
    def feature_dtype(self) -> np.dtype:
        return np.dtype(np.int32)  # transform() emits int32 codes

    def stats(self, block_obs: int = 65536) -> SourceStats:
        # No scan needed: codes are [0, bins) by construction and the
        # class count was recorded during the sketch pass.
        return SourceStats(
            discrete=True,
            num_values=self.bins,
            num_classes=self.binner.num_classes_,
        )

    def _fingerprint_update(self, h) -> None:
        h.update(b"binned|")
        h.update(self.base.fingerprint().encode())
        h.update(
            repr(
                (self._binner.bins, self._binner.sketch_k, self._binner.seed)
            ).encode()
        )


def fit_binned(
    source: DataSource,
    bins: int,
    *,
    block_obs: int = 65536,
    sketch_k: int = 512,
    seed: int = 0,
) -> BinnedSource:
    """Wrap + eagerly fit: ``BinnedSource`` with the sketch pass done."""
    binned = BinnedSource(
        source, bins, sketch_k=sketch_k, seed=seed, fit_block_obs=block_obs
    )
    binned.binner  # force the (memoised) sketch pass now
    return binned


__all__ = [
    "BinnedSource",
    "QuantileBinner",
    "QuantileSketch",
    "clear_binner_memo",
    "fit_binned",
]
