"""Encoded-block spill cache — pay the parse/encode tax once per dataset.

The streaming engine visits its source ``L`` times (1 relevance +
``L-1`` redundancy passes), and every pass re-does the expensive host
work from scratch: CSV parse, dtype conversion, quantile-bin encode.
:class:`BlockCacheSource` is a write-through / read-through cache at
exactly the point where that work is done — post parse, post
:class:`~repro.data.binning.BinnedSource` encode, pre placement:

* **pass 1** streams the wrapped source normally and spills every block
  to ``cache_dir`` as compact ``.npy`` chunks (written to a temp name,
  published with an atomic ``os.replace``; a manifest lands last, so a
  crash mid-write can never look like a complete entry);
* **passes 2..L** replay the memmapped chunks — zero parse, zero encode,
  and (for a binned source) a fraction of the bytes: int codes spill at
  the narrowest integer dtype that holds ``bins`` values (``int8`` for
  the common ``bins<=127`` case vs the base's float32 — 4x fewer bytes).

Entries are keyed by ``fingerprint() × block_obs`` (a
:class:`~repro.data.binning.BinnedSource` fingerprint already folds the
bin config in, so ``bins=16`` and ``bins=64`` spills never collide) and
evicted LRU against a byte ``budget``.  Replay re-verifies every chunk
against the manifest's recorded sizes: a truncated or missing chunk
invalidates the whole entry and the pass silently falls back to
re-staging from the base source — a corrupt spill can cost a pass, never
a wrong selection.

The wrapper IS its base source to every consumer: same geometry, same
block stream (values, order, block-size independence), same
``fingerprint()`` — so the selection service's result cache treats
spilled and direct fits as the same content, which they are.

Like the rest of ``repro.data`` this module is numpy-only: importing it
never initialises a jax backend.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import threading
from typing import Iterator

import numpy as np

from repro.data.binning import BinnedSource
from repro.data.sources import Block, DataSource, SourceStats

_MANIFEST = "manifest.json"

# One lock per process: entry publication (chunks + manifest) and LRU
# eviction mutate shared directories.  Cross-process safety rides on the
# atomic renames — a reader either sees a complete entry or none.
_CACHE_LOCK = threading.Lock()


def _narrow_int_dtype(num_values: int) -> np.dtype:
    """Smallest signed integer dtype holding codes in ``[0, num_values)``."""
    for dt in (np.int8, np.int16, np.int32):
        if num_values - 1 <= np.iinfo(dt).max:
            return np.dtype(dt)
    return np.dtype(np.int64)


def _atomic_save(path: str, arr: np.ndarray) -> None:
    """Write ``arr`` as ``.npy`` via a temp file + atomic rename, so a
    crash mid-write leaves a stray temp, never a truncated ``path``."""
    d = os.path.dirname(path)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.save(f, arr)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


@dataclasses.dataclass
class BlockCacheSource(DataSource):
    """A :class:`DataSource` wrapper that spills staged blocks to disk.

    Args:
      base: the source to cache.  Wrapping a
        :class:`~repro.data.binning.BinnedSource` caches the *encoded*
        int codes (the expensive part), downcast to the narrowest integer
        dtype that holds ``bins`` values.
      cache_dir: spill directory (created on demand).  Entries are
        subdirectories keyed by ``fingerprint() × block_obs``; several
        sources (or processes) may share one ``cache_dir``.
      budget_bytes: LRU byte budget for ``cache_dir`` as a whole; when a
        freshly completed entry pushes the total over, the least recently
        replayed entries are evicted (never the one just written).
        ``None`` = unbounded.
      namespace: extra entry-key segment for writers that must never share
        an entry even at identical content — multi-host fits pass their
        process index (``"h0"``, ``"h1"``, ...) so hosts on one shared
        filesystem can never race each other's chunks or manifests (shard
        windows already make the *fingerprints* distinct; the namespace
        makes disjointness a contract rather than a property of the
        wrapped source).

    Counters (:attr:`counters`) record the parse-vs-replay split so I/O
    savings are measurable, not guessed: ``parse_passes``/``parsed_bytes``
    count blocks staged from the base source, ``replay_passes``/
    ``replayed_bytes`` count blocks served from the spill.
    """

    base: DataSource
    cache_dir: str
    budget_bytes: int | None = None
    namespace: str = ""

    def __post_init__(self):
        if not isinstance(self.base, DataSource):
            raise TypeError(
                f"BlockCacheSource wraps a DataSource, got "
                f"{type(self.base).__name__}"
            )
        if isinstance(self.base, BlockCacheSource):
            raise ValueError("base source is already block-cached")
        if self.budget_bytes is not None and self.budget_bytes <= 0:
            raise ValueError(
                f"budget_bytes must be positive or None, got "
                f"{self.budget_bytes}"
            )
        if self.namespace and not all(
            c.isalnum() or c in "-_." for c in self.namespace
        ):
            raise ValueError(
                f"namespace {self.namespace!r} must be filesystem-safe "
                "(alphanumerics, '-', '_', '.')"
            )
        # Encoded spill dtype: known without I/O only for binned bases
        # (codes live in [0, bins)); everything else spills as-is.
        self._spill_dtype = (
            _narrow_int_dtype(self.base.bins)
            if isinstance(self.base, BinnedSource)
            else None
        )
        self.counters = dict(
            parse_passes=0, parsed_bytes=0, replay_passes=0, replayed_bytes=0
        )

    # -- delegated identity/geometry ------------------------------------

    @property
    def num_obs(self) -> int:
        return self.base.num_obs

    @property
    def num_features(self) -> int:
        return self.base.num_features

    @property
    def feature_dtype(self) -> np.dtype | None:
        dt = self.base.feature_dtype
        return self._spill_dtype if self._spill_dtype is not None else dt

    def fingerprint(self) -> str:
        # Same content, same address: the cache changes where blocks come
        # from, never what they hold — result-cache keys must coalesce.
        return self.base.fingerprint()

    def stats(self, block_obs: int = 65536) -> SourceStats:
        return self.base.stats(block_obs)

    # -- entry layout ----------------------------------------------------

    def _entry_dir(self, block_obs: int) -> str:
        ns = f"-{self.namespace}" if self.namespace else ""
        return os.path.join(
            self.cache_dir, f"{self.fingerprint()[:32]}-b{int(block_obs)}{ns}"
        )

    def _chunk_paths(self, entry: str, i: int) -> tuple[str, str]:
        return (
            os.path.join(entry, f"X{i:05d}.npy"),
            os.path.join(entry, f"y{i:05d}.npy"),
        )

    def _load_manifest(self, entry: str) -> dict | None:
        try:
            with open(os.path.join(entry, _MANIFEST)) as f:
                return json.load(f)
        except (OSError, ValueError):
            return None

    def _verify(self, entry: str, manifest: dict | None) -> bool:
        """A replayable entry has a manifest whose every chunk exists at
        exactly the recorded byte size — a crash that truncated a chunk
        after the manifest landed (torn disk, copy) is caught here."""
        if not manifest or manifest.get("version") != 1:
            return False
        if manifest.get("num_obs") != self.num_obs or manifest.get(
            "num_features"
        ) != self.num_features:
            return False
        for i, ch in enumerate(manifest.get("chunks", [])):
            xp, yp = self._chunk_paths(entry, i)
            try:
                ok = (
                    os.path.getsize(xp) == ch["x_bytes"]
                    and os.path.getsize(yp) == ch["y_bytes"]
                )
            except OSError:
                return False
            if not ok:
                return False
        return True

    # -- the block stream ------------------------------------------------

    def iter_blocks(self, block_obs: int) -> Iterator[Block]:
        entry = self._entry_dir(block_obs)
        manifest = self._load_manifest(entry)
        with _CACHE_LOCK:
            replayable = self._verify(entry, manifest)
        if replayable:
            yield from self._replay(entry, manifest)
        else:
            yield from self._stage_and_spill(entry, block_obs)

    def _replay(self, entry: str, manifest: dict) -> Iterator[Block]:
        self.counters["replay_passes"] += 1
        os.utime(entry)  # LRU recency: replays keep an entry warm
        for i in range(len(manifest["chunks"])):
            xp, yp = self._chunk_paths(entry, i)
            # Memmapped load: replay never allocates the chunk on the
            # host — the consumer (placer) copies straight out of the
            # page cache while padding.
            X = np.load(xp, mmap_mode="r")
            y = np.load(yp, mmap_mode="r")
            self.counters["replayed_bytes"] += X.nbytes + y.nbytes
            yield X, y

    def _stage_and_spill(self, entry: str, block_obs: int) -> Iterator[Block]:
        self.counters["parse_passes"] += 1
        os.makedirs(entry, exist_ok=True)
        chunks = []
        for i, (X, y) in enumerate(self.base.iter_blocks(block_obs)):
            if self._spill_dtype is not None and X.dtype != self._spill_dtype:
                X = X.astype(self._spill_dtype)
            X = np.ascontiguousarray(X)
            y = np.ascontiguousarray(y)
            self.counters["parsed_bytes"] += X.nbytes + y.nbytes
            xp, yp = self._chunk_paths(entry, i)
            _atomic_save(xp, X)
            _atomic_save(yp, y)
            chunks.append(
                dict(
                    rows=int(X.shape[0]),
                    x_bytes=os.path.getsize(xp),
                    y_bytes=os.path.getsize(yp),
                )
            )
            yield X, y
        # The manifest is written LAST (atomically): its presence asserts
        # every chunk above it is complete.  A crash anywhere before this
        # line leaves a manifest-less entry that replay refuses.
        manifest = dict(
            version=1,
            num_obs=self.num_obs,
            num_features=self.num_features,
            block_obs=int(block_obs),
            chunks=chunks,
            bytes=sum(c["x_bytes"] + c["y_bytes"] for c in chunks),
        )
        d = os.path.dirname(os.path.join(entry, _MANIFEST))
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, os.path.join(entry, _MANIFEST))
        self._evict(keep=entry)

    # -- LRU eviction ----------------------------------------------------

    def _entries(self) -> list:
        """(mtime, path, bytes) of every complete entry under cache_dir."""
        out = []
        try:
            names = os.listdir(self.cache_dir)
        except OSError:
            return out
        for name in names:
            path = os.path.join(self.cache_dir, name)
            m = self._load_manifest(path)
            if m is None:
                continue
            try:
                out.append((os.stat(path).st_mtime, path, int(m.get("bytes", 0))))
            except OSError:
                continue
        return out

    def _evict(self, keep: str) -> None:
        """Drop least-recently-used entries until the directory fits the
        byte budget; the entry just written (``keep``) is never evicted."""
        if self.budget_bytes is None:
            return
        with _CACHE_LOCK:
            entries = self._entries()
            total = sum(b for _, _, b in entries)
            for _, path, nbytes in sorted(entries):
                if total <= self.budget_bytes:
                    break
                if os.path.abspath(path) == os.path.abspath(keep):
                    continue
                _rmtree_entry(path)
                total -= nbytes

    def spilled_bytes(self, block_obs: int) -> int | None:
        """Byte size of this source's entry for ``block_obs`` (None when
        the entry is incomplete or absent)."""
        m = self._load_manifest(self._entry_dir(block_obs))
        return None if m is None else int(m.get("bytes", 0))


def _rmtree_entry(path: str) -> None:
    """Remove one cache entry directory (manifest first, so a concurrent
    reader that raced past _verify sees missing chunks, not torn ones)."""
    try:
        os.unlink(os.path.join(path, _MANIFEST))
    except OSError:
        pass
    try:
        for name in os.listdir(path):
            try:
                os.unlink(os.path.join(path, name))
            except OSError:
                pass
        os.rmdir(path)
    except OSError:
        pass


__all__ = ["BlockCacheSource"]
