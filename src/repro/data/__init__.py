from repro.data.binning import (  # noqa: F401
    BinnedSource,
    QuantileBinner,
    QuantileSketch,
    fit_binned,
)
from repro.data.synthetic import corral_dataset, lm_token_batches  # noqa: F401
from repro.data.pipeline import ShardedDataPipeline  # noqa: F401
from repro.data.sources import (  # noqa: F401
    ArraySource,
    CSVSource,
    CorralSource,
    DataSource,
    NpySource,
    SourceStats,
    SyntheticTokenSource,
    as_source,
)
