from repro.data.synthetic import corral_dataset, lm_token_batches  # noqa: F401
from repro.data.pipeline import ShardedDataPipeline  # noqa: F401
