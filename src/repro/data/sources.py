"""Out-of-core dataset ingestion — the ``DataSource`` protocol.

The paper's premise is a dataset too big for one node: MapReduce workers
each see a *partition*, emit sufficient statistics, and the reducer sums
them.  ``DataSource`` is that partition interface for this repo: a source
knows its global geometry (``num_obs`` × ``num_features``) and yields
observation-blocks — host-side numpy arrays ``(X_block (B, N), y_block
(B,))`` in conventional orientation with ``B <= block_obs`` — whose
concatenation is the full dataset, in a deterministic order that does not
depend on the requested block size.

Everything that can feed a fit is a source: in-memory arrays
(:class:`ArraySource`), memmapped ``.npy`` files (:class:`NpySource`),
CSV files (:class:`CSVSource`), Parquet files and in-memory Arrow tables
(:class:`ParquetSource` / :class:`ArrowSource`, soft-gated on pyarrow)
and the paper's synthetic generator
(:class:`CorralSource`).  The streaming engine
(``repro.core.streaming``) consumes blocks and accumulates per-score
sufficient statistics, so peak device memory is bounded by the block
size, never by ``num_obs``.

This module is deliberately numpy-only: importing it never initialises a
jax backend, so launchers can still set ``XLA_FLAGS`` after import.

The LM side of the repo speaks the same block language through
:class:`SyntheticTokenSource` — a *step-indexed* source (an infinite
stream pure in ``(seed, step)``) that ``repro.data.pipeline`` places onto
a mesh; finite selection sources and infinite token sources are the two
faces of one host-blocks protocol.
"""

from __future__ import annotations

import dataclasses
import hashlib
import io
import os
import threading
from collections import OrderedDict
from typing import Iterator, Tuple

import numpy as np

Block = Tuple[np.ndarray, np.ndarray]

# Internal generation granularity of synthetic sources: fixed, so the
# emitted dataset is identical for every requested block_obs.
_GEN_CHUNK = 8192

# Cross-instance stats memo, keyed by source fingerprint: repeated fits on
# the same file (the selection service constructs a fresh source per
# request) used to rescan ``stats()`` — one full pass of I/O — every time.
# Bounded LRU; :func:`clear_stats_memo` resets it (tests).
_STATS_MEMO: OrderedDict = OrderedDict()
_STATS_MEMO_CAP = 256
_STATS_LOCK = threading.Lock()


def clear_stats_memo() -> None:
    """Drop every memoised ``stats()`` scan (tests / changed files)."""
    with _STATS_LOCK:
        _STATS_MEMO.clear()


@dataclasses.dataclass(frozen=True)
class SourceStats:
    """Streaming-scan metadata used to auto-resolve a score function."""

    discrete: bool      # X and y both integral -> exact-MI territory
    num_values: int     # d_v: 1 + max feature category (0 if continuous)
    num_classes: int    # d_c: 1 + max class label (0 if continuous)


def _rechunked(chunks: Iterator[Block], block_obs: int) -> Iterator[Block]:
    """Re-slice an (X, y) chunk stream into blocks of exactly ``block_obs``
    rows (the final block may be ragged).  Chunk boundaries of the producer
    never leak into the consumer's block boundaries."""
    pend_x, pend_y, have = [], [], 0
    for X, y in chunks:
        pend_x.append(X)
        pend_y.append(y)
        have += X.shape[0]
        if have >= block_obs:
            # Concatenate once per producer chunk, then slice every full
            # block out as views — linear total copying, however small the
            # requested blocks are relative to the producer's chunks.
            Xc, yc = np.concatenate(pend_x), np.concatenate(pend_y)
            lo = 0
            while have - lo >= block_obs:
                yield Xc[lo : lo + block_obs], yc[lo : lo + block_obs]
                lo += block_obs
            pend_x, pend_y = [Xc[lo:]], [yc[lo:]]
            have -= lo
    if have:
        yield np.concatenate(pend_x), np.concatenate(pend_y)


class DataSource:
    """Base class: geometry + deterministic observation-block iteration."""

    @property
    def num_obs(self) -> int:
        raise NotImplementedError

    @property
    def num_features(self) -> int:
        raise NotImplementedError

    def iter_blocks(self, block_obs: int) -> Iterator[Block]:
        """Yield ``(X (B, N), y (B,))`` numpy blocks, ``B <= block_obs``,
        concatenating to the full dataset in a block-size-independent order."""
        raise NotImplementedError

    @property
    def feature_dtype(self) -> "np.dtype | None":
        """Static dtype of the feature blocks, when knowable WITHOUT I/O
        (``None`` otherwise).  Lets the selector route discrete-vs-
        continuous without spending an ``iter_blocks`` pass — a floating
        hint means continuous, any other hint means discrete (matching
        the dtype rule in :meth:`stats`)."""
        return None

    # -- identity --------------------------------------------------------

    def fingerprint(self) -> str:
        """Content address of this source (hex sha256, memoised).

        Two sources with the same fingerprint yield the same dataset; the
        selection service keys its result cache and stats memo on it.
        File-backed sources hash ``(path, size, mtime_ns)`` — the build-
        system convention: cheap, and any rewrite changes it.  Synthetic
        sources hash their generating parameters.  The base implementation
        content-hashes the block stream (one pass; in-memory sources only).
        """
        cached = getattr(self, "_fingerprint", None)
        if cached is not None:
            return cached
        h = hashlib.sha256()
        h.update(
            f"{type(self).__name__}:{self.num_obs}x{self.num_features}:".encode()
        )
        self._fingerprint_update(h)
        fp = h.hexdigest()
        object.__setattr__(self, "_fingerprint", fp)  # frozen-dataclass safe
        return fp

    def _fingerprint_update(self, h) -> None:
        """Subclass hook: feed identity into the hash.  Default: full
        content (dtypes + bytes of every block)."""
        for X, y in self.iter_blocks(65536):
            h.update(str(X.dtype).encode())
            h.update(np.ascontiguousarray(X).tobytes())
            h.update(str(y.dtype).encode())
            h.update(np.ascontiguousarray(y).tobytes())

    # -- shard-restricted iteration (multi-host map) --------------------

    def iter_shard_blocks(
        self,
        block_obs: int,
        obs_range: "tuple | None" = None,
        col_range: "tuple | None" = None,
    ) -> Iterator[Block]:
        """Yield blocks covering only ``rows[obs_range] × cols[col_range]``
        — the multi-host map step, where each host walks its own shard.

        The default walks :meth:`iter_blocks` and slices, stopping early
        once past the row window (so a host partitioned to the first half
        of a file never reads the second half through a row-ordered
        source); array-backed sources override with direct slicing that
        touches only the window's bytes.  Blocks are re-chunked to exactly
        ``block_obs`` rows so shard streams are block-size deterministic
        like everything else.
        """
        olo, ohi = obs_range if obs_range is not None else (0, self.num_obs)
        clo, chi = col_range if col_range is not None else (0, self.num_features)
        whole_cols = (clo, chi) == (0, self.num_features)

        def windowed() -> Iterator[Block]:
            off = 0
            it = self.iter_blocks(block_obs)
            try:
                for X, y in it:
                    n = X.shape[0]
                    if off >= ohi:
                        break
                    lo, hi = max(olo - off, 0), min(ohi - off, n)
                    if lo < hi:
                        Xs = X[lo:hi] if whole_cols else X[lo:hi, clo:chi]
                        yield np.ascontiguousarray(Xs), y[lo:hi]
                    off += n
            finally:
                close = getattr(it, "close", None)
                if close is not None:
                    close()  # release file handles promptly (CSVSource)

        yield from _rechunked(windowed(), block_obs)

    # -- derived conveniences -------------------------------------------

    def stats(self, block_obs: int = 65536) -> SourceStats:
        """One streaming pass of metadata (cached): dtype regime + the
        paper's ``d_v`` / ``d_c`` category counts.

        Memoised twice over: per instance, and across instances by
        :meth:`fingerprint` — a fresh source on the same file (how the
        selection service builds them) reuses the scan instead of paying
        a full pass of I/O per fit.
        """
        cached = getattr(self, "_stats", None)
        if cached is not None:
            return cached
        fp = self.fingerprint()
        with _STATS_LOCK:
            memo = _STATS_MEMO.get(fp)
            if memo is not None:
                _STATS_MEMO.move_to_end(fp)
        if memo is not None:
            object.__setattr__(self, "_stats", memo)
            return memo
        x_max = y_max = 0
        x_min = y_min = 0
        discrete = True
        for X, y in self.iter_blocks(block_obs):
            discrete = discrete and (
                np.issubdtype(X.dtype, np.integer) or X.dtype == np.bool_
            ) and (np.issubdtype(y.dtype, np.integer) or y.dtype == np.bool_)
            if not discrete:
                break  # dtype settles it; don't burn a full pass of I/O
            x_max = max(x_max, int(X.max(initial=0)))
            y_max = max(y_max, int(y.max(initial=0)))
            x_min = min(x_min, int(X.min(initial=0)))
            y_min = min(y_min, int(y.min(initial=0)))
        if discrete and (x_min < 0 or y_min < 0):
            # A negative category one-hots to an all-zero row, so the
            # observation silently vanishes from every contingency count
            # and the resulting MI is wrong with no error anywhere.
            raise ValueError(
                "negative category values in discrete source "
                f"(min feature value {x_min}, min target value {y_min}): "
                "one-hot contingency counts drop them silently; remap "
                "categories to 0..K-1 before fitting"
            )
        st = SourceStats(
            discrete=discrete,
            num_values=x_max + 1 if discrete else 0,
            num_classes=y_max + 1 if discrete else 0,
        )
        object.__setattr__(self, "_stats", st)  # works on frozen dataclasses
        with _STATS_LOCK:
            _STATS_MEMO[fp] = st
            _STATS_MEMO.move_to_end(fp)
            while len(_STATS_MEMO) > _STATS_MEMO_CAP:
                _STATS_MEMO.popitem(last=False)
        return st

    def materialize(self, block_obs: int = 65536) -> Block:
        """Concatenate every block — small datasets and tests only."""
        xs, ys = zip(*self.iter_blocks(block_obs))
        return np.concatenate(xs), np.concatenate(ys)

    def to_npy(
        self, x_path: str, y_path: str, block_obs: int = 65536
    ) -> tuple[str, str]:
        """Stream the source into ``.npy`` files (block-wise via memmap, no
        full-dataset host allocation) — ready for :class:`NpySource`."""
        peek = self.iter_blocks(1)
        try:
            first = next(peek)  # dtype peek, one row
        finally:
            # Close the peek iterator explicitly: an abandoned generator
            # keeps its frame (and e.g. CSVSource's open file handle)
            # alive until GC, which is not prompt off-CPython.
            close = getattr(peek, "close", None)
            if close is not None:
                close()
        Xm = np.lib.format.open_memmap(
            x_path, mode="w+", dtype=first[0].dtype,
            shape=(self.num_obs, self.num_features),
        )
        ym = np.lib.format.open_memmap(
            y_path, mode="w+", dtype=first[1].dtype, shape=(self.num_obs,)
        )
        lo = 0
        for X, y in self.iter_blocks(block_obs):
            Xm[lo : lo + X.shape[0]] = X
            ym[lo : lo + X.shape[0]] = y
            lo += X.shape[0]
        Xm.flush()
        ym.flush()
        return x_path, y_path


def as_source(X, y=None) -> DataSource:
    """Coerce ``fit`` inputs to a source: pass sources through, wrap arrays."""
    if isinstance(X, DataSource):
        if y is not None:
            raise ValueError("y comes from the DataSource; pass the source alone")
        return X
    if y is None:
        raise ValueError("array inputs need a target: as_source(X, y)")
    return ArraySource(X, y)


class ArraySource(DataSource):
    """In-memory (or memmapped) arrays as a source — the fast-path adapter."""

    def __init__(self, X, y):
        # asanyarray keeps memmaps memmapped (no eager load) while copying
        # device arrays to host exactly once.
        self.X = np.asanyarray(X)
        self.y = np.asanyarray(y)
        # y must be exactly 1-D: a (M, k) target would pass a leading-dim
        # check yet mis-shape every downstream streaming accumulation
        # (Pearson moments broadcast (B,) targets against (B, N) blocks).
        if (
            self.X.ndim != 2
            or self.y.ndim != 1
            or self.y.shape[0] != self.X.shape[0]
        ):
            raise ValueError(f"bad shapes X{self.X.shape} y{self.y.shape}")

    @property
    def num_obs(self) -> int:
        return self.X.shape[0]

    @property
    def num_features(self) -> int:
        return self.X.shape[1]

    @property
    def feature_dtype(self) -> np.dtype:
        return self.X.dtype

    def iter_blocks(self, block_obs: int) -> Iterator[Block]:
        for lo in range(0, self.num_obs, block_obs):
            hi = min(lo + block_obs, self.num_obs)
            # np.array forces a real copy: yielded blocks are contiguous
            # and independent of the backing store, so consumers that
            # retain them never pin a memmapped file.
            yield np.array(self.X[lo:hi]), np.array(self.y[lo:hi])

    def iter_shard_blocks(
        self,
        block_obs: int,
        obs_range: "tuple | None" = None,
        col_range: "tuple | None" = None,
    ) -> Iterator[Block]:
        # Direct window slicing: a memmapped host never faults in pages
        # outside its shard (the default walks every leading block).
        olo, ohi = obs_range if obs_range is not None else (0, self.num_obs)
        clo, chi = col_range if col_range is not None else (0, self.num_features)
        for lo in range(olo, ohi, block_obs):
            hi = min(lo + block_obs, ohi)
            yield (
                np.ascontiguousarray(self.X[lo:hi, clo:chi]),
                np.array(self.y[lo:hi]),
            )


class NpySource(ArraySource):
    """Memmapped ``.npy`` feature matrix + target vector.

    The file is never loaded whole: ``np.load(mmap_mode="r")`` maps it and
    ``iter_blocks`` copies one observation-block at a time, so datasets far
    larger than device (or host) memory stream through a fit.
    """

    def __init__(self, x_path: str, y_path: str, *, mmap: bool = True):
        mode = "r" if mmap else None
        super().__init__(
            np.load(x_path, mmap_mode=mode), np.load(y_path, mmap_mode=mode)
        )
        self.x_path, self.y_path = x_path, y_path

    def _fingerprint_update(self, h) -> None:
        # (path, size, mtime_ns) instead of content: fingerprinting must
        # not cost a full pass over a file that exists precisely because
        # it does not fit in memory.
        _stat_fingerprint(h, self.x_path, self.y_path)


class CSVSource(DataSource):
    """Streaming CSV reader: parses ``block_obs`` lines at a time.

    Args:
      path: CSV file; a non-numeric first line is treated as a header.
      target_col: column index of the target (default: last column).
      dtype: feature dtype (use an integer dtype for discrete/MI data).
      target_dtype: target dtype (default: ``dtype``).
      delimiter: field separator.
    """

    def __init__(
        self,
        path: str,
        *,
        target_col: int = -1,
        dtype=np.float32,
        target_dtype=None,
        delimiter: str = ",",
    ):
        self.path = path
        self.target_col = target_col
        self.dtype = np.dtype(dtype)
        self.target_dtype = np.dtype(target_dtype or dtype)
        self.delimiter = delimiter
        with open(path) as f:
            first = f.readline()
        if not first:
            raise ValueError(f"empty CSV {path!r}")
        fields = first.strip().split(delimiter)
        self._has_header = not _all_numeric(fields)
        self._num_cols = len(fields)
        self._num_obs: int | None = None

    @property
    def num_obs(self) -> int:
        if self._num_obs is None:  # one cheap line-count pass, cached
            with open(self.path) as f:
                n = sum(1 for line in f if line.strip())
            self._num_obs = n - int(self._has_header)
        return self._num_obs

    @property
    def num_features(self) -> int:
        return self._num_cols - 1

    @property
    def feature_dtype(self) -> np.dtype:
        return self.dtype

    def _parse(self, lines: list) -> Block:
        tgt = self.target_col % self._num_cols
        keep = [c for c in range(self._num_cols) if c != tgt]
        rows = np.loadtxt(
            io.StringIO("".join(lines)),
            delimiter=self.delimiter,
            ndmin=2,
            dtype=np.float64,
        )
        return rows[:, keep].astype(self.dtype), rows[:, tgt].astype(
            self.target_dtype
        )

    def _fingerprint_update(self, h) -> None:
        # Parse knobs are part of the identity: the same file read with a
        # different target column or dtype is a different dataset.
        _stat_fingerprint(h, self.path)
        h.update(
            repr(
                (self.target_col, str(self.dtype), str(self.target_dtype),
                 self.delimiter)
            ).encode()
        )

    def iter_blocks(self, block_obs: int) -> Iterator[Block]:
        with open(self.path) as f:
            if self._has_header:
                f.readline()
            lines: list = []
            # Count only non-blank lines toward the block, so blank runs of
            # any length never truncate the stream.
            for line in f:
                if not line.strip():
                    continue
                lines.append(line)
                if len(lines) == block_obs:
                    yield self._parse(lines)
                    lines = []
            if lines:
                yield self._parse(lines)


def _pyarrow(what: str):
    """Soft-import pyarrow: columnar sources are optional, and the error
    should say what to install rather than NameError deep in a fit."""
    try:
        import pyarrow as pa
        import pyarrow.parquet as pq
    except ImportError:
        raise ImportError(
            f"{what} requires pyarrow; install it (pip install pyarrow) "
            "or convert the data to .npy/.csv for the built-in readers"
        ) from None
    return pa, pq


def _arrow_numpy_dtype(fields) -> np.dtype:
    """Schema -> block dtype: all-integral (incl. bool) columns stream as
    int32 (exact-MI territory), anything else as float32 — the same
    discrete-vs-continuous split :meth:`DataSource.stats` applies."""
    import pyarrow.types as pt

    integral = all(
        pt.is_integer(f.type) or pt.is_boolean(f.type) for f in fields
    )
    return np.dtype(np.int32 if integral else np.float32)


class _ColumnarSource(DataSource):
    """Shared column-wise block extraction for Arrow-layout sources.

    Subclasses provide ``_batches(block_obs)`` — an iterator of
    RecordBatch/Table slices in row order — plus resolved feature/target
    column names and dtypes; this base turns each slice into the
    protocol's ``(X (B, N), y (B,))`` numpy block.
    """

    def _resolve_columns(self, names, target_col):
        if isinstance(target_col, str):
            if target_col not in names:
                raise ValueError(
                    f"target column {target_col!r} not in schema {names}"
                )
            tgt = target_col
        else:
            tgt = names[int(target_col) % len(names)]
        self._tgt_name = tgt
        self._feat_names = [n for n in names if n != tgt]
        if not self._feat_names:
            raise ValueError("schema holds only the target column")

    def _block_of(self, batch) -> Block:
        def col(name):
            idx = batch.schema.get_field_index(name)
            return batch.column(idx).to_numpy(zero_copy_only=False)

        X = np.column_stack(
            [col(n).astype(self.dtype, copy=False) for n in self._feat_names]
        )
        y = col(self._tgt_name).astype(self.target_dtype, copy=False)
        return np.ascontiguousarray(X), np.ascontiguousarray(y)

    @property
    def num_features(self) -> int:
        return len(self._feat_names)

    @property
    def feature_dtype(self) -> np.dtype:
        return self.dtype


class ParquetSource(_ColumnarSource):
    """Streaming Parquet reader (pyarrow) — column-chunked row batches.

    ``pq.ParquetFile.iter_batches`` decodes ``block_obs`` rows at a time
    straight from the file's row groups, so peak host memory is one block
    regardless of file size; row order is file order, independent of the
    requested block size.  Geometry (``num_obs``) comes from the Parquet
    footer metadata — no data pages are read until ``iter_blocks``.

    Args:
      path: ``.parquet`` file.
      target_col: target column name, or index into the schema (default:
        last column).
      dtype / target_dtype: numpy dtypes for the emitted blocks; default
        derives from the schema (all-integral columns -> int32 for exact
        MI, otherwise float32 — pair with ``bins=`` on the selector).

    Composes like every other source: wrap in ``BinnedSource`` for
    on-the-fly quantile discretisation or ``BlockCacheSource`` to spill
    decoded blocks across selection passes.
    """

    def __init__(
        self, path: str, *, target_col=-1, dtype=None, target_dtype=None
    ):
        _, pq = _pyarrow("ParquetSource")
        self.path = path
        self.target_col = target_col
        meta = pq.ParquetFile(path)
        try:
            schema = meta.schema_arrow
            self._resolve_columns(list(schema.names), target_col)
            self._num_obs = int(meta.metadata.num_rows)
            fields = {f.name: f for f in schema}
        finally:
            meta.close()
        self.dtype = (
            np.dtype(dtype)
            if dtype is not None
            else _arrow_numpy_dtype([fields[n] for n in self._feat_names])
        )
        self.target_dtype = (
            np.dtype(target_dtype)
            if target_dtype is not None
            else _arrow_numpy_dtype([fields[self._tgt_name]])
        )

    @property
    def num_obs(self) -> int:
        return self._num_obs

    def _fingerprint_update(self, h) -> None:
        # (path, size, mtime_ns) like NpySource — never a content pass —
        # plus the parse knobs: same file, different target column or
        # dtype is a different dataset.
        _stat_fingerprint(h, self.path)
        h.update(
            repr(
                (self.target_col, str(self.dtype), str(self.target_dtype))
            ).encode()
        )

    def iter_blocks(self, block_obs: int) -> Iterator[Block]:
        _, pq = _pyarrow("ParquetSource")
        pf = pq.ParquetFile(self.path)
        try:
            cols = self._feat_names + [self._tgt_name]
            for batch in pf.iter_batches(batch_size=block_obs, columns=cols):
                yield self._block_of(batch)
        finally:
            pf.close()


class ArrowSource(_ColumnarSource):
    """An in-memory ``pyarrow.Table`` (or RecordBatch) as a source.

    The zero-copy handoff for data already in Arrow memory — a Flight
    fetch, a DuckDB/Polars result — sliced into observation blocks
    without ever round-tripping through a file.
    """

    def __init__(self, table, *, target_col=-1, dtype=None, target_dtype=None):
        pa, _ = _pyarrow("ArrowSource")
        if isinstance(table, pa.RecordBatch):
            table = pa.Table.from_batches([table])
        self.table = table
        self.target_col = target_col
        self._resolve_columns(list(table.schema.names), target_col)
        fields = {f.name: f for f in table.schema}
        self.dtype = (
            np.dtype(dtype)
            if dtype is not None
            else _arrow_numpy_dtype([fields[n] for n in self._feat_names])
        )
        self.target_dtype = (
            np.dtype(target_dtype)
            if target_dtype is not None
            else _arrow_numpy_dtype([fields[self._tgt_name]])
        )

    @property
    def num_obs(self) -> int:
        return int(self.table.num_rows)

    def iter_blocks(self, block_obs: int) -> Iterator[Block]:
        for lo in range(0, self.num_obs, block_obs):
            yield self._block_of(self.table.slice(lo, block_obs))


@dataclasses.dataclass(frozen=True)
class ShardSource(DataSource):
    """A window of another source, presented as a complete source.

    The multi-host engine wraps each host's base source in one of these
    (ranges from ``HostShardSpec``), so every downstream consumer —
    placer, spill cache, read-ahead, binning — sees an ordinary
    ``num_obs × num_features`` source and streams only the shard's
    bytes.  The fingerprint folds the window into the base identity, so
    different hosts' spill caches for the same file never collide even
    before explicit namespacing.
    """

    base: DataSource
    obs_range: tuple
    col_range: tuple

    def __post_init__(self):
        olo, ohi = self.obs_range
        clo, chi = self.col_range
        if not (0 <= olo < ohi <= self.base.num_obs):
            raise ValueError(
                f"obs_range {self.obs_range} outside 0..{self.base.num_obs}"
            )
        if not (0 <= clo < chi <= self.base.num_features):
            raise ValueError(
                f"col_range {self.col_range} outside "
                f"0..{self.base.num_features}"
            )

    @property
    def num_obs(self) -> int:
        return self.obs_range[1] - self.obs_range[0]

    @property
    def num_features(self) -> int:
        return self.col_range[1] - self.col_range[0]

    @property
    def feature_dtype(self) -> "np.dtype | None":
        return self.base.feature_dtype

    def _fingerprint_update(self, h) -> None:
        h.update(
            f"shard|{self.base.fingerprint()}|"
            f"{self.obs_range}|{self.col_range}".encode()
        )

    def iter_blocks(self, block_obs: int) -> Iterator[Block]:
        yield from self.base.iter_shard_blocks(
            block_obs, self.obs_range, self.col_range
        )

    def iter_shard_blocks(
        self,
        block_obs: int,
        obs_range: "tuple | None" = None,
        col_range: "tuple | None" = None,
    ) -> Iterator[Block]:
        # Compose windows so nested sharding hits the base directly.
        olo, ohi = obs_range if obs_range is not None else (0, self.num_obs)
        clo, chi = col_range if col_range is not None else (0, self.num_features)
        yield from self.base.iter_shard_blocks(
            block_obs,
            (self.obs_range[0] + olo, self.obs_range[0] + ohi),
            (self.col_range[0] + clo, self.col_range[0] + chi),
        )


def _all_numeric(fields) -> bool:
    try:
        [float(v) for v in fields]
        return True
    except ValueError:
        return False


def _stat_fingerprint(h, *paths: str) -> None:
    """Feed ``(abspath, size, mtime_ns)`` of each file into the hash."""
    for p in paths:
        st = os.stat(p)
        h.update(
            f"{os.path.abspath(p)}:{st.st_size}:{st.st_mtime_ns};".encode()
        )


@dataclasses.dataclass(frozen=True)
class CorralSource(DataSource):
    """The paper's §V CorrAL-style generator as a streaming source (Eq. 3).

    Rows are generated in fixed internal chunks, each seeded by
    ``(seed, chunk_index)``, so the dataset is a pure function of
    ``(seed, num_obs, num_cols)`` — identical for every ``block_obs`` and
    never materialised whole.  Column layout matches
    ``repro.data.synthetic.corral_dataset``: 0..7 relevant (Eq. 3), 8
    partially class-correlated (75% agreement), the rest iid noise;
    ``flip_prob`` injects label noise.
    """

    num_rows: int
    num_cols: int
    seed: int = 0
    flip_prob: float = 0.05

    def __post_init__(self):
        if self.num_cols < 9:
            raise ValueError("CorralSource needs at least 9 columns")

    @property
    def num_obs(self) -> int:
        return self.num_rows

    @property
    def num_features(self) -> int:
        return self.num_cols

    @property
    def feature_dtype(self) -> np.dtype:
        return np.dtype(np.int8)

    def _fingerprint_update(self, h) -> None:
        # The dataset is a pure function of these parameters — no I/O.
        h.update(
            repr(
                (self.num_rows, self.num_cols, self.seed, self.flip_prob)
            ).encode()
        )

    def _chunk(self, ci: int) -> Block:
        rows = min(_GEN_CHUNK, self.num_rows - ci * _GEN_CHUNK)
        rng = np.random.default_rng((self.seed, ci))
        blk = rng.integers(0, 2, size=(rows, self.num_cols), dtype=np.int8)
        x = [blk[:, i].astype(bool) for i in range(8)]
        c = ((x[0] & x[1]) | (x[2] & x[3])) & ((x[4] & x[5]) | (x[6] & x[7]))
        agree = rng.random(rows) < 0.75
        blk[:, 8] = np.where(agree, c, ~c)
        if self.flip_prob > 0:
            flips = rng.random(rows) < self.flip_prob
            c = np.where(flips, ~c, c)
        return blk, c.astype(np.int8)

    def iter_blocks(self, block_obs: int) -> Iterator[Block]:
        nchunks = -(-self.num_rows // _GEN_CHUNK)
        yield from _rechunked(
            (self._chunk(ci) for ci in range(nchunks)), block_obs
        )


# ---------------------------------------------------------------------------
# step-indexed token sources (the LM-pipeline face of the protocol)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SyntheticTokenSource:
    """Infinite step-indexed token stream, pure in ``(seed, step)``.

    ``block(step, lo, hi)`` returns rows [lo, hi) of the global batch at
    ``step`` — the restart-replay property ``ShardedDataPipeline`` builds
    its fault tolerance on (same Zipf-ish marginal as
    ``synthetic.lm_token_batches``)."""

    global_batch: int
    seq_len: int
    vocab: int
    seed: int = 0

    def block(self, step: int, lo: int, hi: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step))
        u = rng.random((self.global_batch, self.seq_len + 1))[lo:hi]
        return (u * u * self.vocab).astype(np.int32)


__all__ = [
    "ArraySource",
    "ArrowSource",
    "CSVSource",
    "CorralSource",
    "DataSource",
    "NpySource",
    "ParquetSource",
    "ShardSource",
    "SourceStats",
    "SyntheticTokenSource",
    "as_source",
    "clear_stats_memo",
]
