import sys
import pathlib

# Make ``repro`` importable without an install step (mirrors PYTHONPATH=src).
sys.path.insert(0, str(pathlib.Path(__file__).parent / "src"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device semantics suites run as subprocesses"
    )
